"""Paper Fig. 15 reproduction on the production pod topology: two process
groups running DIFFERENT collectives (All-to-Allv + All-Gather) are jointly
synthesized over one shared TEN; NPUs outside both groups forward traffic.

    PYTHONPATH=src python examples/synthesize_pod.py

This is the *joint* synthesis layer: condition builders (``all_gather``,
``all_to_allv``, ...) compose several groups' requirements into one
synthesis problem. A single collective goes through the
:class:`repro.core.CollectiveRequest` entry point instead — see
``examples/quickstart.py``.
"""

from repro.core import (
    ChunkIds,
    all_gather,
    all_to_allv,
    replay_algorithm,
    synthesize_joint,
)
from repro.topology import mesh2d, tpu_v5e_pod


def main():
    # paper setup: 3x3 mesh; NPUs 0-2 run All-to-Allv (NPU 0 sends 2x),
    # NPUs 6-8 run All-Gather; NPUs 3-5 belong to no group. The two groups'
    # conditions draw from one ChunkIds.split() family, so ids can't collide
    # even though each builder gets its own allocator.
    topo = mesh2d(3, 3)
    v_ids, ag_ids = ChunkIds().split(2)
    v = all_to_allv([0, 1, 2], [[0, 2, 2], [1, 0, 1], [1, 1, 0]], ids=v_ids)
    ag = all_gather([6, 7, 8], ids=ag_ids, chunks_per_npu=2)
    alg = synthesize_joint(topo, [("a2av", v), ("allgather", ag)])
    alg.validate()
    used = {t.src for t in alg.transfers} | {t.dst for t in alg.transfers}
    outside = sorted(used - {0, 1, 2, 6, 7, 8})
    print("Fig 15 scenario on 3x3 mesh:")
    print(f"  makespan={alg.makespan}, transfers={alg.num_transfers}")
    print(f"  out-of-group NPUs carrying traffic: {outside}")
    util = replay_algorithm(alg).link_utilization()
    print(f"  links used: {len(util)}/{topo.num_links}")

    # same idea at pod scale: every row of an 8x8 pod slice runs its own
    # expert-parallel All-to-All (the MoE pattern), synthesized jointly
    pod = tpu_v5e_pod(8, 8)
    from repro.core import all_to_all

    groups = []
    for r, row_ids in enumerate(ChunkIds().split(8)):
        row = [r * 8 + c for c in range(8)]
        groups.append((f"ep_row{r}", all_to_all(row, ids=row_ids, bytes=1.0)))
    alg = synthesize_joint(pod, groups)
    alg.validate()
    print("\n8x8 pod, 8 concurrent EP All-to-All groups:")
    print(f"  makespan={alg.makespan:.1f} us, transfers={alg.num_transfers}")
    print(f"  links used: {len(alg.link_busy_time())}/{pod.num_links}")


if __name__ == "__main__":
    main()
