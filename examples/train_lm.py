"""End-to-end training driver: data pipeline -> sharded train step ->
async checkpointing -> straggler monitoring -> (simulated) failure recovery.

    PYTHONPATH=src python examples/train_lm.py                 # quick (~10M)
    PYTHONPATH=src python examples/train_lm.py --model 100m --steps 300

Data-parallel training over a multi-device mesh can route the gradient
all-reduce through a PCCL-synthesized, topology-aware ppermute schedule
instead of XLA's built-in psum:

    PYTHONPATH=src python examples/train_lm.py \
        --dp 8 --host-devices 8 --collectives pccl

``--compare-collectives`` runs the same steps through both implementations
from the same initialization and prints the max loss/param divergence
(`PCCL_CONFORMANCE ...` — asserted by the mesh conformance suite).

The ~100M configuration is the deliverable's "train a ~100M model for a few
hundred steps" driver; the default is a smaller config so the example runs in
seconds on one CPU. All machinery is the production path: ShardingPolicy,
remat, AdamW + cosine schedule, deterministic restartable data.
"""

import argparse
import os
import sys
import time

# --host-devices must take effect before jax initializes its backend, so
# peek at argv ahead of the jax import
_early = argparse.ArgumentParser(add_help=False)
_early.add_argument("--host-devices", type=int, default=0)
_hd = _early.parse_known_args(sys.argv[1:])[0].host_devices
if _hd:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_hd}"
    ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.checkpoint import Checkpointer  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.data.pipeline import DataPipeline  # noqa: E402
from repro.jaxcompat import make_mesh, shard_map_unchecked  # noqa: E402
from repro.launch.sharding import ShardingPolicy  # noqa: E402
from repro.models import LM  # noqa: E402
from repro.optim import adamw_init, adamw_update, cosine_schedule  # noqa: E402
from repro.runtime import StragglerMonitor  # noqa: E402
from repro.runtime.fault_tolerance import StepTimer  # noqa: E402

MODELS = {
    # tiny: mesh-conformance subprocess tests | ~10M: d=256, 4L
    # ~100M: d=768, 12L (GPT-2-small-ish)
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=256, vocab_size=512),
    "10m": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                head_dim=32, d_ff=1024, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32000),
}


def build_dp_step(lm, lr, mesh, dp: int, collectives: str):
    """Data-parallel train step: per-device loss/grads inside shard_map, a
    global gradient mean, then a replicated AdamW update.

    ``collectives="pccl"`` flattens the gradients (plus the loss scalar)
    into one vector and all-reduces it with a PCCL-synthesized ppermute
    schedule served by the PlanService — synthesized for a bidirectional
    ring fabric over the data axis, executed with the executor's static
    buffer plan. ``collectives="xla"`` is the lax.psum baseline.
    """
    program = None
    req = None
    topo = None
    if collectives == "pccl":
        from repro.core import CollectiveRequest
        from repro.core.planservice import PlanService
        from repro.topology import ring

        topo = ring(dp, bidirectional=True)
        svc = PlanService()
        program = svc.program(topo, {"data": dp}, "all_reduce", "data")
        req = CollectiveRequest("all_reduce", group=tuple(range(dp)))

    def mean_over_devices(vec):
        if collectives == "pccl":
            from repro.comms import pccl_all_reduce

            pad = (-vec.size) % dp
            if pad:
                vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
            vec = pccl_all_reduce(vec, "data", topo, req, program=program)
            if pad:
                vec = vec[:-pad]
        else:
            vec = lax.psum(vec, "data")
        return vec / dp

    def f(params, opt, local_batch):
        (loss, _metrics), grads = jax.value_and_grad(
            lm.loss, has_aux=True)(params, local_batch)
        flat, treedef = jax.tree_util.tree_flatten(grads)
        shapes = [g.shape for g in flat]
        sizes = [g.size for g in flat]
        vec = jnp.concatenate(
            [g.reshape(-1).astype(jnp.float32) for g in flat]
            + [loss.reshape(1).astype(jnp.float32)])
        vec = mean_over_devices(vec)
        loss_mean = vec[-1]
        vec = vec[:-1]
        out, off = [], 0
        for g, shp, size in zip(flat, shapes, sizes):
            out.append(vec[off:off + size].reshape(shp).astype(g.dtype))
            off += size
        grads = jax.tree_util.tree_unflatten(treedef, out)
        params, opt, om = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss_mean, om["grad_norm"]

    step = shard_map_unchecked(f, mesh=mesh,
                               in_specs=(P(), P(), P("data")),
                               out_specs=(P(), P(), P(), P()))
    return jax.jit(step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="10m", choices=sorted(MODELS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel devices (needs that many jax "
                    "devices; see --host-devices)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host-CPU devices (sets XLA_FLAGS before "
                    "jax initializes)")
    ap.add_argument("--collectives", default="xla", choices=("xla", "pccl"),
                    help="gradient all-reduce implementation when --dp > 1")
    ap.add_argument("--compare-collectives", action="store_true",
                    help="run every step through both xla and pccl "
                    "collectives and report the max divergence")
    args = ap.parse_args()

    cfg = get_config("llama3.2-1b").reduced(**MODELS[args.model])
    print(f"model: {cfg.name} reduced -> {cfg.param_count()/1e6:.1f}M params")

    dp = max(args.dp, 1)
    if dp > 1:
        if args.batch % dp:
            raise SystemExit(f"--batch {args.batch} not divisible by "
                             f"--dp {dp}")
        if jax.device_count() < dp:
            raise SystemExit(f"--dp {dp} needs {dp} jax devices (have "
                             f"{jax.device_count()}); pass --host-devices")
        mesh = make_mesh((dp,), ("data",))
        lm = LM(cfg, remat=True)  # no TP constraints inside shard_map
    else:
        mesh = make_mesh((1, 1), ("data", "model"))
        policy = ShardingPolicy(mesh, cfg)
        lm = LM(cfg, policy=policy, remat=True)

    params = lm.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    lr = cosine_schedule(3e-4, warmup=20, total=max(args.steps, 100))

    if dp > 1:
        train_step = build_dp_step(lm, lr, mesh, dp, args.collectives)
    else:
        @jax.jit
        def train_step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lm.loss, has_aux=True)(params, batch)
            params, opt, om = adamw_update(params, grads, opt, lr=lr)
            return params, opt, loss, om["grad_norm"]

    if args.compare_collectives:
        if dp <= 1:
            raise SystemExit("--compare-collectives needs --dp > 1")
        step_xla = build_dp_step(lm, lr, mesh, dp, "xla")
        step_pccl = build_dp_step(lm, lr, mesh, dp, "pccl")
        pipe = DataPipeline(seed=1234, batch=args.batch, seq=args.seq,
                            vocab=cfg.vocab_size, start_step=0)
        px, ox = params, opt
        pp, op_ = params, opt
        max_loss_diff = 0.0
        for _ in range(args.steps):
            _, batch = next(pipe)
            px, ox, lx, _ = step_xla(px, ox, batch)
            pp, op_, lp, _ = step_pccl(pp, op_, batch)
            d = abs(float(lx) - float(lp))
            max_loss_diff = max(max_loss_diff, d)
            print(f"step loss xla={float(lx):.6f} pccl={float(lp):.6f} "
                  f"diff={d:.3e}")
        pipe.close()
        lx_leaves = jax.tree_util.tree_leaves(px)
        lp_leaves = jax.tree_util.tree_leaves(pp)
        max_param_diff = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(lx_leaves, lp_leaves))
        print(f"PCCL_CONFORMANCE max_loss_diff={max_loss_diff:.3e} "
              f"max_param_diff={max_param_diff:.3e}")
        return

    ck = Checkpointer(args.ckpt_dir, keep=2)
    start_step = 0
    if args.resume and ck.latest_step() is not None:
        start_step, restored = ck.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start_step}")

    pipe = DataPipeline(seed=1234, batch=args.batch, seq=args.seq,
                        vocab=cfg.vocab_size, start_step=start_step)
    monitor = StragglerMonitor()

    t_start = time.time()
    for _ in range(start_step, args.steps):
        step, batch = next(pipe)
        with StepTimer(monitor) as timer:
            params, opt, loss, gnorm = train_step(params, opt, batch)
            loss.block_until_ready()
        if timer.verdict != "ok":
            print(f"  [straggler] step {step} verdict={timer.verdict}")
        if step % 5 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / max(monitor.median, 1e-9)
            print(f"step {step:4d}  loss={float(loss):.4f}  "
                  f"gnorm={float(gnorm):.3f}  ~{tok_s:,.0f} tok/s")
        if step and step % args.ckpt_every == 0:
            ck.save(step, {"params": params, "opt": opt})
    ck.wait()
    pipe.close()
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
