"""End-to-end training driver: data pipeline -> sharded train step ->
async checkpointing -> straggler monitoring -> (simulated) failure recovery.

    PYTHONPATH=src python examples/train_lm.py                 # quick (~10M)
    PYTHONPATH=src python examples/train_lm.py --model 100m --steps 300

The ~100M configuration is the deliverable's "train a ~100M model for a few
hundred steps" driver; the default is a smaller config so the example runs in
seconds on one CPU. All machinery is the production path: ShardingPolicy,
remat, AdamW + cosine schedule, deterministic restartable data.
"""

import argparse
import time

import jax

from repro.checkpoint import Checkpointer
from repro.jaxcompat import make_mesh
from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.launch.sharding import ShardingPolicy
from repro.models import LM
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.runtime import StragglerMonitor
from repro.runtime.fault_tolerance import StepTimer

MODELS = {
    # ~10M: d=256, 4L  |  ~100M: d=768, 12L (GPT-2-small-ish)
    "10m": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                head_dim=32, d_ff=1024, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="10m", choices=sorted(MODELS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("llama3.2-1b").reduced(**MODELS[args.model])
    print(f"model: {cfg.name} reduced -> {cfg.param_count()/1e6:.1f}M params")

    mesh = make_mesh((1, 1), ("data", "model"))
    policy = ShardingPolicy(mesh, cfg)
    lm = LM(cfg, policy=policy, remat=True)

    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    lr = cosine_schedule(3e-4, warmup=20, total=max(args.steps, 100))

    @jax.jit
    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(
            params, batch)
        params, opt, om = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss, om["grad_norm"]

    ck = Checkpointer(args.ckpt_dir, keep=2)
    start_step = 0
    if args.resume and ck.latest_step() is not None:
        start_step, restored = ck.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start_step}")

    pipe = DataPipeline(seed=1234, batch=args.batch, seq=args.seq,
                        vocab=cfg.vocab_size, start_step=start_step)
    monitor = StragglerMonitor()

    t_start = time.time()
    for _ in range(start_step, args.steps):
        step, batch = next(pipe)
        with StepTimer(monitor) as timer:
            params, opt, loss, gnorm = train_step(params, opt, batch)
            loss.block_until_ready()
        if timer.verdict != "ok":
            print(f"  [straggler] step {step} verdict={timer.verdict}")
        if step % 5 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / max(monitor.median, 1e-9)
            print(f"step {step:4d}  loss={float(loss):.4f}  "
                  f"gnorm={float(gnorm):.3f}  ~{tok_s:,.0f} tok/s")
        if step and step % args.ckpt_every == 0:
            ck.save(step, {"params": params, "opt": opt})
    ck.wait()
    pipe.close()
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
