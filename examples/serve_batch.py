"""Batched serving driver: prefill a batch of prompts, then decode with the
KV cache via the production serve path.

    PYTHONPATH=src python examples/serve_batch.py --batch 4 --new-tokens 16
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    print(f"serving reduced {args.arch}: {cfg.param_count()/1e6:.1f}M params")

    rng = jax.random.PRNGKey(7)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    max_seq = args.prompt_len + args.new_tokens
    cache = lm.decode_init(args.batch, max_seq, dtype=jnp.float32)
    step = jax.jit(lm.decode_step)

    # prefill by stepping the decoder over the prompt (cache fills as we go)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t], jnp.asarray(t))
    print(f"prefill: {args.prompt_len} steps x {args.batch} seqs "
          f"in {time.time()-t0:.2f}s")

    # greedy decode
    t0 = time.time()
    tokens = jnp.argmax(logits, axis=-1)
    generated = [tokens]
    for t in range(args.prompt_len, max_seq - 1):
        logits, cache = step(params, cache, tokens, jnp.asarray(t))
        tokens = jnp.argmax(logits, axis=-1)
        generated.append(tokens)
    out = jnp.stack(generated, axis=1)
    dt = time.time() - t0
    total = args.batch * out.shape[1]
    print(f"decode: {out.shape[1]} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({total/dt:,.0f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq {b}: {out[b, :10].tolist()} ...")


if __name__ == "__main__":
    main()
