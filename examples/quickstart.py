"""Quickstart: synthesize a topology-aware, process-group-aware collective.

    PYTHONPATH=src python examples/quickstart.py

Builds a 4x4 mesh, synthesizes an All-Gather for a 3-NPU process group and
an All-to-All for the whole mesh through the :class:`CollectiveRequest`
API, validates both, compares against the Direct baseline, prints the
ppermute translation, *executes* the process-group All-Gather on a real
16-device jax mesh, and finishes with a fault drill: a link dies and the
plan is repaired incrementally instead of re-synthesized from scratch.
"""

import os

# the execution demo wants one (host CPU) jax device per NPU of the 4x4
# mesh; must be set before jax initializes its backend
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=16")

from repro.core import (  # noqa: E402
    AlgorithmRegistry,
    CollectiveRequest,
    DegradationEvent,
    PlanRepairer,
    SynthesisEngine,
    direct_all_to_all,
    to_msccl_json,
    to_ppermute_program,
)
from repro.topology import mesh2d, multi_pod  # noqa: E402


def main():
    topo = mesh2d(4, 4)
    eng = SynthesisEngine(topo)
    print(f"topology: {topo}")

    # --- process-group All-Gather: corners only ---
    # one request object carries the whole collective spec (kind, group,
    # payload, chunking, routing) — the same value keys the plan registry
    req = CollectiveRequest("all_gather", group=(0, 3, 12))
    alg = eng.collective(req)
    alg.validate()
    used = {t.src for t in alg.transfers} | {t.dst for t in alg.transfers}
    print(f"\nAll-Gather over process group {list(req.group)}:")
    print(f"  makespan={alg.makespan} steps, transfers={alg.num_transfers}")
    print(f"  NPUs touched: {sorted(used)} (out-of-group forwarding: "
          f"{sorted(used - set(req.group))})")
    for t in alg.transfers[:6]:
        print(f"    t={t.start:>4}: chunk {t.chunk} {t.src} -> {t.dst}")

    # --- whole-mesh All-to-All vs Direct ---
    full = tuple(range(16))
    a2a = eng.collective(CollectiveRequest("all_to_all", group=full))
    a2a.validate()
    direct = direct_all_to_all(topo, list(full))
    print("\nAll-to-All over all 16 NPUs:")
    print(f"  PCCL makespan   = {a2a.makespan}")
    print(f"  Direct makespan = {direct.makespan}")
    print(f"  speedup         = {direct.makespan / a2a.makespan:.2f}x")

    # --- translations ---
    prog = to_ppermute_program(a2a)
    print(f"\nppermute program: {prog.num_rounds} rounds "
          f"({sum(len(r) for r in prog.rounds)} sends)")
    print("first round:", [(s.src, s.dst) for s in prog.rounds[0]][:8], "...")
    ir = to_msccl_json(alg)
    print(f"\nMSCCL-IR export: {len(ir)} bytes of JSON (alg 'pccl_all_gather')")

    # --- execute the process-group All-Gather on a real jax mesh ---
    # the same request lowers to shard_map ppermute rounds; out-of-group
    # NPUs forward chunks in transit but return zeros
    import numpy as np
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.comms import pccl_all_gather
    from repro.jaxcompat import make_mesh, shard_map

    n = len(topo.npus)
    if jax.device_count() >= n:
        jmesh = make_mesh((n,), ("x",))
        x = (np.arange(n, dtype=np.float32) + 1.0)[:, None]  # NPU d holds d+1

        def run_ag(xl):
            return pccl_all_gather(xl[0], "x", topo, req)[None]

        step = jax.jit(shard_map(run_ag, mesh=jmesh,
                                 in_specs=P("x"), out_specs=P("x")))
        out = np.asarray(step(x))  # [n, group_size, 1]
        m = req.group[0]
        print(f"\nexecuted on {n} jax devices: NPU {m} gathered "
              f"{out[m, :, 0].tolist()} (group {list(req.group)}), "
              f"non-member NPU 1 got {out[1, :, 0].tolist()}")
    else:
        print(f"\n(skipping mesh execution: {jax.device_count()} jax "
              f"devices < {n})")

    # --- degraded-fabric repair ---
    # plan a pod-spanning All-Gather with phase capture, kill one
    # pod-internal link, and patch only the damaged pod's phases; the
    # undamaged pods' schedules survive verbatim
    pods = multi_pod(4, 4, 4, unit_links=True)
    rp = PlanRepairer(pods, registry=AlgorithmRegistry(), pipeline=False)
    preq = CollectiveRequest("all_gather", group=tuple(pods.npus))
    rp.plan(preq)
    victim = next(
        l.id for l in pods.links
        if l.id not in {b.id for b in pods.boundary_links()})
    res = rp.repair(preq, DegradationEvent(failed_links=[victim]))
    res.algorithm.validate()
    print(f"\nlink {victim} died on {pods.name}: strategy={res.strategy}, "
          f"{res.phases_kept} phases kept verbatim, "
          f"{res.phases_resynthesized} re-synthesized")


if __name__ == "__main__":
    main()
