"""Quickstart: synthesize a topology-aware, process-group-aware collective.

    PYTHONPATH=src python examples/quickstart.py

Builds a 4x4 mesh, synthesizes an All-Gather for a 3-NPU process group and an
All-to-All for the whole mesh, validates both, compares against the Direct
baseline, and prints the ppermute translation.
"""

from repro.core import (
    direct_all_to_all,
    synthesize_all_gather,
    synthesize_all_to_all,
    to_msccl_json,
    to_ppermute_program,
)
from repro.topology import mesh2d


def main():
    topo = mesh2d(4, 4)
    print(f"topology: {topo}")

    # --- process-group All-Gather: corners only ---
    group = [0, 3, 12]
    alg = synthesize_all_gather(topo, group)
    alg.validate()
    used = {t.src for t in alg.transfers} | {t.dst for t in alg.transfers}
    print(f"\nAll-Gather over process group {group}:")
    print(f"  makespan={alg.makespan} steps, transfers={alg.num_transfers}")
    print(f"  NPUs touched: {sorted(used)} (out-of-group forwarding: "
          f"{sorted(used - set(group))})")
    for t in alg.transfers[:6]:
        print(f"    t={t.start:>4}: chunk {t.chunk} {t.src} -> {t.dst}")

    # --- whole-mesh All-to-All vs Direct ---
    full = list(range(16))
    a2a = synthesize_all_to_all(topo, full)
    a2a.validate()
    direct = direct_all_to_all(topo, full)
    print("\nAll-to-All over all 16 NPUs:")
    print(f"  PCCL makespan   = {a2a.makespan}")
    print(f"  Direct makespan = {direct.makespan}")
    print(f"  speedup         = {direct.makespan / a2a.makespan:.2f}x")

    # --- translations ---
    prog = to_ppermute_program(a2a)
    print(f"\nppermute program: {prog.num_rounds} rounds "
          f"({sum(len(r) for r in prog.rounds)} sends)")
    print("first round:", [(s.src, s.dst) for s in prog.rounds[0]][:8], "...")
    ir = to_msccl_json(alg)
    print(f"\nMSCCL-IR export: {len(ir)} bytes of JSON (alg 'pccl_all_gather')")


if __name__ == "__main__":
    main()
