"""Paper Fig. 16/17: process-group-aware A2A. Concurrent row-sized process
groups on a 2D mesh, jointly synthesized by PCCL vs localized Direct.
Paper reports 2.33-3.03x (mean 2.68x) and the Fig. 17 link-utilization gap:
Direct never touches links outside the group's shortest paths."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import (
    ChunkIds,
    Flow,
    all_to_all,
    shortest_path_links,
    simulate_flows,
    synthesize_joint,
)
from repro.topology import mesh2d


def _direct_joint(topo, groups):
    """Direct baseline for several concurrent A2A process groups: every
    pairwise chunk rides its shortest path; all groups share the network."""
    ids = ChunkIds()
    flows = []
    for group in groups:
        for c in all_to_all(group, ids=ids):
            flows.append(Flow(c.chunk, c.bytes,
                              shortest_path_links(topo, c.src,
                                                  next(iter(c.dests)))))
    return simulate_flows(topo, flows)


def run(full: bool = False) -> list[Row]:
    rows = []
    sides = [4, 6] + ([8] if full else [])
    for side in sides:
        topo = mesh2d(side, side)
        groups = [[r * side + c for c in range(side)] for r in range(side)]
        ids = ChunkIds()
        named = [(f"row{r}", all_to_all(g, ids=ids))
                 for r, g in enumerate(groups)]
        alg, us = timed(synthesize_joint, topo, named)
        alg.validate()
        direct = _direct_joint(topo, groups)
        speedup = direct.makespan / alg.makespan
        # Fig 17 analogue: fraction of physical links each algorithm touches
        pccl_links = len({t.link for t in alg.transfers})
        direct_links = len({t.link for t in direct.transfers})
        rows.append(Row(
            f"fig16_pg_rows_mesh{side}x{side}", us,
            f"groups={side};speedup={speedup:.2f};"
            f"pccl_links={pccl_links}/{topo.num_links};"
            f"direct_links={direct_links}/{topo.num_links}"))
    return rows
