"""Paper Fig. 12: synthesis time vs collective size (chunks per NPU pair) on
a fixed mesh — scaling in the *collective* dimension rather than topology."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import synthesize_all_to_all
from repro.topology import mesh2d
from repro.topology.generators import grid_hypercube


def run(full: bool = False) -> list[Row]:
    rows = []
    side = 8 if full else 4
    topo = mesh2d(side, side)
    n = side * side
    chunk_counts = [1, 2, 4] + ([8, 16] if full else [])
    for chunks in chunk_counts:
        alg, us = timed(synthesize_all_to_all, topo, list(range(n)),
                        chunks_per_pair=chunks)
        alg.validate()
        rows.append(Row(
            f"fig12_chunks_mesh{side}x{side}_c{chunks}", us,
            f"npus={n};chunks_per_pair={chunks};makespan={alg.makespan}"))
    cube = grid_hypercube(4 if full else 2, 3)
    nn = len(cube.npus)
    for chunks in chunk_counts:
        # flat-path scaling row (hierarchical rows live in fig_hier_*)
        alg, us = timed(synthesize_all_to_all, cube, list(range(nn)),
                        chunks_per_pair=chunks, hierarchy="never")
        alg.validate()
        rows.append(Row(
            f"fig12_chunks_cube_{nn}_c{chunks}", us,
            f"npus={nn};chunks_per_pair={chunks};makespan={alg.makespan}"))
    return rows
