"""Paper Fig. 14: normalized whole-cluster All-to-All bandwidth, PCCL vs the
Direct baseline, as the 2D mesh grows. (TE-CCL comparison is quoted from the
paper — optimizer-based synthesis is out of scope of this repo.)"""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import direct_all_to_all, synthesize_all_to_all
from repro.topology import mesh2d


def run(full: bool = False) -> list[Row]:
    rows = []
    sides = [3, 4, 5, 6] + ([7, 8] if full else [])
    for side in sides:
        topo = mesh2d(side, side)
        n = side * side
        group = list(range(n))
        alg, us = timed(synthesize_all_to_all, topo, group)
        alg.validate()
        direct = direct_all_to_all(topo, group)
        # normalized algorithmic bandwidth = payload / time, direct == 1.0
        rel_bw = direct.makespan / alg.makespan
        rows.append(Row(
            f"fig14_a2a_bw_mesh{side}x{side}", us,
            f"npus={n};pccl_rel_bw={rel_bw:.2f};pccl_t={alg.makespan};"
            f"direct_t={direct.makespan}"))
    return rows
