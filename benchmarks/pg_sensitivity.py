"""Paper Fig. 19: sensitivity to the number of concurrent process groups.
Fixed mesh, increasing count of size-8 A2A groups: with one group PCCL can
borrow the whole idle network (paper: 3.05x); as groups contend, the
advantage narrows."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import ChunkIds, all_to_all, synthesize_joint
from repro.topology import mesh2d

from benchmarks.process_group import _direct_joint


def run(full: bool = False) -> list[Row]:
    rows = []
    side = 8 if full else 6
    topo = mesh2d(side, side)
    pg = 8 if full else 6
    max_groups = (side * side) // pg
    counts = [1, 2, max_groups // 2, max_groups]
    counts = sorted({c for c in counts if c >= 1})
    for g in counts:
        groups = [list(range(i * pg, (i + 1) * pg)) for i in range(g)]
        ids = ChunkIds()
        named = [(f"pg{i}", all_to_all(grp, ids=ids))
                 for i, grp in enumerate(groups)]
        alg, us = timed(synthesize_joint, topo, named)
        alg.validate()
        direct = _direct_joint(topo, groups)
        speedup = direct.makespan / alg.makespan
        rows.append(Row(
            f"fig19_ngroups_mesh{side}x{side}_g{g}", us,
            f"groups={g};pg_size={pg};speedup={speedup:.2f};"
            f"pccl_t={alg.makespan};direct_t={direct.makespan}"))
    return rows
