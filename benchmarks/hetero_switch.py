"""Paper Fig. 13: All-to-All on the heterogeneous 2D switch topology
(8-NPU nodes with fast local switches joined by a slower spine), PCCL vs the
Direct baseline. Paper reports 1.33x mean speedup.

Also the traffic-engineering rows (``fig_te_*``): hierarchical All-Gather and
All-to-All on multi-pod fabrics whose DCI uplinks have asymmetric bandwidth
(one healthy 100G port plus three degraded 10G ports per pod), comparing the
makespan-aware gateway assignment (``gateway_strategy="te"``) against the
legacy round-robin spread. On a uniform fabric the two tie; under skew,
round-robin keeps pushing an equal chunk share through the slow ports while
TE balances modeled link busy-time, so TE's win measures exactly the
traffic-engineering contribution."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import (
    AlgorithmRegistry,
    SynthesisEngine,
    direct_all_to_all,
    synthesize_all_to_all,
)
from repro.topology import multi_pod, two_level_switch

# one healthy 100G uplink + three degraded 10G ports per pod: the skew is
# large enough that the boundary dominates makespan, which is the regime the
# TE assignment targets
_TE_DCI_GBPS = [100.0, 10.0, 10.0, 10.0]


def _te_rows(full: bool) -> list[Row]:
    rows = []
    pod_counts = [4, 8] + ([12] if full else [])
    for pods in pod_counts:
        topo = multi_pod(num_pods=pods, rows=2, cols=4,
                         dci_port_gbps=_TE_DCI_GBPS)
        n = len(topo.npus)
        for kind in ("all_gather", "all_to_all"):
            spans = {}
            us = 0.0
            for strategy in ("round_robin", "te"):
                engine = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                                         gateway_strategy=strategy)
                alg, t = timed(getattr(engine, kind), topo.npus, bytes=4.0)
                alg.validate(mode="bulk")
                spans[strategy] = alg.makespan
                if strategy == "te":
                    us = t
            speedup = (spans["round_robin"] / spans["te"]
                       if spans["te"] else 0.0)
            tag = "ag" if kind == "all_gather" else "a2a"
            rows.append(Row(
                f"fig_te_{tag}_{pods}pods", us,
                f"npus={n};pods={pods};makespan={spans['te']:.1f};"
                f"rr_t={spans['round_robin']:.1f};speedup={speedup:.2f}"))
    return rows


def run(full: bool = False) -> list[Row]:
    rows = []
    node_counts = [2, 4] + ([8, 16, 32] if full else [])
    for nodes in node_counts:
        topo = two_level_switch(nodes, npus_per_node=8)
        n = nodes * 8
        group = list(range(n))
        alg, us = timed(synthesize_all_to_all, topo, group, bytes=128.0)
        alg.validate()
        direct = direct_all_to_all(topo, group, bytes=128.0)
        speedup = direct.makespan / alg.makespan if alg.makespan else 0.0
        rows.append(Row(
            f"fig13_switch2d_{n}npu", us,
            f"npus={n};pccl_t={alg.makespan:.1f};direct_t={direct.makespan:.1f};"
            f"speedup={speedup:.2f}"))
    rows.extend(_te_rows(full))
    return rows
