"""Paper Fig. 13: All-to-All on the heterogeneous 2D switch topology
(8-NPU nodes with fast local switches joined by a slower spine), PCCL vs the
Direct baseline. Paper reports 1.33x mean speedup."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import direct_all_to_all, synthesize_all_to_all
from repro.topology import two_level_switch


def run(full: bool = False) -> list[Row]:
    rows = []
    node_counts = [2, 4] + ([8, 16, 32] if full else [])
    for nodes in node_counts:
        topo = two_level_switch(nodes, npus_per_node=8)
        n = nodes * 8
        group = list(range(n))
        alg, us = timed(synthesize_all_to_all, topo, group, bytes=128.0)
        alg.validate()
        direct = direct_all_to_all(topo, group, bytes=128.0)
        speedup = direct.makespan / alg.makespan if alg.makespan else 0.0
        rows.append(Row(
            f"fig13_switch2d_{n}npu", us,
            f"npus={n};pccl_t={alg.makespan:.1f};direct_t={direct.makespan:.1f};"
            f"speedup={speedup:.2f}"))
    return rows
