"""Benchmark harness: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run            # quick set
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
    PYTHONPATH=src python -m benchmarks.run --only fig16

Prints ``name,us_per_call,derived`` CSV. `us_per_call` is synthesis wall time
where the benchmark synthesizes; derived carries the figure's metric
(speedups, makespans, roofline terms, ...).
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow: up to 16x16 meshes)")
    ap.add_argument("--only", default=None, help="substring filter on module")
    args = ap.parse_args()

    from benchmarks import (
        alltoall_bw,
        hetero_switch,
        pg_sensitivity,
        process_group,
        registry_amortization,
        roofline,
        synthesis_chunks,
        synthesis_scale,
        utilization,
    )

    modules = [
        ("fig11", synthesis_scale),
        ("fig12", synthesis_chunks),
        ("fig13", hetero_switch),
        ("fig14", alltoall_bw),
        ("fig16", process_group),
        ("fig18", utilization),
        ("fig19", pg_sensitivity),
        ("registry", registry_amortization),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    for tag, mod in modules:
        if args.only and args.only not in tag and args.only not in mod.__name__:
            continue
        try:
            for row in mod.run(full=args.full):
                print(row.csv())
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{tag}_FAILED,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
