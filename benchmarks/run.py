"""Benchmark harness: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run            # quick set
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
    PYTHONPATH=src python -m benchmarks.run --only fig16

Prints ``name,us_per_call,derived`` CSV. `us_per_call` is synthesis wall time
where the benchmark synthesizes; derived carries the figure's metric
(speedups, makespans, roofline terms, ...).

Every run also writes ``BENCH_synthesis.json`` at the repo root (one record
per row: name, us, meta) so the performance trajectory is tracked across
PRs; rows from a filtered run (``--only``) are merged over the previous
file's rows instead of replacing them.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_synthesis.json")


def write_bench_json(rows: list, full: bool, merge: bool) -> str:
    """Persist rows as [{name, us, meta}, ...] at the repo root."""
    path = os.path.abspath(_BENCH_JSON)
    records = {}
    if merge and os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                records = {r["name"]: r for r in json.load(f)["rows"]}
        except (OSError, ValueError, KeyError):
            records = {}
    for row in rows:
        records[row.name] = {"name": row.name, "us": row.us_per_call,
                             "meta": row.derived}
    doc = {"suite": "pccl-repro", "full": full,
           "rows": sorted(records.values(), key=lambda r: r["name"])}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow: up to 16x16 meshes)")
    ap.add_argument("--only", default=None, help="substring filter on module")
    args = ap.parse_args()

    from benchmarks import (
        alltoall_bw,
        exec_mesh,
        hetero_switch,
        hierarchical,
        pg_sensitivity,
        plan_store,
        process_group,
        registry_amortization,
        repair,
        roofline,
        synthesis_chunks,
        synthesis_scale,
        utilization,
    )

    modules = [
        ("fig11", synthesis_scale),
        ("fig12", synthesis_chunks),
        ("fig13", hetero_switch),
        ("fig14", alltoall_bw),
        ("fig16", process_group),
        ("fig18", utilization),
        ("fig19", pg_sensitivity),
        ("fig_exec", exec_mesh),
        ("fig_hier", hierarchical),
        ("fig_plan", plan_store),
        ("fig_repair", repair),
        ("registry", registry_amortization),
        ("roofline", roofline),
    ]
    all_rows = []
    print("name,us_per_call,derived")
    for tag, mod in modules:
        if args.only and args.only not in tag and args.only not in mod.__name__:
            continue
        try:
            for row in mod.run(full=args.full):
                all_rows.append(row)
                print(row.csv())
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{tag}_FAILED,0,{type(e).__name__}: {e}")
    path = write_bench_json(all_rows, args.full, merge=args.only is not None)
    print(f"# wrote {len(all_rows)} rows -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
