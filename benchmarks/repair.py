"""Fault-aware incremental plan repair vs cold degraded-fabric resynthesis.

Scenario (deterministic): a three-level fabric loses its first rack-internal
non-boundary link inside pod 0, under a whole-fabric All-Gather planned in
the sequential (phase-repairable) regime. The repair path re-synthesizes
only the damaged pod's phase — every undamaged pod registry-hits the plans
cached at plan() time — while the cold path synthesizes the collective from
scratch on a fresh degraded view with a fresh registry.

Both sides are timed without inline validation (``validate=None`` mirrors
the cold production path, which never validates inline); validity and
condition-equivalence against the cold plan are asserted untimed and
reported as the ``valid`` field, which the bench gate requires to stay 1.0.
``repair_speedup`` is wall-clock-derived and therefore report-only; the
quick row's presence is enforced via ``REQUIRED_ROW_PREFIXES``.
"""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import (
    AlgorithmRegistry,
    CollectiveRequest,
    DegradationEvent,
    PlanRepairer,
    SynthesisEngine,
)
from repro.topology import three_level


def _first_internal_link(topo, pod: int) -> int:
    members = set(topo.pods()[pod])
    boundary = {l.id for l in topo.boundary_links()}
    for l in topo.links:
        if l.id not in boundary and l.src in members and l.dst in members:
            return l.id
    raise RuntimeError(f"pod {pod} has no internal link")


def _delivery(alg):
    return sorted(
        (c.chunk, tuple(sorted(getattr(c, "srcs", [getattr(c, "src", -1)]))),
         tuple(sorted(c.dests)))
        for c in alg.conditions)


def _scenario(pods: int, racks: int, k: int) -> Row:
    n = pods * racks * k
    topo = three_level(pods, racks, k, unit_links=True)
    req = CollectiveRequest("all_gather", group=tuple(topo.npus))
    event = DegradationEvent(failed_links=[_first_internal_link(topo, 0)])

    # incremental: plan (untimed, warms the per-phase registry), then the
    # FIRST repair from that state — later repairs would registry-hit the
    # degraded entries and flatter the number
    rp = PlanRepairer(topo, registry=AlgorithmRegistry(), pipeline=False)
    rp.plan(req)
    res, repair_us = timed(rp.repair, req, event, validate=None)

    # cold: a fresh topology object (fresh degraded-view memo — the view
    # build is inside neither timing) and a fresh registry
    cold_topo = three_level(pods, racks, k, unit_links=True)
    dtopo = cold_topo.degraded(event.failed_links,
                               event.failed_npus).topology
    ceng = SynthesisEngine(dtopo, registry=AlgorithmRegistry())
    cold, cold_us = timed(ceng.collective, req)

    # correctness, untimed: both validate, identical per-chunk conditions
    res.algorithm.validate()
    cold.validate()
    valid = 1.0 if _delivery(res.algorithm) == _delivery(cold) else 0.0

    return Row(
        f"fig_repair_{n}", repair_us,
        f"npus={n};pods={pods};makespan={res.algorithm.makespan};"
        f"transfers={res.algorithm.num_transfers};strategy={res.strategy};"
        f"kept={res.phases_kept};resynth={res.phases_resynthesized};"
        f"cold_makespan={cold.makespan};cold_us={cold_us:.0f};"
        f"repair_us={repair_us:.0f};"
        f"repair_speedup={cold_us / repair_us:.2f};valid={valid}")


def run(full: bool = False) -> list[Row]:
    rows = [_scenario(4, 4, 4)]  # 64 NPUs: the gated quick row
    if full:
        # the paper-scale headline: single-link repair on a 512-NPU
        # three-level All-Gather, >= 5x over cold resynthesis
        rows.append(_scenario(8, 8, 8))
    return rows
