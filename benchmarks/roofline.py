"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_link_bw_per_chip

(post-SPMD HLO shapes are per-device, so no further division by chip count —
verified empirically in launch/dryrun.py development.) FLOPs/bytes come from
the loop-aware hierarchical analyzer (launch/hlo_cost.py); XLA's flat
cost_analysis undercounts scan-over-layers bodies by their trip count.

MODEL_FLOPS uses the standard analytic estimate over the step's tokens:
train: 6*N*D, prefill: 2*N*D, decode: 2*N*B tokens (N = active params for
MoE). The MODEL/HLO ratio surfaces remat/padding/masking overheads.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Row
from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12  # TPU v5e bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip budget used by the assignment)

MESH_CHIPS = {"pod": 256, "multipod": 512}


def analytic_memory_bytes(arch: str, shape_name: str, chips: int) -> float:
    """HBM-traffic floor per device per step, assuming TPU-grade fusion and
    VMEM-resident attention tiles (which the Pallas kernels provide; the
    CPU-targeted HLO byte count is an upper bound that includes tile traffic
    a TPU keeps on-chip).

    train:   3 passes over activations (fwd, bwd, remat) + params read +
             grads written + AdamW state read/write (16 B/param f32)
    prefill: 1 activation pass + params read
    decode:  params read + KV/SSM state read per token (+ write)
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count()
    p_bytes = 4.0 * n  # fp32 master params
    tokens_local = shape.global_batch * shape.seq_len / chips
    # ~8 activation tensors of width d per layer touched per token
    layer_traffic = 8 * 2.0 * cfg.d_model  # bf16
    depth = cfg.num_layers + (cfg.encoder_layers or 0)
    act = tokens_local * layer_traffic * depth
    if shape.kind == "train":
        return 3.0 * act + (p_bytes + 4.0 * n + 16.0 * n) / chips
    if shape.kind == "prefill":
        return act + p_bytes / chips
    # decode: one token per sequence; reads whole param shard + cache shard
    cache = (2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * 2.0 *
             cfg.num_layers * shape.global_batch / chips)
    if cfg.family == "ssm":
        cache = (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0 *
                 cfg.num_layers * shape.global_batch / chips)
    return p_bytes / chips + 2.0 * cache


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def analyze_cell(key: str, rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape_name, mesh_name = key.split("|")
    chips = MESH_CHIPS[mesh_name]
    flops = rec["flops"]
    nbytes = rec["bytes_accessed"]
    coll = sum(rec.get("collective_bytes", {}).values())
    t_compute = flops / PEAK_FLOPS
    t_memory_hlo = nbytes / HBM_BW  # upper bound (CPU-fusion granularity)
    t_memory = analytic_memory_bytes(arch, shape_name, chips) / HBM_BW
    t_collective = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops_per_device(arch, shape_name, chips)
    ratio = mf / flops if flops else 0.0
    # roofline fraction: useful compute time / dominant-term time
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "key": key, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_hlo_upper_s": t_memory_hlo,
        "t_collective_s": t_collective, "dominant": dominant,
        "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
        "model_over_hlo": ratio, "roofline_fraction": frac,
        "collective_bytes": rec.get("collective_bytes", {}),
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "fits_hbm": (rec["memory"]["temp_bytes"]
                     + rec["memory"]["argument_bytes"]) < 16 * 2**30,
    }


def improvement_hint(cell: dict) -> str:
    d = cell["dominant"]
    if d == "compute":
        if cell["model_over_hlo"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat/"
                    "masked-tile waste (Pallas causal tile skipping)")
        return "compute-bound near useful FLOPs: scale batch or accept"
    if d == "memory":
        return ("memory-bound: fuse elementwise chains, bf16 residuals, "
                "larger tiles to raise arithmetic intensity")
    return ("collective-bound: overlap collectives with compute, shrink "
            "gradient payload (compression), or reshard to cheaper axes")


def run(full: bool = False, path: str = "results/dryrun.json") -> list[Row]:
    if not os.path.exists(path):
        return [Row("roofline_missing_dryrun", 0.0,
                    f"run `python -m repro.launch.dryrun --out {path}` first")]
    with open(path) as f:
        results = json.load(f)
    rows = []
    cells = []
    for key in sorted(results):
        cell = analyze_cell(key, results[key])
        if cell is None:
            continue
        cells.append(cell)
        rows.append(Row(
            "roofline_" + key.replace("|", "_"), 0.0,
            f"compute_s={cell['t_compute_s']:.4g};"
            f"memory_s={cell['t_memory_s']:.4g};"
            f"collective_s={cell['t_collective_s']:.4g};"
            f"dominant={cell['dominant']};"
            f"model/hlo={cell['model_over_hlo']:.3f};"
            f"roofline_frac={cell['roofline_fraction']:.3f};"
            f"fits_hbm={cell['fits_hbm']};"
            f"hint={improvement_hint(cell)}"))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(cells, f, indent=1)
    return rows
