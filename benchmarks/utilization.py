"""Paper Fig. 18: network bandwidth utilization over time on an 8x8 mesh,
whole-cluster (PG=64) vs half-cluster (PG=32) All-to-All; the paper reports
PCCL finishing 1.88x faster than Direct for the PG=32 case."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import (
    direct_all_to_all,
    replay_algorithm,
    synthesize_all_to_all,
)
from repro.topology import mesh2d


def run(full: bool = False) -> list[Row]:
    rows = []
    side = 8 if full else 6
    topo = mesh2d(side, side)
    n = side * side
    for pg_size in (n, n // 2):
        group = list(range(pg_size))
        alg, us = timed(synthesize_all_to_all, topo, group)
        alg.validate()
        direct = direct_all_to_all(topo, group)
        speedup = direct.makespan / alg.makespan
        timeline = replay_algorithm(alg).busy_timeline(topo.num_links, bins=8)
        tl = "|".join(f"{x:.2f}" for x in timeline)
        rows.append(Row(
            f"fig18_util_mesh{side}x{side}_pg{pg_size}", us,
            f"speedup={speedup:.2f};pccl_t={alg.makespan};"
            f"direct_t={direct.makespan};busy_timeline={tl}"))
    return rows
