"""Registry amortization: cold synthesis vs cache-hit latency across all
data-parallel rows of a 2D torus mesh (the production scenario: every row of
a (data, model) mesh runs the same collective on an isomorphic process
group). Cold = first row, full TEN/BFS synthesis; hit = remaining rows,
served by automorphism relabeling from the AlgorithmRegistry."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AlgorithmRegistry, SynthesisEngine
from repro.topology.generators import torus2d


def _rows(side: int) -> list[list[int]]:
    return [[r * side + c for c in range(side)] for r in range(side)]


def run(full: bool = False) -> list[Row]:
    out: list[Row] = []
    sides = [4, 8] + ([16] if full else [])
    for side in sides:
        for kind in ("all_gather", "all_to_all"):
            topo = torus2d(side, side)
            registry = AlgorithmRegistry()
            engine = SynthesisEngine(topo, registry=registry)
            rows = _rows(side)
            synth = getattr(engine, kind)

            cold_alg, cold_us = timed(synth, rows[0])
            cold_alg.validate()

            hit_us_total = 0.0
            for row in rows[1:]:
                alg, us = timed(synth, row)
                hit_us_total += us
                assert alg.makespan == cold_alg.makespan
            hit_us = hit_us_total / max(len(rows) - 1, 1)
            speedup = cold_us / hit_us if hit_us else float("inf")
            stats = registry.stats
            out.append(Row(
                f"registry_{kind}_torus{side}x{side}",
                cold_us,
                f"rows={side};cold_us={cold_us:.1f};hit_us={hit_us:.1f};"
                f"speedup={speedup:.1f}x;hits={stats.hits};"
                f"misses={stats.misses};makespan={cold_alg.makespan}",
            ))
    return out
