"""Paper Fig. 11: All-to-All synthesis time vs topology size (2D Mesh and 3D
Hypercube). PCCL's headline scalability claim: tractable growth (O(n^3)),
512-NPU A2A in minutes — vs hours for optimizer-based synthesizers."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import synthesize_all_to_all
from repro.topology import mesh2d
from repro.topology.generators import grid_hypercube


def run(full: bool = False) -> list[Row]:
    rows = []
    mesh_sides = [3, 4, 5, 6, 8] + ([10, 12, 16] if full else [])
    for side in mesh_sides:
        topo = mesh2d(side, side)
        n = side * side
        alg, us = timed(synthesize_all_to_all, topo, list(range(n)))
        alg.validate()
        rows.append(Row(
            f"fig11_synthesis_mesh{side}x{side}", us,
            f"npus={n};makespan={alg.makespan};transfers={alg.num_transfers}"))
    cube_sides = [2, 3, 4] + ([5, 6, 8] if full else [])
    for side in cube_sides:
        topo = grid_hypercube(side, 3)
        n = side ** 3
        # fig11 tracks *flat* synthesis scaling; grid_hypercube fabrics are
        # partitioned now, so pin the flat path (fig_hier_* covers hierarchy)
        alg, us = timed(synthesize_all_to_all, topo, list(range(n)),
                        hierarchy="never")
        alg.validate()
        rows.append(Row(
            f"fig11_synthesis_cube{side}^3", us,
            f"npus={n};makespan={alg.makespan};transfers={alg.num_transfers}"))
    return rows
