"""fig_exec_*: execute synthesized plans on a real (forced-host) jax mesh.

Per case: synthesis + translation happen in-process (deterministic
``rounds``/``sends`` counts — gated), then one subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` executes every case
as a shard_map ppermute program, checks numerics against the pure-numpy
reference (``valid`` — gated), and times the jitted collective against the
XLA built-in (``wall_ms``/``lax_ms`` — wall clock, report-only; host-CPU
"bandwidth" says nothing about ICI, the value of the row is that executed
plans are *measured at all* plus proven conformant in the bench gate).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Row, timed

# tag -> (fabric, kind, request kwargs). ar_hier8 rides the chunk-pipelined
# hierarchical route; a2a_mp8 crosses the multi_pod DCI switch, so it only
# executes through the translator's switch unrolling.
CASES = [
    ("ag_ring8", "ring8", "all_gather", {"hierarchy": "never"}),
    ("rs_ring8", "ring8", "reduce_scatter", {"hierarchy": "never"}),
    ("ar_hier8", "grid23", "all_reduce",
     {"hierarchy": "always", "pipelined": True}),
    ("a2a_mp8", "mp222", "all_to_all", {"hierarchy": "always"}),
]

N = 8
PAYLOAD = 4096  # per-shard f32 elements


def _topo(name: str):
    from repro.topology import ring
    from repro.topology.generators import grid_hypercube, multi_pod

    return {
        "ring8": lambda: ring(8, bidirectional=True),
        "grid23": lambda: grid_hypercube(2, 3),
        "mp222": lambda: multi_pod(2, 2, 2, unit_links=True,
                                   dci_ports_per_pod=2),
    }[name]()


def _request(kind: str, kw: dict):
    from repro.core import CollectiveRequest

    return CollectiveRequest(kind, group=tuple(range(N)), **kw)


def _exec_worker() -> None:
    """Subprocess body: run every case on the forced host mesh, print one
    JSON dict tag -> {wall_ms, lax_ms, valid}."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N}").strip()
    import time

    import numpy as np

    import jax
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.comms import primitives
    from repro.jaxcompat import make_mesh, shard_map

    mesh = make_mesh((N,), ("x",))
    out: dict[str, dict] = {}
    for tag, fabric, kind, kw in CASES:
        topo = _topo(fabric)
        req = _request(kind, kw)
        fn = getattr(primitives, f"pccl_{kind}")
        rng = np.random.default_rng(42)
        if kind == "all_gather":
            x = rng.standard_normal((N, PAYLOAD)).astype(np.float32)
        elif kind == "all_reduce":
            x = rng.standard_normal((N, N * PAYLOAD)).astype(np.float32)
        else:
            x = rng.standard_normal((N, N, PAYLOAD)).astype(np.float32)

        def f(xl, _fn=fn, _topo=topo, _req=req):
            return _fn(xl[0], "x", _topo, _req)[None]

        def g(xl, _kind=kind):
            v = xl[0]
            if _kind == "all_gather":
                r = lax.all_gather(v, "x")
            elif _kind == "reduce_scatter":
                r = lax.psum_scatter(v, "x", scatter_dimension=0, tiled=False)
            elif _kind == "all_reduce":
                r = lax.psum(v, "x")
            else:
                r = lax.all_to_all(v[:, None], "x", split_axis=0,
                                   concat_axis=0)[:, 0]
            return r[None]

        mine = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"),
                                 out_specs=P("x")))
        ref = jax.jit(shard_map(g, mesh=mesh, in_specs=P("x"),
                                out_specs=P("x")))
        got = np.asarray(mine(x))
        want = np.asarray(ref(x))
        if kind in ("reduce_scatter", "all_reduce"):
            valid = int(np.allclose(got, want, rtol=1e-5, atol=1e-5))
        else:
            valid = int(np.array_equal(got, want))

        def _time(fjit, iters=5):
            fjit(x).block_until_ready()  # warmup/compile
            t0 = time.perf_counter()
            for _ in range(iters):
                fjit(x).block_until_ready()
            return (time.perf_counter() - t0) / iters * 1e3

        out[tag] = {"wall_ms": round(_time(mine), 3),
                    "lax_ms": round(_time(ref), 3),
                    "valid": valid}
    print(json.dumps(out))


def run(full: bool = False):
    from repro.core import SynthesisEngine
    from repro.core.translate import to_ppermute_program

    # deterministic lowering stats, in-process
    stats = {}
    for tag, fabric, kind, kw in CASES:
        topo = _topo(fabric)
        req = _request(kind, kw)
        alg, synth_us = timed(lambda t=topo, r=req:
                              SynthesisEngine(t).collective(r))
        prog = to_ppermute_program(alg)
        stats[tag] = (synth_us, prog.num_rounds, prog.num_sends)

    # execution wall clock + conformance, one forced-host-mesh subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.exec_mesh", "--exec-worker"],
        cwd=root, env=env, capture_output=True, text=True, timeout=900)
    execd: dict[str, dict] = {}
    if proc.returncode == 0:
        try:
            execd = json.loads(proc.stdout.strip().splitlines()[-1])
        except (ValueError, IndexError):
            execd = {}
    else:
        sys.stderr.write(proc.stderr)

    for tag, fabric, kind, kw in CASES:
        synth_us, rounds, sends = stats[tag]
        e = execd.get(tag, {"wall_ms": 0.0, "lax_ms": 0.0, "valid": 0})
        yield Row(
            f"fig_exec_{tag}", synth_us,
            f"npus={N};rounds={rounds};sends={sends};"
            f"wall_ms={e['wall_ms']};lax_ms={e['lax_ms']};"
            f"valid={e['valid']}")


if __name__ == "__main__":
    if "--exec-worker" in sys.argv:
        _exec_worker()
    else:
        for row in run():
            print(row.csv())
