"""Hierarchical synthesis benchmarks (fig_hier_*): the ISSUE-3/4/5 scale
gate.

Four row families:

* ``fig_hier_{ag,a2a,rs,ar}_<n>`` — cold hierarchical synthesis + full
  validation on multi-pod fabrics (the ≥1024-NPU rows are the headline:
  flat synthesis at that size is minutes-to-hours; hierarchical must land
  in seconds — including the reduction collectives, which compose per-pod
  reduce phases via the reversed-fabric trick). ``us_per_call`` is
  synthesis wall time; validation time rides in meta.
* ``fig_hier3_{ag,ar}_<n>`` — the multi-level (rack -> pod -> plane) rows:
  cold synthesis + bulk validation on ``three_level`` fabrics through the
  recursive pipeline. The ≥2048-NPU rows are fabrics the flat path cannot
  touch at all; ``misses`` in meta is the registry-miss count, bounded by
  (phase kinds x levels) + 1 named route regardless of fabric size.
* ``fig_hier_vs_flat_<kind>`` — simulated-makespan ratio hierarchical/flat
  on a fabric small enough for flat synthesis (<= 1.25x for the forward
  collectives, <= 1.0x for the reductions).
* ``fig_hier_reuse`` — registry amortization: N isomorphic pods cost one
  intra/scatter synthesis each.
"""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AlgorithmRegistry, CollectiveRequest, SynthesisEngine
from repro.topology import multi_pod, three_level


def _cold_row(name: str, topo, kind: str, mode: str = "auto") -> Row:
    reg = AlgorithmRegistry()
    eng = SynthesisEngine(topo, registry=reg)
    alg, us = timed(getattr(eng, kind), topo.npus)
    _, val_us = timed(alg.validate, mode)
    n = len(topo.npus)
    return Row(
        name, us,
        f"npus={n};pods={topo.num_pods};levels={topo.partition_depth + 1};"
        f"makespan={alg.makespan};"
        f"transfers={alg.num_transfers};validate_s={val_us / 1e6:.2f};"
        f"total_s={(us + val_us) / 1e6:.2f};misses={reg.stats.misses};"
        f"algo={alg.name}",
    )


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []

    # -- cold synthesis + validation at scale ------------------------------
    # small pods minimize intra/scatter legs (see ISSUE-3 tuning): 64 pods
    # of 4x4 beats 16 pods of 8x8 by ~2.5x wall-clock at 1024 NPUs
    sizes = [(4, 4, 4, 4)]  # (pods, rows, cols, dci_ports) -> 64 NPUs
    if full:
        sizes += [(16, 4, 4, 4), (64, 4, 4, 4)]  # 256, 1024 NPUs
    for pods, r, c, ports in sizes:
        topo = multi_pod(pods, r, c, unit_links=True, dci_ports_per_pod=ports)
        n = pods * r * c
        rows.append(_cold_row(f"fig_hier_ag_{n}", topo, "all_gather"))
        rows.append(_cold_row(f"fig_hier_a2a_{n}", topo, "all_to_all"))
        rows.append(_cold_row(f"fig_hier_rs_{n}", topo, "reduce_scatter"))
        rows.append(_cold_row(f"fig_hier_ar_{n}", topo, "all_reduce"))

    # -- multi-level (rack -> pod -> plane) recursion at scale -------------
    # (pods, racks, npus_per_rack); bulk validation (the oracle replays
    # millions of transfers in python — the vectorized path is the point)
    sizes3 = [(4, 4, 4)]  # 64 NPUs, quick
    if full:
        sizes3 += [(8, 8, 8), (16, 16, 8)]  # 512, 2048 NPUs
    for pods, racks, k in sizes3:
        topo = three_level(pods, racks, k, unit_links=True)
        n = pods * racks * k
        rows.append(_cold_row(f"fig_hier3_ag_{n}", topo, "all_gather",
                              mode="bulk"))
        rows.append(_cold_row(f"fig_hier3_ar_{n}", topo, "all_reduce",
                              mode="bulk"))

    # -- hierarchical vs flat makespan on a flat-feasible fabric -----------
    topo = multi_pod(2, 4, 8, unit_links=True)
    eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
    for kind in ("all_gather", "all_to_all", "reduce_scatter", "all_reduce"):
        hier, hier_us = timed(getattr(eng, kind), topo.npus)
        flat, flat_us = timed(eng.collective, CollectiveRequest(
            kind, group=tuple(topo.npus), hierarchy="never"))
        hier.validate()
        flat.validate()
        rows.append(Row(
            f"fig_hier_vs_flat_{kind}", hier_us,
            f"npus=64;hier_makespan={hier.makespan};"
            f"flat_makespan={flat.makespan};"
            f"ratio={hier.makespan / flat.makespan:.3f};"
            f"flat_synth_us={flat_us:.0f}",
        ))

    # -- chunk-granular (barrier-free) pipelined All-Reduce ----------------
    # quick: the flat-feasible 64-NPU fabric, where ratio (pipelined
    # hierarchical / flat makespan) is the headline (<= 1.05x gate); full:
    # 512/2048 three-level fabrics, where ratio against the sequential
    # (barrier) route shows what killing the RS->AG barrier buys at sizes
    # flat synthesis cannot touch
    topo = multi_pod(2, 4, 8, unit_links=True)
    reg = AlgorithmRegistry()
    eng = SynthesisEngine(topo, registry=reg)
    pipe, us = timed(eng.hierarchical().all_reduce, topo.npus,
                     pipeline=True)
    pipe.validate()
    flat = eng.collective(CollectiveRequest(
        "all_reduce", group=tuple(topo.npus), hierarchy="never"))
    rows.append(Row(
        "fig_hier_pipe_ar_64", us,
        f"npus=64;pods={topo.num_pods};makespan={pipe.makespan};"
        f"transfers={pipe.num_transfers};flat_makespan={flat.makespan};"
        f"ratio={pipe.makespan / flat.makespan:.3f};"
        f"misses={reg.stats.misses};algo={pipe.name}",
    ))
    if full:
        for pods, racks, k in ((8, 8, 8), (16, 16, 8)):
            topo = three_level(pods, racks, k, unit_links=True)
            n = pods * racks * k
            reg = AlgorithmRegistry()
            eng = SynthesisEngine(topo, registry=reg)
            pipe, us = timed(eng.hierarchical().all_reduce, topo.npus,
                             pipeline=True)
            _, val_us = timed(pipe.validate, "bulk")
            seq = SynthesisEngine(
                topo, registry=AlgorithmRegistry()).hierarchical(
            ).all_reduce(topo.npus, pipeline=False)
            rows.append(Row(
                f"fig_hier_pipe_ar_{n}", us,
                f"npus={n};pods={topo.num_pods};makespan={pipe.makespan};"
                f"transfers={pipe.num_transfers};"
                f"seq_makespan={seq.makespan};"
                f"ratio={pipe.makespan / seq.makespan:.3f};"
                f"validate_s={val_us / 1e6:.2f};"
                f"misses={reg.stats.misses};algo={pipe.name}",
            ))

    # -- per-pod plan amortization -----------------------------------------
    pods = 8 if full else 4
    topo = multi_pod(pods, 4, 4, unit_links=True, dci_ports_per_pod=4)
    reg = AlgorithmRegistry()
    eng = SynthesisEngine(topo, registry=reg)
    alg, us = timed(eng.hierarchical().all_gather, topo.npus, pipeline=False)
    alg.validate()
    st = reg.stats.as_dict()
    rows.append(Row(
        "fig_hier_reuse", us,
        f"pods={pods};misses={st['misses']};hits={st['hits']};"
        f"makespan={alg.makespan}",
    ))
    return rows
