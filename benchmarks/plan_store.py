"""Columnar plan-representation benchmarks (fig_plan_*): the ISSUE-7 gate.

Two row families on composed (three-level All-Reduce) plans:

* ``fig_plan_stitch_<n>`` — cold hierarchical synthesis wall (stitching
  through ``TransferColumns.concat`` + one lexsort) plus differential
  micro-benchmarks of the schedule kernels against the pre-columnar
  per-object implementations, rebuilt inline: Python ``sorted`` over
  ``Transfer`` objects vs ``np.lexsort``, and per-object validator
  ingestion (one ``fromiter`` per field over attribute access) vs direct
  column views. ``plan_bytes`` (peak in-memory schedule footprint) is
  deterministic and gated; ``mem_ratio`` reports the object-path multiple.
* ``fig_plan_store_<n>`` — npz persistence: save wall, on-disk bytes
  (deterministic, gated), and mmap-load vs parse-load wall. The mmap load
  reads only zip metadata, so ``load_speedup`` grows with plan size.

The 2048-NPU rows (``--full``) are the acceptance row: sort+ingest speedup
>= 5x and/or object/columnar memory ratio >= 4x.
"""

from __future__ import annotations

import os
import sys
import tempfile
from operator import attrgetter

import numpy as np

from benchmarks.common import Row, timed
from repro.core import (
    AlgorithmRegistry,
    SynthesisEngine,
    Transfer,
    load_plan_npz,
    save_plan_npz,
    topology_fingerprint,
)
from repro.topology import three_level

_SORT_KEY = attrgetter("start", "chunk", "link")


def _object_path_sort(objs: list[Transfer]) -> list[Transfer]:
    """The pre-columnar canonicalization: sort Transfer objects."""
    return sorted(objs, key=_SORT_KEY)


def _object_path_ingest(objs: list[Transfer]):
    """The pre-columnar bulk-validator ingestion: one fromiter per field
    over per-object attribute access."""
    n = len(objs)
    return (
        np.fromiter((t.chunk for t in objs), np.int64, n),
        np.fromiter((t.link for t in objs), np.int64, n),
        np.fromiter((t.src for t in objs), np.int64, n),
        np.fromiter((t.dst for t in objs), np.int64, n),
        np.fromiter((t.start for t in objs), np.float64, n),
        np.fromiter((t.end for t in objs), np.float64, n),
        np.fromiter((t.reduce for t in objs), np.bool_, n),
    )


def _object_path_bytes(n: int) -> int:
    """Deterministic footprint of the pre-columnar schedule: n Transfer
    objects (plus their two uncached float payloads) and the list's
    pointer array."""
    proto = Transfer(0, 0, 0, 1, 0.0, 1.0)
    return n * (sys.getsizeof(proto) + 2 * sys.getsizeof(1.0) + 8)


def _rows_for(topo, n: int) -> list[Row]:
    reg = AlgorithmRegistry()
    eng = SynthesisEngine(topo, registry=reg)
    alg, synth_us = timed(eng.all_reduce, topo.npus)
    _, val_us = timed(alg.validate, "bulk")
    cols = alg.columns
    nt = len(cols)

    # shuffle once; both sort paths canonicalize the same permuted schedule
    rng = np.random.default_rng(0)
    order = rng.permutation(nt)
    shuffled = cols.take(order)
    objs = list(alg.transfers)
    shuffled_objs = [objs[i] for i in order.tolist()]

    _, sort_cols_us = timed(shuffled.sorted_schedule)
    _, sort_objs_us = timed(_object_path_sort, shuffled_objs)
    _, ingest_cols_us = timed(
        lambda c: (c.chunk, c.link, c.src, c.dst, c.start, c.end, c.reduce),
        cols)
    _, ingest_objs_us = timed(_object_path_ingest, objs)

    plan_bytes = alg.plan_nbytes
    obj_bytes = _object_path_bytes(nt)
    rows = [Row(
        f"fig_plan_stitch_{n}", synth_us,
        f"npus={n};transfers={nt};makespan={alg.makespan};"
        f"plan_bytes={plan_bytes};mem_ratio={obj_bytes / plan_bytes:.2f};"
        f"sort_speedup={sort_objs_us / max(sort_cols_us, 1e-9):.1f};"
        f"ingest_speedup={ingest_objs_us / max(ingest_cols_us, 1e-9):.1f};"
        f"validate_s={val_us / 1e6:.2f};misses={reg.stats.misses}",
    )]

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "plan.npz")
        fp = topology_fingerprint(topo)
        _, save_us = timed(save_plan_npz, path, alg, fp)
        disk_bytes = os.path.getsize(path)
        loaded, load_mmap_us = timed(load_plan_npz, path, topo)
        _, load_parse_us = timed(load_plan_npz, path, topo, use_mmap=False)
        assert loaded.num_transfers == nt
        rows.append(Row(
            f"fig_plan_store_{n}", save_us,
            f"npus={n};transfers={nt};disk_bytes={disk_bytes};"
            f"load_mmap_us={load_mmap_us:.0f};"
            f"load_parse_us={load_parse_us:.0f};"
            f"load_speedup={load_parse_us / max(load_mmap_us, 1e-9):.1f}",
        ))
    return rows


def run(full: bool = False) -> list[Row]:
    sizes = [(4, 4, 4)]  # 64 NPUs, quick
    if full:
        sizes += [(8, 8, 8), (16, 16, 8)]  # 512, 2048 NPUs
    rows: list[Row] = []
    for pods, racks, k in sizes:
        topo = three_level(pods, racks, k, unit_links=True)
        rows.extend(_rows_for(topo, pods * racks * k))
    return rows
