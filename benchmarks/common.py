"""Shared benchmark helpers."""

from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float  # synthesis wall time (us) where applicable, else 0
    derived: str  # metric payload, `key=value;key=value`

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6
