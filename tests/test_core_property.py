"""Property-based tests (hypothesis): every synthesized algorithm on every
random topology satisfies the full validation oracle — postconditions met,
congestion-free, causal, alpha-beta-timed, switch-legal."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (
    ChunkIds,
    Condition,
    all_gather,
    all_to_all,
    synthesize,
    synthesize_all_reduce,
    synthesize_joint,
    synthesize_reduce_scatter,
)
from repro.topology.topology import NodeType, Topology

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@st.composite
def connected_topologies(draw, max_npus=8, hetero=False, switches=False):
    """Random strongly-connected topology: a random ring backbone (guarantees
    strong connectivity) plus random extra links; optional hetero alpha/beta
    and switch nodes."""
    n = draw(st.integers(min_value=2, max_value=max_npus))
    topo = Topology("prop")
    topo.add_npus(n)
    perm = draw(st.permutations(list(range(n))))

    def ab():
        if not hetero:
            return 0.0, 1.0
        alpha = draw(st.sampled_from([0.0, 0.5, 1.0, 2.0]))
        beta = draw(st.sampled_from([0.25, 0.5, 1.0, 2.0]))
        return alpha, beta

    for i in range(n):
        a, b = ab()
        topo.add_link(perm[i], perm[(i + 1) % n], a, b)
    extra = draw(st.integers(min_value=0, max_value=2 * n))
    for _ in range(extra):
        u = draw(st.integers(min_value=0, max_value=n - 1))
        v = draw(st.integers(min_value=0, max_value=n - 1))
        if u != v and not any(l.dst == v for l in topo.out_links(u)):
            a, b = ab()
            topo.add_link(u, v, a, b)
    if switches:
        # hang a switch connecting a random subset bidirectionally
        sw = topo.add_node(
            NodeType.SWITCH,
            buffer_limit=draw(st.sampled_from([None, 1, 2, 4])),
            multicast=draw(st.booleans()),
        )
        members = draw(
            st.lists(st.integers(min_value=0, max_value=n - 1), min_size=2,
                     max_size=n, unique=True)
        )
        for m in members:
            a, b = ab()
            topo.add_bidir_link(m, sw, a, b)
    return topo


@st.composite
def groups_of(draw, topo):
    npus = topo.npus
    k = draw(st.integers(min_value=2, max_value=len(npus)))
    return draw(st.permutations(npus))[:k]


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_all_gather_valid_on_random_topology(data):
    topo = data.draw(connected_topologies())
    group = data.draw(groups_of(topo))
    alg = synthesize(topo, all_gather(list(group)))
    alg.validate()


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_all_to_all_valid_on_random_topology(data):
    topo = data.draw(connected_topologies(max_npus=6))
    group = data.draw(groups_of(topo))
    alg = synthesize(topo, all_to_all(list(group)))
    alg.validate()


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_hetero_random_topology(data):
    topo = data.draw(connected_topologies(max_npus=6, hetero=True))
    group = data.draw(groups_of(topo))
    bytes_ = data.draw(st.sampled_from([0.5, 1.0, 3.0]))
    alg = synthesize(topo, all_gather(list(group), bytes=bytes_))
    alg.validate()


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_switch_random_topology(data):
    topo = data.draw(connected_topologies(max_npus=6, switches=True))
    group = data.draw(groups_of(topo))
    alg = synthesize(topo, all_gather(list(group)))
    alg.validate()


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_reductions_random_topology(data):
    topo = data.draw(connected_topologies(max_npus=6))
    group = data.draw(groups_of(topo))
    rs = synthesize_reduce_scatter(topo, list(group))
    rs.validate()
    ar = synthesize_all_reduce(topo, list(group),
                               pipelined=data.draw(st.booleans()))
    ar.validate()


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_joint_groups_random(data):
    topo = data.draw(connected_topologies(max_npus=8))
    npus = list(topo.npus)
    if len(npus) < 4:
        return
    half = len(npus) // 2
    ids = ChunkIds()
    g1, g2 = npus[:half], npus[half:]
    alg = synthesize_joint(
        topo,
        [("g1", all_gather(g1, ids=ids)), ("g2", all_to_all(g2, ids=ids))],
    )
    alg.validate()


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_arbitrary_conditions_random(data):
    """Custom collectives: arbitrary pre/postconditions (paper abstract)."""
    topo = data.draw(connected_topologies(max_npus=7))
    npus = list(topo.npus)
    ids = ChunkIds()
    n_conds = data.draw(st.integers(min_value=1, max_value=6))
    conds = []
    for _ in range(n_conds):
        src = data.draw(st.sampled_from(npus))
        dests = data.draw(
            st.lists(st.sampled_from(npus), min_size=1, max_size=len(npus),
                     unique=True)
        )
        conds.append(Condition(ids.next(), src, frozenset(dests)))
    alg = synthesize(topo, conds)
    alg.validate()
    # postcondition double-check outside the oracle
    for c in conds:
        reached = {c.src} | {t.dst for t in alg.transfers if t.chunk == c.chunk}
        assert c.dests <= reached
