"""Differential tests for the columnar plan representation.

``CollectiveAlgorithm`` stores its schedule as parallel numpy columns with
lazy per-row ``Transfer`` views. Every vectorized kernel here is compared
bit-for-bit against an in-test reference written the way the old per-object
code worked — same sort key, same arithmetic, same iteration order — on all
four routing paths: flat, hierarchical (multi-pod), multi-level + time
reversal (reductions), and traffic-engineered (CommSketch). The npz
persistence round-trip is held to the same standard: transfer order, every
field, conditions, and phase spans must come back identical.
"""

from operator import attrgetter

import numpy as np
import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveAlgorithm,
    CommSketch,
    SynthesisEngine,
    Transfer,
    TransferColumns,
    TransferList,
    load_plan_npz,
    save_plan_npz,
    topology_fingerprint,
)
from repro.core.conditions import ChunkIds
from repro.core.registry import (
    invert_permutation,
    relabel_algorithm,
    renumber_chunks,
)
from repro.topology import multi_pod, torus2d
from repro.topology.generators import three_level

SORT_KEY = attrgetter("start", "chunk", "link")


def _routes():
    """(name, algorithm) for every routing path in the synthesis stack."""
    flat = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
    pods = SynthesisEngine(
        multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4),
        registry=AlgorithmRegistry())
    deep = SynthesisEngine(three_level(2, 2, 2, unit_links=True),
                           registry=AlgorithmRegistry())
    te = SynthesisEngine(
        multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4),
        registry=AlgorithmRegistry(),
        sketch=CommSketch(max_pod_ports={0: 1, 1: 1}))
    return [
        ("flat_ag", flat.all_gather(list(range(16)))),
        ("flat_a2a", flat.all_to_all([0, 1, 2, 3])),
        ("flat_rs", flat.reduce_scatter(list(range(16)))),
        ("hier_ag", pods.all_gather(pods.topology.npus)),
        ("hier_ar", pods.all_reduce(pods.topology.npus)),
        ("hier3_ag", deep.all_gather(deep.topology.npus)),
        ("hier3_rs", deep.reduce_scatter(deep.topology.npus)),
        ("te_ag", te.all_gather(te.topology.npus)),
    ]


ROUTES = _routes()
IDS = [name for name, _ in ROUTES]


@pytest.mark.parametrize("alg", [a for _, a in ROUTES], ids=IDS)
class TestScheduleIdentity:
    def test_sort_matches_object_sort(self, alg):
        """Column order == the old __post_init__'s object sort."""
        assert list(alg.transfers) == sorted(alg.transfers, key=SORT_KEY)

    def test_object_ingestion_roundtrip(self, alg):
        """Rebuilding from plain Transfer objects reproduces the schedule
        bit-for-bit: fields, order, and phase spans."""
        objs = [Transfer(t.chunk, t.link, t.src, t.dst, t.start, t.end,
                         t.reduce) for t in alg.transfers]
        rebuilt = CollectiveAlgorithm(
            alg.topology, list(alg.conditions), objs, name=alg.name,
            phase_spans=list(alg.phase_spans))
        assert rebuilt == alg
        assert list(rebuilt.transfers) == list(alg.transfers)
        assert rebuilt.phase_spans == alg.phase_spans

    def test_npz_roundtrip_bit_identical(self, alg, tmp_path):
        path = str(tmp_path / "plan.npz")
        save_plan_npz(path, alg, topology_fingerprint(alg.topology))
        for use_mmap in (True, False):
            back = load_plan_npz(path, alg.topology, use_mmap=use_mmap)
            assert list(back.transfers) == list(alg.transfers)
            assert back.conditions == alg.conditions
            assert back.phase_spans == alg.phase_spans
            assert back.name == alg.name
            back.validate()

    def test_vectorized_metrics_match_reference(self, alg):
        """makespan / link_busy_time / link_utilization / total_bytes_moved
        against the old per-object loops."""
        release = min((c.release for c in alg.conditions), default=0.0)
        ref_makespan = max((t.end for t in alg.transfers),
                           default=release) - release
        assert alg.makespan == ref_makespan

        busy: dict[int, float] = {}
        for t in alg.transfers:  # same accumulation order as np.add.at
            busy[t.link] = busy.get(t.link, 0.0) + (t.end - t.start)
        assert alg.link_busy_time() == busy

        if ref_makespan > 0 and busy:
            ref_util = {l: b / ref_makespan for l, b in busy.items()}
            assert alg.link_utilization() == ref_util

        sizes = {c.chunk: c.bytes for c in alg.conditions}
        ref_total = sum(sizes[t.chunk] for t in alg.transfers)
        assert alg.total_bytes_moved() == pytest.approx(ref_total)

    def test_time_reversal_primitive(self, alg):
        """Columnar time reversal == the old per-object construction."""
        cols = alg.columns
        pivot = float(cols.end.max()) if len(cols) else 0.0
        rev = cols.time_reversed(pivot)
        ref = [Transfer(t.chunk, t.link, t.dst, t.src,
                        pivot - t.end, pivot - t.start, reduce=True)
               for t in alg.transfers]
        assert list(TransferList(rev)) == ref

    def test_relabel_identity_roundtrip(self, alg):
        """Relabeling through an automorphism and back is lossless and the
        forward image matches a per-object reference relabel."""
        topo = alg.topology
        gens = [g for g in getattr(topo, "automorphism_generators", [])]
        if not gens:
            pytest.skip("no symmetry generators on this fabric")
        perm = list(gens[0])
        fwd = relabel_algorithm(alg, perm)

        from repro.core.registry import _link_map
        links = _link_map(topo, perm)
        ref = sorted((Transfer(t.chunk, links[t.link], perm[t.src],
                               perm[t.dst], t.start, t.end, t.reduce)
                      for t in alg.transfers), key=SORT_KEY)
        assert list(fwd.transfers) == ref

        back = relabel_algorithm(fwd, invert_permutation(perm))
        assert list(back.transfers) == list(alg.transfers)
        assert back.conditions == alg.conditions

    def test_renumber_chunks_matches_reference(self, alg):
        ids = ChunkIds(1000)
        out = renumber_chunks(alg, ids)
        mapping = {}
        nxt = 1000
        for c in alg.conditions:  # same allocation order as renumber_chunks
            mapping[c.chunk] = nxt
            nxt += 1
        ref = [Transfer(mapping.get(t.chunk, t.chunk), t.link, t.src, t.dst,
                        t.start, t.end, t.reduce) for t in alg.transfers]
        assert list(out.transfers) == ref
        assert [c.chunk for c in out.conditions] == \
            [mapping[c.chunk] for c in alg.conditions]


class TestTransferListApi:
    def setup_method(self):
        eng = SynthesisEngine(torus2d(3, 3), registry=AlgorithmRegistry())
        self.alg = eng.all_gather(list(range(9)))
        self.tl = self.alg.transfers

    def test_sequence_semantics(self):
        tl = self.tl
        assert isinstance(tl, TransferList)
        n = len(tl)
        assert n == self.alg.num_transfers
        assert isinstance(tl[0], Transfer)
        assert tl[-1] == tl[n - 1]
        assert list(tl[2:5]) == list(tl)[2:5]
        assert tl == list(tl)
        assert tl + [tl[0]] == list(tl) + [tl[0]]
        with pytest.raises(IndexError):
            tl[n]

    def test_rows_are_plain_python_scalars(self):
        t = self.tl[0]
        assert type(t.chunk) is int and type(t.link) is int
        assert type(t.src) is int and type(t.dst) is int
        assert type(t.start) is float and type(t.end) is float
        assert type(t.reduce) is bool

    def test_columns_are_read_only_after_mmap_load(self, tmp_path):
        path = str(tmp_path / "p.npz")
        save_plan_npz(path, self.alg, topology_fingerprint(self.alg.topology))
        back = load_plan_npz(path, self.alg.topology)
        for name in ("chunk", "link", "src", "dst", "start", "end",
                     "reduce"):
            arr = getattr(back.columns, name)
            assert not arr.flags.writeable
        # and the arrays are views over the file, not copies
        base = back.columns.chunk.base
        while base is not None:
            if isinstance(base, memoryview):
                base = base.obj
                continue
            if type(base).__name__ == "mmap":
                break
            base = getattr(base, "base", None)
        assert type(base).__name__ == "mmap"

    def test_concat_and_shift(self):
        cols = self.alg.columns
        shifted = cols.shifted(2.5)
        assert np.array_equal(shifted.start, cols.start + 2.5)
        both = TransferColumns.concat([cols, shifted])
        assert len(both) == 2 * len(cols)
        assert np.array_equal(both.chunk[:len(cols)], cols.chunk)
