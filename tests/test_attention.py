"""Attention-path equivalence tests: blockwise (flash-style) vs dense
reference, sliding window, partial rotary, GQA grouping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attend,
    blockwise_attention,
    gqa_scores_mask,
)
from repro.models.layers import apply_rope, rope_tables


def _rand_qkv(rng, B=2, S=256, H=8, KV=4, hd=32, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, S, H, hd), dtype)
    k = jax.random.normal(kk, (B, S, KV, hd), dtype)
    v = jax.random.normal(kv, (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("block_q,block_kv", [(64, 64), (128, 256), (256, 64)])
def test_blockwise_matches_dense(causal, block_q, block_kv):
    q, k, v = _rand_qkv(jax.random.PRNGKey(0))
    S = q.shape[1]
    mask = gqa_scores_mask(S, S, causal=causal)
    want = attend(q, k, v, mask)
    got = blockwise_attention(q, k, v, causal=causal, block_q=block_q,
                              block_kv=block_kv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_blockwise_sliding_window(window):
    q, k, v = _rand_qkv(jax.random.PRNGKey(1))
    S = q.shape[1]
    mask = gqa_scores_mask(S, S, causal=True, window=window)
    want = attend(q, k, v, mask)
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_softcap():
    q, k, v = _rand_qkv(jax.random.PRNGKey(2))
    S = q.shape[1]
    mask = gqa_scores_mask(S, S, causal=True)
    want = attend(q, k, v, mask, softcap=30.0)
    got = blockwise_attention(q, k, v, causal=True, softcap=30.0,
                              block_q=64, block_kv=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_partial_rotary_rotates_prefix_only():
    hd, pct = 64, 0.5
    pos = jnp.arange(16)
    cos, sin, rot = rope_tables(pos, hd, 10000.0, pct)
    assert rot == 32
    x = jnp.ones((1, 16, 2, hd))
    y = apply_rope(x, cos, sin, rot)
    # the un-rotated suffix is untouched
    np.testing.assert_allclose(np.asarray(y[..., rot:]),
                               np.asarray(x[..., rot:]))
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(x[0, 0]),
                               rtol=1e-6)


def test_gqa_grouping_consistency():
    """GQA with KV=H equals MHA on the same tensors."""
    rng = jax.random.PRNGKey(3)
    q, k, v = _rand_qkv(rng, H=4, KV=4)
    S = q.shape[1]
    mask = gqa_scores_mask(S, S, causal=True)
    out = attend(q, k, v, mask)
    # manual per-head attention
    import math

    for h in range(4):
        s = jnp.einsum("bsd,btd->bst", q[:, :, h], k[:, :, h]) / math.sqrt(32)
        s = s + mask
        p = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bst,btd->bsd", p, v[:, :, h])
        np.testing.assert_allclose(np.asarray(out[:, :, h]),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)
