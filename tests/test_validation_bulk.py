"""Differential tests: the vectorized validation path must accept and
reject exactly what the reference oracle does on its eligible class
(plain conditions, unconstrained switches)."""

import dataclasses

import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveAlgorithm,
    SynthesisEngine,
    Transfer,
)
from repro.topology import multi_pod, ring, star_switch, torus2d


@pytest.fixture(scope="module")
def algs():
    t1 = torus2d(3, 3)
    eng = SynthesisEngine(t1)
    t2 = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
    e2 = SynthesisEngine(t2, registry=AlgorithmRegistry())
    return [
        eng.all_gather(list(range(9))),
        eng.all_to_all(list(range(9))),
        e2.all_gather(t2.npus),  # hierarchical, stitched phases
        e2.all_to_all(t2.npus),
    ]


def _mutate(alg, idx, **kw):
    ts = list(alg.transfers)
    ts[idx] = dataclasses.replace(ts[idx], **kw)
    return CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                               name=alg.name)


def _drop_last_delivery(alg):
    """Remove the final transfer of some chunk: its destination is never
    reached (post-condition failure)."""
    ts = list(alg.transfers)
    last = {}
    for i, t in enumerate(ts):
        last[t.chunk] = i
    del ts[last[ts[-1].chunk]]
    return CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                               name=alg.name)


class TestBulkMatchesOracle:
    @pytest.mark.parametrize("i", range(4))
    def test_valid_schedules_accepted(self, algs, i):
        alg = algs[i]
        alg.validate(mode="oracle")
        alg.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_congestion_rejected(self, algs, i):
        alg = algs[i]
        # move one transfer onto another's slot on the same link
        a, b = None, None
        by_link = {}
        for k, t in enumerate(alg.transfers):
            if t.link in by_link:
                a, b = by_link[t.link], k
                break
            by_link[t.link] = k
        assert a is not None
        broken = _mutate(
            alg, b,
            start=alg.transfers[a].start,
            end=alg.transfers[a].start
            + (alg.transfers[b].end - alg.transfers[b].start),
        )
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_bad_duration_rejected(self, algs, i):
        alg = algs[i]
        broken = _mutate(alg, 0, end=alg.transfers[0].end + 0.5)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_causality_violation_rejected(self, algs, i):
        alg = algs[i]
        # find a forwarding transfer (sender is not the chunk's origin) and
        # pull it before the chunk could have arrived
        origin = {c.chunk: c.src for c in alg.conditions}
        k = next(j for j, t in enumerate(alg.transfers)
                 if t.src != origin[t.chunk])
        t = alg.transfers[k]
        broken = _mutate(alg, k, start=t.start - t.end,
                         end=t.start - t.end + (t.end - t.start))
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_missing_delivery_rejected(self, algs, i):
        alg = algs[i]
        broken = _drop_last_delivery(alg)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_wrong_link_endpoints_rejected(self):
        alg = SynthesisEngine(ring(4)).all_gather(list(range(4)))
        t = alg.transfers[0]
        broken = _mutate(alg, 0, dst=(t.dst + 1) % 4)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_release_violation_rejected(self):
        import dataclasses as dc

        alg = SynthesisEngine(ring(4)).all_gather(list(range(4)))
        conds = [dc.replace(c, release=5.0) for c in alg.conditions]
        broken = CollectiveAlgorithm(alg.topology, conds,
                                     list(alg.transfers), name=alg.name)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_bulk_refuses_constrained_switches(self):
        topo = star_switch(4, buffer_limit=1)
        alg = SynthesisEngine(topo).all_gather(list(range(4)))
        alg.validate(mode="oracle")
        with pytest.raises(ValueError, match="bulk validation"):
            alg.validate(mode="bulk")
        alg.validate()  # auto falls back to the oracle

    def test_bulk_refuses_reductions(self):
        alg = SynthesisEngine(ring(4)).all_reduce(list(range(4)))
        alg.validate(mode="oracle")
        with pytest.raises(ValueError, match="bulk validation"):
            alg.validate(mode="bulk")

    def test_bulk_empty_transfers(self):
        """Zero transfers: clean post-condition rejection (not IndexError)
        for missing deliveries, acceptance when every dest is the origin."""
        from repro.core import Condition

        topo = ring(4)
        undelivered = CollectiveAlgorithm(
            topo, [Condition(0, 0, frozenset([1]))], [])
        with pytest.raises(AssertionError, match="never reached"):
            undelivered.validate(mode="bulk")
        with pytest.raises(AssertionError):
            undelivered.validate(mode="oracle")
        trivial = CollectiveAlgorithm(
            topo, [Condition(0, 0, frozenset([0]))], [])
        trivial.validate(mode="bulk")
        trivial.validate(mode="oracle")
