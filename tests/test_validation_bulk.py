"""Differential tests: the vectorized validation path must accept and
reject exactly what the reference oracle does on its eligible class
(plain conditions, unconstrained switches)."""

import dataclasses

import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveAlgorithm,
    SynthesisEngine,
)
from repro.topology import multi_pod, ring, star_switch, torus2d


@pytest.fixture(scope="module")
def algs():
    t1 = torus2d(3, 3)
    eng = SynthesisEngine(t1)
    t2 = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
    e2 = SynthesisEngine(t2, registry=AlgorithmRegistry())
    return [
        eng.all_gather(list(range(9))),
        eng.all_to_all(list(range(9))),
        e2.all_gather(t2.npus),  # hierarchical, stitched phases
        e2.all_to_all(t2.npus),
    ]


def _mutate(alg, idx, **kw):
    ts = list(alg.transfers)
    ts[idx] = dataclasses.replace(ts[idx], **kw)
    return CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                               name=alg.name)


def _drop_last_delivery(alg):
    """Remove the final transfer of some chunk: its destination is never
    reached (post-condition failure)."""
    ts = list(alg.transfers)
    last = {}
    for i, t in enumerate(ts):
        last[t.chunk] = i
    del ts[last[ts[-1].chunk]]
    return CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                               name=alg.name)


class TestBulkMatchesOracle:
    @pytest.mark.parametrize("i", range(4))
    def test_valid_schedules_accepted(self, algs, i):
        alg = algs[i]
        alg.validate(mode="oracle")
        alg.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_congestion_rejected(self, algs, i):
        alg = algs[i]
        # move one transfer onto another's slot on the same link
        a, b = None, None
        by_link = {}
        for k, t in enumerate(alg.transfers):
            if t.link in by_link:
                a, b = by_link[t.link], k
                break
            by_link[t.link] = k
        assert a is not None
        broken = _mutate(
            alg, b,
            start=alg.transfers[a].start,
            end=alg.transfers[a].start
            + (alg.transfers[b].end - alg.transfers[b].start),
        )
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_bad_duration_rejected(self, algs, i):
        alg = algs[i]
        broken = _mutate(alg, 0, end=alg.transfers[0].end + 0.5)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_causality_violation_rejected(self, algs, i):
        alg = algs[i]
        # find a forwarding transfer (sender is not the chunk's origin) and
        # pull it before the chunk could have arrived
        origin = {c.chunk: c.src for c in alg.conditions}
        k = next(j for j, t in enumerate(alg.transfers)
                 if t.src != origin[t.chunk])
        t = alg.transfers[k]
        broken = _mutate(alg, k, start=t.start - t.end,
                         end=t.start - t.end + (t.end - t.start))
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_missing_delivery_rejected(self, algs, i):
        alg = algs[i]
        broken = _drop_last_delivery(alg)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_wrong_link_endpoints_rejected(self):
        alg = SynthesisEngine(ring(4)).all_gather(list(range(4)))
        t = alg.transfers[0]
        broken = _mutate(alg, 0, dst=(t.dst + 1) % 4)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_release_violation_rejected(self):
        import dataclasses as dc

        alg = SynthesisEngine(ring(4)).all_gather(list(range(4)))
        conds = [dc.replace(c, release=5.0) for c in alg.conditions]
        broken = CollectiveAlgorithm(alg.topology, conds,
                                     list(alg.transfers), name=alg.name)
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    def test_bulk_refuses_constrained_switches(self):
        topo = star_switch(4, buffer_limit=1)
        alg = SynthesisEngine(topo).all_gather(list(range(4)))
        alg.validate(mode="oracle")
        with pytest.raises(ValueError, match="bulk validation"):
            alg.validate(mode="bulk")
        alg.validate()  # auto falls back to the oracle

    def test_bulk_refuses_reduce_flag_on_plain_chunk(self):
        # a reduce-flagged copy of a plain chunk is a nonstandard schedule:
        # the oracle judges it with its full replay, bulk stays out
        alg = SynthesisEngine(ring(4)).all_gather(list(range(4)))
        weird = _mutate(alg, 0, reduce=True)
        with pytest.raises(ValueError, match="bulk validation"):
            weird.validate(mode="bulk")

    def test_bulk_validates_reductions(self):
        # reductions in the in-forest normal form now take the bulk path
        for alg in (SynthesisEngine(ring(4)).all_reduce(list(range(4))),
                    SynthesisEngine(ring(4)).reduce_scatter(list(range(4)))):
            alg.validate(mode="oracle")
            alg.validate(mode="bulk")

    def test_bulk_empty_transfers(self):
        """Zero transfers: clean post-condition rejection (not IndexError)
        for missing deliveries, acceptance when every dest is the origin."""
        from repro.core import Condition

        topo = ring(4)
        undelivered = CollectiveAlgorithm(
            topo, [Condition(0, 0, frozenset([1]))], [])
        with pytest.raises(AssertionError, match="never reached"):
            undelivered.validate(mode="bulk")
        with pytest.raises(AssertionError):
            undelivered.validate(mode="oracle")
        trivial = CollectiveAlgorithm(
            topo, [Condition(0, 0, frozenset([0]))], [])
        trivial.validate(mode="bulk")
        trivial.validate(mode="oracle")


class TestBulkReductionDifferential:
    """Reduction schedules (flat reversed-gather and hierarchical composed):
    the bulk in-forest checks must accept what the oracle accepts and reject
    every corruption class the oracle rejects."""

    @pytest.fixture(scope="class")
    def ralgs(self):
        eng = SynthesisEngine(ring(4))
        t2 = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        e2 = SynthesisEngine(t2, registry=AlgorithmRegistry())
        return [
            eng.reduce_scatter(list(range(4))),
            eng.all_reduce(list(range(4))),
            e2.reduce_scatter(t2.npus),  # hierarchical, time-reversed phases
            e2.all_reduce(t2.npus),
        ]

    @staticmethod
    def _both_reject(broken):
        with pytest.raises(AssertionError):
            broken.validate(mode="oracle")
        with pytest.raises(AssertionError):
            broken.validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_valid_accepted(self, ralgs, i):
        ralgs[i].validate(mode="oracle")
        ralgs[i].validate(mode="bulk")

    @pytest.mark.parametrize("i", range(4))
    def test_double_partial_send_rejected(self, ralgs, i):
        alg = ralgs[i]
        t = next(t for t in alg.transfers if t.reduce)
        dup = dataclasses.replace(t, start=t.start + 1000, end=t.end + 1000)
        self._both_reject(CollectiveAlgorithm(
            alg.topology, alg.conditions, list(alg.transfers) + [dup],
            name=alg.name))

    @pytest.mark.parametrize("i", range(4))
    def test_partial_copy_rejected(self, ralgs, i):
        # stripping the reduce flag turns a partial forward into an illegal
        # copy of partially-reduced state
        alg = ralgs[i]
        k = next(j for j, t in enumerate(alg.transfers) if t.reduce)
        self._both_reject(_mutate(alg, k, reduce=False))

    @pytest.mark.parametrize("i", range(4))
    def test_missing_contribution_rejected(self, ralgs, i):
        # dropping a chunk's final merge leaves its contribution stranded
        alg = ralgs[i]
        last, li = {}, {}
        for j, t in enumerate(alg.transfers):
            if t.reduce and (t.chunk not in last or t.end > last[t.chunk]):
                last[t.chunk], li[t.chunk] = t.end, j
        drop = li[min(li)]
        ts = [t for j, t in enumerate(alg.transfers) if j != drop]
        self._both_reject(CollectiveAlgorithm(
            alg.topology, alg.conditions, ts, name=alg.name))

    @pytest.mark.parametrize("i", range(4))
    def test_forward_before_merge_rejected(self, ralgs, i):
        # a merge point forwarding before a child partial arrives loses it
        alg = ralgs[i]
        recv = {(t.chunk, t.dst) for t in alg.transfers if t.reduce}
        k = next(j for j, t in enumerate(alg.transfers)
                 if t.reduce and (t.chunk, t.src) in recv)
        t = alg.transfers[k]
        self._both_reject(_mutate(alg, k, start=t.start - 100,
                                  end=t.end - 100))

    @staticmethod
    def _agree(alg):
        """Both paths must return the same verdict; return it."""
        res = {}
        for mode in ("oracle", "bulk"):
            try:
                alg.validate(mode=mode)
                res[mode] = True
            except AssertionError:
                res[mode] = False
        assert res["oracle"] == res["bulk"], res
        return res["oracle"]

    def test_nonstandard_but_valid_schedules_defer_to_oracle(self):
        """Outside the in-forest normal form the bulk path must hand the
        verdict to the oracle, not structurally reject: a node that
        assembled the full set may legally hold it while reduce-forwarding
        or copying it onward."""
        from repro.core import ReduceCondition, Transfer
        from repro.topology import Topology

        t = Topology("chain")
        t.add_npus(3)
        l01 = t.add_link(0, 1)
        l12 = t.add_link(1, 2)
        fwd = CollectiveAlgorithm(
            t, [ReduceCondition(0, frozenset([0, 1]), frozenset([1]))],
            [Transfer(0, l01, 0, 1, 0.0, 1.0, reduce=True),
             Transfer(0, l12, 1, 2, 1.0, 2.0, reduce=True)])
        assert self._agree(fwd)  # dest holds full set despite forwarding
        copy = CollectiveAlgorithm(
            t, [ReduceCondition(0, frozenset([0, 1]), frozenset([1, 2]))],
            [Transfer(0, l01, 0, 1, 0.0, 1.0, reduce=True),
             Transfer(0, l12, 1, 2, 1.0, 2.0, reduce=False)])
        assert self._agree(copy)  # mid-chain full-set holder may copy

    @pytest.mark.parametrize("i", range(2))
    def test_single_transfer_mutation_fuzz(self, ralgs, i):
        """Every single-transfer mutation of a flat reduction (flip the
        reduce flag, retime either way, drop) gets the same verdict from
        both paths."""
        base = ralgs[i]
        for k in range(len(base.transfers)):
            tr = base.transfers[k]
            muts = [
                dataclasses.replace(tr, reduce=not tr.reduce),
                dataclasses.replace(tr, start=tr.start - 2, end=tr.end - 2),
                dataclasses.replace(tr, start=tr.start + 7, end=tr.end + 7),
            ]
            for m in muts:
                ts = list(base.transfers)
                ts[k] = m
                self._agree(CollectiveAlgorithm(
                    base.topology, base.conditions, ts, name="mut"))
            ts = [x for j, x in enumerate(base.transfers) if j != k]
            self._agree(CollectiveAlgorithm(
                base.topology, base.conditions, ts, name="drop"))
