"""Fault-aware incremental plan repair: PlanRepairer, PlanService.repair,
and the FaultToleranceManager wiring.

The contract under test (see ``repro/core/repair.py``): a repaired plan
fulfils, on the surviving fabric, exactly the per-chunk conditions a cold
degraded-fabric synthesis would — validated end to end — or the repair
raises :class:`FabricDegradedError` loudly. Strategy provenance rides on
the :class:`RepairResult`: phase-local repair keeps undamaged phases
verbatim and re-synthesizes only the damaged ones; anything the phase
record cannot express falls back to cold resynthesis through the shared
registry.
"""

import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveRequest,
    DamageReport,
    DegradationEvent,
    FabricDegradedError,
    PlanRepairer,
    PlanService,
    SynthesisEngine,
)
from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.core.conditions import ReduceCondition
from repro.runtime.fault_tolerance import (
    ElasticMeshPlanner,
    FaultToleranceManager,
)
from repro.topology import multi_pod, ring, three_level


def _delivery(alg):
    out = []
    for c in alg.conditions:
        if isinstance(c, ReduceCondition):
            out.append((c.chunk, tuple(sorted(c.srcs)),
                        tuple(sorted(c.dests))))
        else:
            out.append((c.chunk, c.src, tuple(sorted(c.dests))))
    return sorted(out)


def _internal_link(topo, pod: int) -> int:
    """A non-boundary link with both endpoints inside ``pod``."""
    members = set(topo.pods()[pod])
    boundary = {l.id for l in topo.boundary_links()}
    for l in topo.links:
        if l.id not in boundary and l.src in members and l.dst in members:
            return l.id
    raise AssertionError("no internal link found")


def _cold_degraded(topo, req, event):
    """Reference: cold synthesis on the surviving fabric, fresh registry."""
    dtopo = topo.degraded(event.failed_links, event.failed_npus).topology
    eng = SynthesisEngine(dtopo, registry=AlgorithmRegistry())
    return eng.collective(req)


class TestDegradedView:
    def test_node_ids_stable_links_dropped(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        dead_link = _internal_link(topo, 0)
        view = topo.degraded([dead_link], [])
        assert list(view.nodes) == list(range(topo.num_nodes))
        assert dead_link not in view.links
        assert len(view.links) == topo.num_links - 1
        assert view.topology.partition is not None

    def test_failed_npu_drops_incident_links(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        victim = topo.pods()[0][0]
        incident = [l.id for l in topo.links
                    if l.src == victim or l.dst == victim]
        view = topo.degraded([], [victim])
        assert not set(incident) & set(view.links)
        assert len(view.links) == topo.num_links - len(incident)

    def test_memoized_per_event(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        assert topo.degraded([0], []) is topo.degraded([0], [])
        assert topo.degraded([0], []) is not topo.degraded([1], [])

    def test_unknown_link_rejected(self):
        topo = ring(4)
        with pytest.raises(ValueError, match="link"):
            topo.degraded([topo.num_links + 7], [])


class TestDamageClassification:
    @pytest.fixture(scope="class")
    def repairer(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        return PlanRepairer(topo, registry=AlgorithmRegistry())

    def test_pod_internal_link(self, repairer):
        ev = DegradationEvent(
            failed_links=[_internal_link(repairer.topology, 1)])
        rep = repairer.classify(ev)
        assert rep == DamageReport(pod_internal=(1,))

    def test_boundary_link(self, repairer):
        ev = DegradationEvent(
            failed_links=[repairer.topology.boundary_links()[0].id])
        assert repairer.classify(ev).boundary

    def test_gateway_vs_plain_member(self, repairer):
        topo = repairer.topology
        gw = topo.gateways(0)[0]
        plain = next(n for n in topo.pods()[0] if n not in topo.gateways(0))
        assert repairer.classify(
            DegradationEvent(failed_npus=[gw])).gateway_loss == (0,)
        assert repairer.classify(
            DegradationEvent(failed_npus=[plain])).pod_internal == (0,)

    def test_unpartitioned_fabric(self):
        rp = PlanRepairer(ring(4), registry=AlgorithmRegistry())
        assert rp.classify(DegradationEvent(failed_links=[0])).unpartitioned
        assert not rp.classify(DegradationEvent()).unpartitioned

    def test_event_normalizes_and_fingerprints(self):
        a = DegradationEvent(failed_links=[3, 1, 3], failed_npus=[2])
        assert a.failed_links == (1, 3) and bool(a)
        assert not DegradationEvent()
        assert a.fingerprint() != DegradationEvent(
            failed_links=[1]).fingerprint()


class TestRepairStrategies:
    @pytest.fixture(scope="class")
    def planned(self):
        topo = multi_pod(2, 4, 8, unit_links=True)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry(),
                          pipeline=False)
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        rp.plan(req)
        return topo, rp, req

    def test_pod_internal_link_repairs_phase_locally(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        res = rp.repair(req, ev)
        assert res.strategy == "phases"
        assert res.phases_kept >= 1 and res.phases_resynthesized >= 1
        assert res.report.pod_internal == (0,)
        # the repaired plan lives on the degraded fabric and validates
        # under both the bulk path and the reference oracle
        assert res.algorithm.topology is res.view.topology
        res.algorithm.validate(mode="bulk")
        res.algorithm.validate(mode="oracle")
        # identical per-chunk final conditions to a cold degraded synthesis
        assert _delivery(res.algorithm) == _delivery(
            _cold_degraded(topo, req, ev))

    def test_repair_serves_undamaged_pods_from_registry(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(failed_links=[_internal_link(topo, 1)])
        hits_before = rp.registry.stats.hits
        res = rp.repair(req, ev)
        assert res.strategy == "phases"
        # the undamaged pod's phase came back from the shared registry —
        # that sharing is the repair speedup, not an optimization detail
        assert rp.registry.stats.hits > hits_before

    def test_boundary_link_still_fulfils_cold_conditions(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(
            failed_links=[topo.boundary_links()[0].id])
        res = rp.repair(req, ev)
        res.algorithm.validate()
        assert _delivery(res.algorithm) == _delivery(
            _cold_degraded(topo, req, ev))

    def test_dead_member_shrinks_group(self, planned):
        topo, rp, req = planned
        victim = next(n for n in topo.pods()[0]
                      if n not in topo.gateways(0))
        ev = DegradationEvent(failed_npus=[victim])
        res = rp.repair(req, ev)
        assert victim not in res.request.group
        assert len(res.request.group) == len(req.group) - 1
        res.algorithm.validate()
        touched = {res.algorithm.topology.links[t.link].src
                   for t in res.algorithm.transfers} | \
                  {res.algorithm.topology.links[t.link].dst
                   for t in res.algorithm.transfers}
        assert victim not in touched
        assert _delivery(res.algorithm) == _delivery(_cold_degraded(
            topo, res.request, ev))

    def test_gateway_loss_falls_back_but_stays_correct(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(failed_npus=[topo.gateways(0)[0]])
        res = rp.repair(req, ev)  # survivable: pod 0 has more gateways
        assert res.strategy == "resynth"
        res.algorithm.validate()

    def test_unplanned_request_repairs_by_resynthesis(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry())
        req = CollectiveRequest("reduce_scatter", group=tuple(topo.npus))
        assert not rp.recorded(req)
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        res = rp.repair(req, ev)
        assert res.strategy == "resynth"
        res.algorithm.validate()
        assert _delivery(res.algorithm) == _delivery(
            _cold_degraded(topo, req, ev))

    def test_sole_gateway_loss_raises_loudly(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=1)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry())
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        rp.plan(req)
        ev = DegradationEvent(failed_npus=[topo.gateways(0)[0]])
        with pytest.raises(FabricDegradedError):
            rp.repair(req, ev)

    def test_cutting_every_boundary_link_raises(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry())
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        ev = DegradationEvent(
            failed_links=[l.id for l in topo.boundary_links()])
        with pytest.raises(FabricDegradedError):
            rp.repair(req, ev)

    def test_dead_reduce_root_raises(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry())
        root = topo.npus[0]
        req = CollectiveRequest("reduce", group=tuple(topo.npus), root=root)
        with pytest.raises(FabricDegradedError, match="root"):
            rp.repair(req, DegradationEvent(failed_npus=[root]))

    def test_validate_none_skips_validation_not_feasibility(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        res = rp.repair(req, ev, validate=None)
        res.algorithm.validate()  # still a correct plan, just unvalidated
        cut = DegradationEvent(
            failed_links=[l.id for l in topo.boundary_links()])
        with pytest.raises(FabricDegradedError):
            rp.repair(req, cut, validate=None)

    def test_single_transfer_corruption_flips_validation(self, planned):
        topo, rp, req = planned
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        alg = rp.repair(req, ev).algorithm
        ts = list(alg.transfers)
        t = ts[len(ts) // 2]
        ts[len(ts) // 2] = Transfer(t.chunk, t.link, t.src, t.dst,
                                    t.start, t.end + 0.5, t.reduce)
        bad = CollectiveAlgorithm(alg.topology, list(alg.conditions), ts,
                                  name=alg.name)
        with pytest.raises((ValueError, AssertionError)):
            bad.validate(mode="bulk")

    def test_nested_fabric_repairs_phase_locally(self):
        topo = three_level(2, 2, 3, unit_links=True)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry(),
                          pipeline=False)
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        rp.plan(req)
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        res = rp.repair(req, ev)
        assert res.strategy == "phases"
        res.algorithm.validate(mode="oracle")
        assert _delivery(res.algorithm) == _delivery(
            _cold_degraded(topo, req, ev))

    def test_nested_compositions_captured_for_recursive_repair(self):
        topo = three_level(2, 2, 3, unit_links=True)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry(),
                          pipeline=False)
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        rp.plan(req)
        _, record, sub = rp._records[req.fingerprint()]
        assert sub, "nested pod compositions were not captured"
        # registry-hit pods share the canonical pod's algorithm object, so
        # every pod-level phase finds its nested record by identity — the
        # match that lets a rack failure re-synthesize one rack instead of
        # re-spanning the whole pod
        for ph in record.phases:
            if ph.name == "inter":
                continue
            assert any(res is ph.algorithm for res, _ in sub), ph.name


@pytest.mark.slow
class TestRepairAtScale:
    def test_single_link_repair_512_npus(self):
        """The acceptance scenario: single rack-internal link loss on a
        512-NPU three-level All-Gather repairs phase-locally, fulfils the
        cold plan's conditions exactly, and is decisively faster than cold
        degraded-fabric resynthesis. The timing bound here is a
        conservative 3x so machine jitter cannot flake the suite; the
        committed ``fig_repair_512`` bench row records the >=5x headline."""
        import time

        topo = three_level(8, 8, 8, unit_links=True)
        rp = PlanRepairer(topo, registry=AlgorithmRegistry(),
                          pipeline=False)
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        rp.plan(req)
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        t0 = time.perf_counter()
        res = rp.repair(req, ev, validate=None)
        repair_s = time.perf_counter() - t0
        assert res.strategy == "phases"
        assert res.phases_kept > res.phases_resynthesized

        cold_topo = three_level(8, 8, 8, unit_links=True)
        dtopo = cold_topo.degraded(ev.failed_links, ev.failed_npus).topology
        ceng = SynthesisEngine(dtopo, registry=AlgorithmRegistry())
        t0 = time.perf_counter()
        cold = ceng.collective(req)
        cold_s = time.perf_counter() - t0

        res.algorithm.validate()
        cold.validate()
        assert _delivery(res.algorithm) == _delivery(cold)
        assert cold_s / repair_s >= 3.0, (
            f"repair {repair_s:.3f}s vs cold {cold_s:.3f}s")


class TestPlanServiceRepair:
    def test_repair_counts_phase_hits_and_plans_lazily(self):
        topo = multi_pod(2, 4, 8, unit_links=True)
        svc = PlanService(registry=AlgorithmRegistry())
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        res = svc.repair(topo, req, ev, pipeline=False)
        assert res.strategy == "phases"
        m = svc.metrics()
        assert m["repairs"] == 1 and m["repair_phase_hits"] == 1
        assert m["repair_fallbacks"] == 0 and m["repair_failures"] == 0

    def test_repair_failure_counted_and_raised(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        svc = PlanService(registry=AlgorithmRegistry())
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        ev = DegradationEvent(
            failed_links=[l.id for l in topo.boundary_links()])
        with pytest.raises(FabricDegradedError):
            svc.repair(topo, req, ev)
        m = svc.metrics()
        assert m["repair_failures"] == 1 and m["repair_phase_hits"] == 0


class _FakeCheckpointer:
    def __init__(self):
        self.restores = 0

    def restore(self, template, shardings=None):
        self.restores += 1
        return 7, {"w": 1}


class TestFaultToleranceWiring:
    def _manager(self, topo, svc=None):
        return FaultToleranceManager(
            checkpointer=_FakeCheckpointer(),
            planner=ElasticMeshPlanner(model_degree=4),
            make_mesh=lambda d, m: (d, m),
            plan_service=svc, topology=topo)

    def test_register_dedups_by_fingerprint(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        ftm = self._manager(topo)
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        ftm.register_collective(req)
        ftm.register_collective(
            CollectiveRequest("all_gather", group=tuple(topo.npus)))
        assert len(ftm._collectives) == 1

    def test_replan_needs_service_and_topology(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        ftm = self._manager(topo, svc=None)
        ftm.register_collective(
            CollectiveRequest("all_gather", group=tuple(topo.npus)))
        with pytest.raises(RuntimeError, match="plan_service"):
            ftm.replan_collectives(DegradationEvent(failed_links=[0]))

    def test_recover_replans_registered_collectives(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        ftm = self._manager(topo, svc=PlanService(
            registry=AlgorithmRegistry()))
        req = CollectiveRequest("all_gather", group=tuple(topo.npus))
        ftm.register_collective(req)
        ev = DegradationEvent(failed_links=[_internal_link(topo, 0)])
        step, state, mesh = ftm.recover(
            {}, len(topo.npus), lambda mesh: {}, degradation=ev)
        assert step == 7 and mesh == (len(topo.npus) // 4, 4)
        assert req.fingerprint() in ftm.replanned
        ftm.replanned[req.fingerprint()].algorithm.validate()

    def test_unfulfillable_fabric_fails_before_restore(self):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        ftm = self._manager(topo, svc=PlanService(
            registry=AlgorithmRegistry()))
        ftm.register_collective(
            CollectiveRequest("all_gather", group=tuple(topo.npus)))
        cut = DegradationEvent(
            failed_links=[l.id for l in topo.boundary_links()])
        with pytest.raises(FabricDegradedError):
            ftm.recover({}, len(topo.npus), lambda mesh: {},
                        degradation=cut)
        assert ftm.checkpointer.restores == 0
