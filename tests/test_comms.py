"""Comms layer tests.

The multi-device executor needs >1 host device, and jax locks the device
count at first init — so the numerical selftest runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8. Pure-function pieces
(translation, buffer planning, compression) are tested in-process.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.comms.executor import plan_buffers
from repro.core import synthesize_all_gather, synthesize_all_to_all, to_ppermute_program
from repro.core.synthesizer import synthesize_all_reduce
from repro.topology import ring, torus2d


class TestTranslation:
    def test_rounds_are_permutations(self):
        topo = torus2d(3, 3)
        alg = synthesize_all_to_all(topo, list(range(9)))
        prog = to_ppermute_program(alg)
        for rnd in prog.rounds:
            srcs = [s.src for s in rnd]
            dsts = [s.dst for s in rnd]
            assert len(srcs) == len(set(srcs)), "src appears twice in a round"
            assert len(dsts) == len(set(dsts)), "dst appears twice in a round"

    def test_rounds_preserve_transfer_count(self):
        topo = ring(6, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(6)))
        prog = to_ppermute_program(alg)
        assert sum(len(r) for r in prog.rounds) == alg.num_transfers

    def test_rounds_causal(self):
        """A chunk is never sent by a device before a round in which that
        device held/received it."""
        topo = torus2d(3, 3)
        alg = synthesize_all_reduce(topo, list(range(9)))
        prog = to_ppermute_program(alg)
        holders = {c: set(h) for c, h in prog.chunk_holders.items()}
        for rnd in prog.rounds:
            for s in rnd:
                assert s.src in holders[s.chunk], f"premature send {s}"
            for s in rnd:
                holders[s.chunk].add(s.dst)

    def test_buffer_plan_slots(self):
        topo = ring(4, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(4)))
        prog = to_ppermute_program(alg)
        plan = plan_buffers(prog)
        assert plan.num_slots >= 4  # every device ends with all 4 chunks
        # every destination has a slot for its chunk
        for chunk, dests in prog.chunk_dests.items():
            for d in dests:
                assert (d, chunk) in plan.slot_of


@pytest.mark.slow
class TestMultiDeviceExecutor:
    def test_selftest_subprocess(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        root = os.path.join(os.path.dirname(__file__), "..")
        env["PYTHONPATH"] = os.path.join(root, "src")
        res = subprocess.run(
            [sys.executable, "-m", "repro.comms.selftest"],
            capture_output=True,
            text=True,
            env=env,
            timeout=600,
        )
        assert res.returncode == 0, res.stdout + res.stderr
        assert "ALL PASS" in res.stdout


class TestCompression:
    def test_int8_roundtrip_error_feedback(self):
        import jax.numpy as jnp

        from repro.comms import ef_int8_compress, ef_int8_decompress

        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
        r = jnp.zeros_like(g)
        total_in, total_out = jnp.zeros_like(g), jnp.zeros_like(g)
        for _ in range(50):
            q, scale, r = ef_int8_compress(g, r)
            total_in = total_in + g
            total_out = total_out + ef_int8_decompress(q, scale)
        # error feedback keeps the long-run sum faithful
        drift = np.abs(np.asarray(total_out + r - total_in)).max()
        assert drift < 1e-3

    def test_topk_roundtrip(self):
        import jax.numpy as jnp

        from repro.comms import topk_compress, topk_decompress

        g = jnp.asarray(np.arange(16, dtype=np.float32) - 8.0)
        r = jnp.zeros_like(g)
        vals, idx, r2 = topk_compress(g, r, k=4)
        dec = topk_decompress(vals, idx, (16,))
        # top-4 magnitudes survive; the rest land in the residual
        assert np.count_nonzero(np.asarray(dec)) == 4
        np.testing.assert_allclose(np.asarray(dec + r2), np.asarray(g), atol=1e-6)
