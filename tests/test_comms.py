"""Comms layer tests.

The multi-device executor needs >1 host device, and jax locks the device
count at first init — so the numerical selftest runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8. Pure-function pieces
(translation, buffer planning, compression) are tested in-process.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.comms.executor import plan_buffers
from repro.core import synthesize_all_gather, synthesize_all_to_all, to_ppermute_program
from repro.core.synthesizer import synthesize_all_reduce
from repro.topology import ring, torus2d


class TestTranslation:
    def test_rounds_are_permutations(self):
        topo = torus2d(3, 3)
        alg = synthesize_all_to_all(topo, list(range(9)))
        prog = to_ppermute_program(alg)
        for rnd in prog.rounds:
            srcs = [s.src for s in rnd]
            dsts = [s.dst for s in rnd]
            assert len(srcs) == len(set(srcs)), "src appears twice in a round"
            assert len(dsts) == len(set(dsts)), "dst appears twice in a round"

    def test_rounds_preserve_transfer_count(self):
        topo = ring(6, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(6)))
        prog = to_ppermute_program(alg)
        assert sum(len(r) for r in prog.rounds) == alg.num_transfers

    def test_rounds_causal(self):
        """A chunk is never sent by a device before a round in which that
        device held/received it."""
        topo = torus2d(3, 3)
        alg = synthesize_all_reduce(topo, list(range(9)))
        prog = to_ppermute_program(alg)
        holders = {c: set(h) for c, h in prog.chunk_holders.items()}
        for rnd in prog.rounds:
            for s in rnd:
                assert s.src in holders[s.chunk], f"premature send {s}"
            for s in rnd:
                holders[s.chunk].add(s.dst)

    def test_buffer_plan_slots(self):
        topo = ring(4, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(4)))
        prog = to_ppermute_program(alg)
        plan = plan_buffers(prog)
        assert plan.num_slots >= 4  # every device ends with all 4 chunks
        # every destination has a slot for its chunk
        for chunk, dests in prog.chunk_dests.items():
            for d in dests:
                assert (d, chunk) in plan.slot_of


def _simulate(plan, buf):
    """Numpy interpreter with the exact executor semantics: per round every
    device sends buf[send_slot]; non-destinations receive ppermute zeros;
    the received value lands at recv_slot (the trash slot for
    non-receivers), added when is_reduce else overwriting."""
    n = plan.num_devices
    dev = np.arange(n)
    for rt in plan.rounds:
        sent = buf[dev, rt.send_slot]
        got = np.zeros_like(sent)
        for s, d in rt.perm:
            got[d] = sent[s]
        old = buf[dev, rt.recv_slot]
        new = np.where(rt.is_reduce[:, None], old + got, got)
        buf[dev, rt.recv_slot] = new
    return buf


class TestSwitchUnrolling:
    """Switch-riding schedules (multi_pod DCI and friends) lower to direct
    NPU-to-NPU ppermute programs; numerics checked with the numpy
    interpreter so tier-1 covers them without a multi-device jax."""

    def _topo(self):
        from repro.topology.generators import multi_pod

        return multi_pod(2, 2, 2, unit_links=True, dci_ports_per_pod=2)

    def _alg(self, kind, topo, **kw):
        from repro.core import CollectiveRequest, SynthesisEngine

        n = len(topo.npus)
        req = CollectiveRequest(kind, group=tuple(range(n)),
                                hierarchy="always", **kw)
        alg = SynthesisEngine(topo).collective(req)
        alg.validate()
        return alg

    def test_strict_mode_still_raises(self):
        topo = self._topo()
        alg = self._alg("all_gather", topo)
        with pytest.raises(ValueError, match="NPU-to-NPU"):
            to_ppermute_program(alg, unroll_switches=False)

    def test_unrolled_endpoints_are_devices(self):
        topo = self._topo()
        for kind in ("all_gather", "reduce_scatter", "all_reduce",
                     "all_to_all"):
            prog = to_ppermute_program(self._alg(kind, topo))
            for rnd in prog.rounds:
                for s in rnd:
                    assert 0 <= s.src < prog.num_devices
                    assert 0 <= s.dst < prog.num_devices
                    assert s.src != s.dst

    def test_unrolled_rounds_causal(self):
        topo = self._topo()
        prog = to_ppermute_program(self._alg("all_reduce", topo))
        holders = {c: set(h) for c, h in prog.chunk_holders.items()}
        for rnd in prog.rounds:
            for s in rnd:
                assert s.src in holders[s.chunk], f"premature send {s}"
            for s in rnd:
                holders[s.chunk].add(s.dst)

    def test_all_gather_numerics_through_dci(self):
        topo = self._topo()
        n = len(topo.npus)
        prog = to_ppermute_program(self._alg("all_gather", topo))
        plan = plan_buffers(prog)
        chunk_of = {src: c for c, src in prog.chunk_srcs.items()}
        rng = np.random.default_rng(3)
        x = rng.standard_normal((n, 2))
        buf = np.zeros((n, plan.buffer_slots, 2))
        for d in range(n):
            buf[d, plan.slot_of[(d, chunk_of[d])]] = x[d]
        buf = _simulate(plan, buf)
        for d in range(n):
            for src in range(n):
                got = buf[d, plan.slot_of[(d, chunk_of[src])]]
                np.testing.assert_array_equal(got, x[src])

    def test_all_reduce_numerics_through_dci(self):
        topo = self._topo()
        n = len(topo.npus)
        prog = to_ppermute_program(self._alg("all_reduce", topo))
        plan = plan_buffers(prog)
        chunks = sorted(prog.chunk_holders)
        rng = np.random.default_rng(4)
        x = rng.standard_normal((n, len(chunks), 2))
        buf = np.zeros((n, plan.buffer_slots, 2))
        for ci, c in enumerate(chunks):
            for d in range(n):
                got = plan.slot_of.get((d, c))
                if got is not None:
                    buf[d, got] = x[d, ci]
        buf = _simulate(plan, buf)
        for ci, c in enumerate(chunks):
            want = x[:, ci].sum(axis=0)
            for d in range(n):
                np.testing.assert_allclose(
                    buf[d, plan.slot_of[(d, c)]], want, atol=1e-9)


class TestPlanCache:
    def _prog(self, n):
        topo = ring(n, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(n)))
        return to_ppermute_program(alg)

    def test_colliding_fingerprints_do_not_cross_serve(self):
        """Regression: two distinct programs handed the same caller
        fingerprint must each get their own plan (the cache also keys on
        the program's structural digest)."""
        from repro.comms import clear_plan_cache, plan_buffers_cached

        clear_plan_cache()
        p4, p6 = self._prog(4), self._prog(6)
        a = plan_buffers_cached(p4, "same-fp")
        b = plan_buffers_cached(p6, "same-fp")
        assert a.num_devices == 4
        assert b.num_devices == 6
        # and both entries still hit
        assert plan_buffers_cached(p4, "same-fp") is a
        assert plan_buffers_cached(p6, "same-fp") is b

    def test_digest_distinguishes_programs(self):
        p4, p4b, p6 = self._prog(4), self._prog(4), self._prog(6)
        assert p4.digest() == p4b.digest()
        assert p4.digest() != p6.digest()

    def test_hit_miss_stats(self):
        from repro.comms import (
            clear_plan_cache,
            plan_buffers_cached,
            plan_cache_stats,
        )

        clear_plan_cache()
        p = self._prog(5)
        plan_buffers_cached(p, "fp")
        plan_buffers_cached(p, "fp")
        assert plan_cache_stats == {"hits": 1, "misses": 1}

    def test_thread_safety_under_eviction_churn(self, monkeypatch):
        """Many threads sharing a tiny cache: every served plan must match
        its program, and no internal state corruption may raise."""
        from concurrent.futures import ThreadPoolExecutor

        from repro.comms import executor as ex

        monkeypatch.setattr(ex, "_PLAN_CACHE_MAX", 4)
        ex.clear_plan_cache()
        progs = [self._prog(n) for n in (4, 5, 6, 7, 8, 9)]

        def worker(i):
            for j in range(40):
                k = (i * 7 + j) % len(progs)
                p = progs[k]
                plan = ex.plan_buffers_cached(p, f"fp{k}")
                assert plan.num_devices == p.num_devices
                for c, dests in p.chunk_dests.items():
                    assert (dests[0], c) in plan.slot_of
            return True

        with ThreadPoolExecutor(max_workers=8) as pool:
            assert all(pool.map(worker, range(16)))
        ex.clear_plan_cache()


@pytest.mark.slow
class TestMultiDeviceExecutor:
    def test_selftest_subprocess(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        root = os.path.join(os.path.dirname(__file__), "..")
        env["PYTHONPATH"] = os.path.join(root, "src")
        res = subprocess.run(
            [sys.executable, "-m", "repro.comms.selftest"],
            capture_output=True,
            text=True,
            env=env,
            timeout=600,
        )
        assert res.returncode == 0, res.stdout + res.stderr
        assert "ALL PASS" in res.stdout


class TestCompression:
    def test_int8_roundtrip_error_feedback(self):
        import jax.numpy as jnp

        from repro.comms import ef_int8_compress, ef_int8_decompress

        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
        r = jnp.zeros_like(g)
        total_in, total_out = jnp.zeros_like(g), jnp.zeros_like(g)
        for _ in range(50):
            q, scale, r = ef_int8_compress(g, r)
            total_in = total_in + g
            total_out = total_out + ef_int8_decompress(q, scale)
        # error feedback keeps the long-run sum faithful
        drift = np.abs(np.asarray(total_out + r - total_in)).max()
        assert drift < 1e-3

    def test_topk_roundtrip(self):
        import jax.numpy as jnp

        from repro.comms import topk_compress, topk_decompress

        g = jnp.asarray(np.arange(16, dtype=np.float32) - 8.0)
        r = jnp.zeros_like(g)
        vals, idx, r2 = topk_compress(g, r, k=4)
        dec = topk_decompress(vals, idx, (16,))
        # top-4 magnitudes survive; the rest land in the residual
        assert np.count_nonzero(np.asarray(dec)) == 4
        np.testing.assert_allclose(np.asarray(dec + r2), np.asarray(g), atol=1e-6)
