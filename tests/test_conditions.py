"""Tests for the condition builders — notably ``all_to_allv``, which drives
MoE expert-parallel dispatch and previously had no coverage."""


from repro.core import ChunkIds, all_to_allv, synthesize
from repro.topology import ring, torus2d


class TestAllToAllv:
    def test_dict_counts(self):
        group = [0, 1, 2]
        counts = {(0, 1): 2, (1, 2): 1, (2, 0): 3}
        conds = all_to_allv(group, counts)
        by_pair = {}
        for c in conds:
            (dst,) = c.dests
            by_pair[(c.src, dst)] = by_pair.get((c.src, dst), 0) + 1
        assert by_pair == counts

    def test_matrix_counts(self):
        group = [5, 7, 9]  # non-contiguous NPU ids: matrix is by group index
        counts = [
            [0, 1, 2],
            [3, 0, 0],
            [1, 1, 0],
        ]
        conds = all_to_allv(group, counts)
        by_pair = {}
        for c in conds:
            (dst,) = c.dests
            by_pair[(c.src, dst)] = by_pair.get((c.src, dst), 0) + 1
        assert by_pair == {(5, 7): 1, (5, 9): 2, (7, 5): 3, (9, 5): 1,
                           (9, 7): 1}

    def test_zero_count_pairs_skipped(self):
        conds = all_to_allv([0, 1, 2], {(0, 1): 0, (1, 2): 2})
        assert len(conds) == 2
        assert all(next(iter(c.dests)) == 2 for c in conds)

    def test_diagonal_ignored(self):
        # self-sends carry no network traffic in either count form
        assert all_to_allv([0, 1], {(0, 0): 5, (0, 1): 1}) != []
        assert len(all_to_allv([0, 1], {(0, 0): 5, (0, 1): 1})) == 1
        assert len(all_to_allv([0, 1], [[4, 0], [0, 4]])) == 0

    def test_chunk_ids_unique_and_allocator_shared(self):
        ids = ChunkIds(100)
        a = all_to_allv([0, 1, 2], {(0, 1): 3, (2, 1): 2}, ids=ids)
        b = all_to_allv([0, 1, 2], {(1, 0): 2}, ids=ids)
        chunks = [c.chunk for c in a + b]
        assert len(chunks) == len(set(chunks)) == 7
        assert min(chunks) == 100  # drawn from the caller's allocator

    def test_deterministic_order(self):
        counts = {(2, 0): 1, (0, 1): 2, (1, 2): 1}
        c1 = all_to_allv([0, 1, 2], dict(counts))
        c2 = all_to_allv([0, 1, 2], dict(reversed(list(counts.items()))))
        assert [(c.src, tuple(c.dests)) for c in c1] == \
            [(c.src, tuple(c.dests)) for c in c2]

    def test_bytes_and_tag_propagate(self):
        conds = all_to_allv([0, 1], {(0, 1): 2}, bytes=4.0, tag="moe")
        assert all(c.bytes == 4.0 and c.tag == "moe" for c in conds)

    def test_synthesizes_and_validates(self):
        topo = torus2d(3, 3)
        counts = {(i, j): (i + j) % 3 for i in range(9) for j in range(9)
                  if i != j}
        conds = all_to_allv(list(range(9)), counts)
        alg = synthesize(topo, conds)
        alg.validate()
        delivered = {c.chunk for c in alg.conditions}
        assert len(delivered) == sum(counts.values())

    def test_empty_counts(self):
        assert all_to_allv([0, 1, 2], {}) == []
        conds = all_to_allv(list(range(4)), [[0] * 4 for _ in range(4)])
        assert conds == []

    def test_ring_delivery(self):
        topo = ring(4)
        conds = all_to_allv([0, 1, 2, 3], {(0, 2): 2, (3, 1): 1})
        alg = synthesize(topo, conds)
        alg.validate()
        assert alg.makespan >= 2.0  # two hops minimum on the ring
