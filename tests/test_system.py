"""End-to-end behaviour tests for the PCCL system: synthesize -> validate ->
translate -> evaluate, on the production pod topology."""


from repro.core import (
    ChunkIds,
    all_to_all,
    all_to_allv,
    direct_all_to_all,
    replay_algorithm,
    synthesize,
    synthesize_all_to_all,
    synthesize_joint,
    to_msccl_json,
    to_ppermute_program,
)
from repro.topology import tpu_v5e_pod, mesh2d


class TestEndToEnd:
    def test_pod_row_all_to_all(self):
        """A2A over one 'model axis' row of an 8x8 pod slice: synthesize,
        validate, translate to a ppermute program."""
        topo = tpu_v5e_pod(8, 8)
        row = list(range(8))
        alg = synthesize_all_to_all(topo, row, bytes=1.0)
        alg.validate()
        prog = to_ppermute_program(alg)
        assert prog.num_rounds >= 1
        sends = [s for r in prog.rounds for s in r]
        assert len(sends) == alg.num_transfers

    def test_pod_concurrent_row_groups(self):
        """Every row of a 4x4 pod runs its own A2A concurrently (the EP
        scenario of paper Fig 16/19), synthesized jointly."""
        topo = tpu_v5e_pod(4, 4)
        ids = ChunkIds()
        groups = []
        for r in range(4):
            row = [r * 4 + c for c in range(4)]
            groups.append((f"row{r}", all_to_all(row, ids=ids, bytes=1.0)))
        alg = synthesize_joint(topo, groups)
        alg.validate()

    def test_process_group_speedup_claim(self):
        """Paper Fig 16: PG-aware PCCL vs Direct on 2D mesh, PG size = width.
        The paper reports 2.33-3.03x; we assert a sound >1.15x on 6x6."""
        topo = mesh2d(6, 6)
        group = list(range(6))  # one row
        pccl = synthesize_all_to_all(topo, group)
        pccl.validate()
        direct = direct_all_to_all(topo, group)
        speedup = direct.makespan / pccl.makespan
        assert speedup > 1.15, f"speedup {speedup:.2f}"

    def test_msccl_json_export(self):
        import json

        topo = mesh2d(3, 3)
        alg = synthesize_all_to_all(topo, [0, 1, 2])
        doc = json.loads(to_msccl_json(alg))
        assert doc["num_npus"] == 9
        ops = [o for g in doc["gpus"] for o in g["ops"]]
        assert any(o["op"] == "send" for o in ops)
        assert any(o["op"] == "recv" for o in ops)

    def test_moe_dispatch_alltoallv(self):
        """MoE expert dispatch = All-to-Allv with imbalanced counts (paper §2.1)."""
        topo = tpu_v5e_pod(4, 4)
        ep_group = [0, 1, 2, 3]
        counts = [[0, 3, 1, 1], [2, 0, 2, 1], [1, 1, 0, 3], [1, 2, 1, 0]]
        conds = all_to_allv(ep_group, counts)
        alg = synthesize(topo, conds)
        alg.validate()
        replay = replay_algorithm(alg)
        assert replay.makespan == alg.makespan
