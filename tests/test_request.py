"""The CollectiveRequest API redesign and the unified PCCLError surface.

Three contracts:

1. **Validation.** A :class:`CollectiveRequest` is a frozen value object
   that rejects malformed descriptions at construction (unknown kind,
   non-positive bytes, root on a non-reduce, ...), so every downstream
   layer can trust a request it receives.
2. **Equivalence.** The legacy per-call kwargs and the request form
   produce bit-identical schedules through the *same* registry entries —
   the redesign changes the call surface, not the plans — and explicitly
   passing a legacy tuning kwarg warns :class:`PCCLDeprecationWarning`
   (escalated to an error for repro-internal call sites by pyproject).
3. **Error surface.** Every domain error derives from :class:`PCCLError`,
   and the silent flat-fallback rules hold: ``HierarchyError`` is advisory
   (the auto route may fall back flat), ``SketchInfeasibleError`` and
   ``FabricDegradedError`` are hard (no fallback may swallow them).
"""

import warnings

import numpy as np
import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveRequest,
    FabricDegradedError,
    HierarchyError,
    PCCLDeprecationWarning,
    PCCLError,
    SketchInfeasibleError,
    SynthesisEngine,
    synthesize_all_gather,
    synthesize_all_to_all,
)
from repro.topology import multi_pod, ring, torus2d

LEGACY_OK = "ignore::repro.core.request.PCCLDeprecationWarning"


def _same_schedule(a, b) -> bool:
    ca, cb = a.columns, b.columns
    return all(
        np.array_equal(getattr(ca, f), getattr(cb, f))
        for f in ("chunk", "link", "src", "dst", "start", "end", "reduce"))


class TestRequestValidation:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            CollectiveRequest("all_gatherr", group=(0, 1))

    def test_nonpositive_bytes_rejected(self):
        with pytest.raises(ValueError, match="bytes"):
            CollectiveRequest("all_gather", group=(0, 1), bytes=0.0)

    def test_chunks_below_one_rejected(self):
        with pytest.raises(ValueError, match="chunks"):
            CollectiveRequest("all_gather", group=(0, 1), chunks=0)

    def test_bad_hierarchy_rejected(self):
        with pytest.raises(ValueError, match="hierarchy"):
            CollectiveRequest("all_gather", group=(0, 1), hierarchy="maybe")

    def test_reduce_requires_root_in_group(self):
        with pytest.raises(ValueError, match="root"):
            CollectiveRequest("reduce", group=(0, 1))
        with pytest.raises(ValueError, match="root"):
            CollectiveRequest("reduce", group=(0, 1), root=7)
        req = CollectiveRequest("reduce", group=(0, 1), root=1)
        assert req.root == 1

    def test_root_on_non_reduce_rejected(self):
        with pytest.raises(ValueError, match="root"):
            CollectiveRequest("all_gather", group=(0, 1), root=0)

    def test_pipelined_only_for_all_reduce(self):
        with pytest.raises(ValueError, match="pipelined"):
            CollectiveRequest("all_gather", group=(0, 1), pipelined=True)
        CollectiveRequest("all_reduce", group=(0, 1), pipelined=True)

    def test_sketch_must_quack(self):
        with pytest.raises(TypeError, match="sketch"):
            CollectiveRequest("all_gather", group=(0, 1), sketch=object())

    def test_frozen_and_group_normalized(self):
        req = CollectiveRequest("all_gather",
                                group=np.asarray([2, 0, 1], np.int64))
        assert req.group == (2, 0, 1)
        assert all(type(n) is int for n in req.group)
        with pytest.raises(AttributeError):
            req.bytes = 2.0

    def test_with_group_binds_without_mutation(self):
        base = CollectiveRequest("all_gather", bytes=2.0)
        bound = base.with_group([3, 4, 5])
        assert base.group == () and bound.group == (3, 4, 5)
        assert bound.bytes == 2.0

    def test_fingerprint_identity(self):
        a = CollectiveRequest("all_gather", group=(0, 1), bytes=2.0)
        b = CollectiveRequest("all_gather", group=(0, 1), bytes=2.0)
        c = CollectiveRequest("all_gather", group=(0, 1), bytes=3.0)
        assert a.fingerprint() == b.fingerprint()
        assert a.fingerprint() != c.fingerprint()
        assert a.fingerprint() != a.with_group((0, 2)).fingerprint()


class TestLegacyShimEquivalence:
    """Old kwargs and new requests must be two spellings of one plan."""

    @pytest.mark.filterwarnings(LEGACY_OK)
    def test_all_gather_same_registry_entry_and_columns(self):
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(torus2d(4, 4), registry=reg)
        legacy = eng.all_gather(list(range(4)), bytes=2.0, chunks_per_npu=2)
        misses = reg.stats.misses
        new = eng.collective(CollectiveRequest(
            "all_gather", group=tuple(range(4)), bytes=2.0, chunks=2))
        assert reg.stats.misses == misses, "request form missed the cache"
        assert reg.stats.hits >= 1
        assert _same_schedule(legacy, new)

    @pytest.mark.filterwarnings(LEGACY_OK)
    def test_pipelined_all_reduce_equivalent(self):
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(torus2d(4, 4), registry=reg)
        legacy = eng.all_reduce(list(range(4)), pipelined=True)
        misses = reg.stats.misses
        new = eng.collective(CollectiveRequest(
            "all_reduce", group=tuple(range(4)), pipelined=True))
        assert reg.stats.misses == misses
        assert _same_schedule(legacy, new)

    def test_reduce_request_carries_root(self):
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(torus2d(4, 4), registry=reg)
        legacy = eng.reduce(list(range(4)), 2)
        new = eng.collective(CollectiveRequest(
            "reduce", group=tuple(range(4)), root=2))
        assert _same_schedule(legacy, new)

    def test_explicit_legacy_kwarg_warns(self):
        eng = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
        with pytest.warns(PCCLDeprecationWarning, match="deprecated"):
            eng.all_gather(list(range(4)), bytes=2.0)

    def test_bare_named_call_stays_silent_sugar(self):
        eng = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            eng.all_gather(list(range(4))).validate()

    def test_module_wrappers_are_warning_free(self):
        topo = torus2d(4, 4)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            synthesize_all_gather(topo, list(range(4)),
                                  chunks_per_npu=2).validate()
            synthesize_all_to_all(topo, list(range(4)),
                                  chunks_per_pair=2,
                                  hierarchy="never").validate()

    def test_request_of_wrong_kind_rejected_by_named_method(self):
        eng = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
        req = CollectiveRequest("all_to_all", group=tuple(range(4)))
        with pytest.raises(ValueError, match="all_to_all"):
            eng.all_gather(req)

    def test_request_plus_kwargs_rejected(self):
        eng = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
        req = CollectiveRequest("all_gather", group=tuple(range(4)))
        with pytest.raises(TypeError, match="CollectiveRequest"):
            eng.all_gather(req, bytes=2.0)

    def test_empty_group_request_rejected_at_synthesis(self):
        eng = SynthesisEngine(torus2d(4, 4), registry=AlgorithmRegistry())
        with pytest.raises(ValueError, match="empty group"):
            eng.collective(CollectiveRequest("all_gather"))


class TestPlannerRequestPath:
    @pytest.fixture(scope="class")
    def planner(self):
        from repro.launch.sharding import MeshCollectivePlanner

        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        return MeshCollectivePlanner(topo, {"data": 4, "model": 4},
                                     registry=AlgorithmRegistry())

    def test_request_and_legacy_agree(self, planner):
        via_name = planner.algorithm("all_gather", "model", 0)
        via_req = planner.algorithm(
            CollectiveRequest("all_gather"), "model", 0)
        assert _same_schedule(via_name, via_req)

    def test_request_with_tuning_kwargs_rejected(self, planner):
        with pytest.raises(TypeError, match="CollectiveRequest"):
            planner.algorithm(CollectiveRequest("all_gather"), "model", 0,
                              hierarchy="never")


class TestErrorSurface:
    def test_hierarchy_of_domain_errors(self):
        assert issubclass(HierarchyError, PCCLError)
        assert issubclass(SketchInfeasibleError, PCCLError)
        assert issubclass(FabricDegradedError, PCCLError)
        # the load-bearing distinction: a sketch violation must never ride
        # the HierarchyError flat-fallback path
        assert not issubclass(SketchInfeasibleError, HierarchyError)
        assert not issubclass(FabricDegradedError, HierarchyError)
        # catchable with stdlib idioms at serving boundaries
        assert issubclass(FabricDegradedError, RuntimeError)
        assert issubclass(HierarchyError, ValueError)

    def test_auto_route_may_swallow_hierarchy_error(self):
        # ring has no partition: the hierarchical route refuses, auto
        # falls back flat — the advisory end of the contract
        eng = SynthesisEngine(ring(4), registry=AlgorithmRegistry())
        alg = eng.collective(CollectiveRequest(
            "all_gather", group=tuple(range(4))))
        alg.validate()
        assert alg.name == "pccl_all_gather"

    def test_pinned_route_raises_catchable_as_pccl_error(self):
        eng = SynthesisEngine(ring(4), registry=AlgorithmRegistry())
        with pytest.raises(PCCLError):
            eng.collective(CollectiveRequest(
                "all_gather", group=tuple(range(4)), hierarchy="always"))
