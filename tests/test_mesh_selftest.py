"""The multi-device executor selftest, promoted into tier-visible pytest.

``repro.comms.selftest`` historically ran only as ``python -m`` in a
subprocess; here each of its checks is a parametrized ``mesh``-marked test,
so its assertions count whenever >= 8 devices are available (the CI mesh
job) and skip cleanly otherwise. The selftest module is imported lazily
inside the test body: importing it sets a default ``XLA_FLAGS``, which must
not happen during collection of a single-device run.
"""

import pytest

pytestmark = pytest.mark.mesh

CASES = [
    "test_all_gather_ring",
    "test_all_gather_subgroup_with_forwarding",
    "test_all_reduce",
    "test_reduce_scatter",
    "test_all_to_all_torus_rows",
    "test_all_to_all_subgroup",
    "test_two_axis_flattened",
]


@pytest.mark.parametrize("case", CASES)
def test_selftest(case):
    from repro.comms import selftest

    getattr(selftest, case)()


def test_selftest_main_lists_every_case():
    """Keep this parametrization in sync with the selftest's own main()."""
    from repro.comms import selftest

    import inspect

    src = inspect.getsource(selftest.main)
    missing = [c for c in CASES if c not in src]
    assert not missing, f"selftest.main() missing {missing}"
    defined = [n for n in dir(selftest) if n.startswith("test_")]
    uncovered = sorted(set(defined) - set(CASES))
    assert not uncovered, f"selftest checks not promoted to pytest: {uncovered}"
