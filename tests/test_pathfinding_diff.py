"""Differential tests: the batched event-frontier ``bfs_int`` must be
bit-identical to the reference per-timestep scan ``bfs_int_ref`` — same
pruned transfers, same arrivals, same reached times, same makespans — on
every topology class and on random pre-committed TEN states (the acceptance
gate for the array-backed synthesis core)."""

import pytest

from repro.core import all_gather, all_to_all
from repro.core.conditions import ChunkIds, Condition
from repro.core.engine import SynthesisEngine
from repro.core.pathfinding import bfs_cont, bfs_int, bfs_int_ref
from repro.core.ten import TEN
from repro.topology import (
    hypercube,
    line,
    mesh2d,
    ring,
    star_switch,
    torus2d,
)
from repro.topology.topology import Topology


def assert_same(ra, rb, ctx=""):
    assert ra.transfers == rb.transfers, ctx
    assert ra.arrivals == rb.arrivals, ctx
    assert ra.reached == rb.reached, ctx


def run_differential(topo, conds):
    """Drive a full greedy synthesis, comparing both searches per condition
    on identical TEN states (commits follow the reference result)."""
    engine = SynthesisEngine(topo)
    ten_ref, ten_new = TEN(topo), TEN(topo)
    for c in engine.order_conditions(conds):
        ra = bfs_int_ref(ten_ref, c)
        rb = bfs_int(ten_new, c)
        assert_same(ra, rb, ctx=f"{topo.name}: {c}")
        engine._commit(ten_ref, ra, True)
        engine._commit(ten_new, rb, True)


TOPOLOGIES = [
    pytest.param(lambda: ring(6), id="ring6"),
    pytest.param(lambda: ring(5, bidirectional=True), id="ring5bidir"),
    pytest.param(lambda: line(5), id="line5"),
    pytest.param(lambda: mesh2d(3, 4), id="mesh3x4"),
    pytest.param(lambda: mesh2d(5, 5), id="mesh5x5"),
    pytest.param(lambda: torus2d(4, 4), id="torus4x4"),
    pytest.param(lambda: hypercube(3), id="hypercube3"),
    pytest.param(lambda: star_switch(5), id="star5"),
    pytest.param(lambda: star_switch(5, multicast=False), id="star5serial"),
    pytest.param(lambda: star_switch(6, buffer_limit=1), id="star6buf1"),
    pytest.param(
        lambda: star_switch(6, buffer_limit=2, multicast=False),
        id="star6buf2serial",
    ),
]


@pytest.mark.parametrize("make", TOPOLOGIES)
def test_all_to_all_differential(make):
    topo = make()
    run_differential(topo, all_to_all(topo.npus))


@pytest.mark.parametrize("make", TOPOLOGIES)
def test_all_gather_differential(make):
    topo = make()
    run_differential(topo, all_gather(topo.npus))


def test_process_group_differential():
    # conditions routed through out-of-group NPUs
    topo = mesh2d(3, 3)
    run_differential(topo, all_gather([0, 2, 8]))
    run_differential(topo, all_to_all([0, 4, 8]))


def test_release_times_differential():
    topo = mesh2d(3, 3)
    ids = ChunkIds()
    conds = [
        Condition(ids.next(), 0, frozenset([8]), release=3.0),
        Condition(ids.next(), 8, frozenset([0]), release=0.0),
        Condition(ids.next(), 4, frozenset([0, 8]), release=1.0),
    ]
    run_differential(topo, conds)


def test_synthesized_algorithms_identical():
    """Whole-pipeline check: identical transfer schedules and makespans."""
    import repro.core.engine as eng

    topo = mesh2d(4, 4)
    group = list(range(16))
    new_alg = SynthesisEngine(topo).all_to_all(group)
    orig = eng.bfs_int
    eng.bfs_int = bfs_int_ref
    try:
        ref_alg = SynthesisEngine(topo).all_to_all(group)
    finally:
        eng.bfs_int = orig
    assert new_alg.transfers == ref_alg.transfers
    assert new_alg.makespan == ref_alg.makespan


def test_unreachable_raises_same():
    topo = Topology("disc")
    topo.add_npus(2)  # no links
    cond = Condition(0, 0, frozenset([1]))
    with pytest.raises(AssertionError, match="unreachable"):
        bfs_int_ref(TEN(topo), cond)
    with pytest.raises(AssertionError, match="unreachable"):
        bfs_int(TEN(topo), cond)


def test_continuous_still_matches_on_homogeneous():
    topo = mesh2d(3, 3)
    cond = Condition(0, 0, frozenset(range(9)))
    assert bfs_int(TEN(topo), cond).reached == bfs_cont(TEN(topo), cond).reached
