"""End-to-end numerical conformance of executed PCCL plans on a jax mesh.

The differential harness the executor work hangs off: every collective kind
x synthesis route x process-group shape runs as a shard_map ppermute program
on an 8-device host mesh and is compared against ``jax.lax`` built-ins
and/or pure-numpy references — bit-identical for data-movement collectives,
fixed-order tolerance for reductions. Routes cover flat, hierarchical
sequential, chunk-pipelined, switch-unrolled (multi_pod DCI), TE-routed,
time-reversed (reduce_scatter *is* the time-reversed all_gather route), and
``PlanRepairer``-repaired plans; strict-subset process groups check that
non-participant buffers come back untouched even when those devices forward
traffic for the group.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest -m mesh``.
"""

from __future__ import annotations

import numpy as np
import pytest

from _exec_harness import (
    KINDS,
    assert_conformant,
    check_collective,
    make_input,
    reference,
    run_on_mesh,
)

pytestmark = pytest.mark.mesh

N = 8

# route name -> (topology builder, CollectiveRequest keywords). The two
# multi_pod routes traverse a DCI switch node, so they only execute through
# the translator's switch unrolling; te_multipod additionally forces the
# traffic-engineered gateway assignment on skewed uplinks.
ROUTES = {
    "flat_ring": ("ring8", {"hierarchy": "never"}),
    "hier_grid": ("grid23", {"hierarchy": "always"}),
    "hier_multipod": ("mp222", {"hierarchy": "always"}),
    "te_multipod": ("mp222_skew", {"hierarchy": "always",
                                   "gateway_strategy": "te"}),
}

_TOPO_CACHE: dict[str, object] = {}


def build_topo(name: str):
    if name not in _TOPO_CACHE:
        from repro.topology import line, ring, torus2d
        from repro.topology.generators import grid_hypercube, multi_pod

        _TOPO_CACHE[name] = {
            "ring8": lambda: ring(8, bidirectional=True),
            "line8": lambda: line(8),
            "torus24": lambda: torus2d(2, 4),
            "grid23": lambda: grid_hypercube(2, 3),
            "mp222": lambda: multi_pod(2, 2, 2, unit_links=True,
                                       dci_ports_per_pod=2),
            "mp222_skew": lambda: multi_pod(2, 2, 2,
                                            dci_port_gbps=[100.0, 10.0]),
        }[name]()
    return _TOPO_CACHE[name]


def request(kind, group, **kw):
    from repro.core import CollectiveRequest

    return CollectiveRequest(kind, group=tuple(group), **kw)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("route", sorted(ROUTES))
def test_full_group_conformance(route, kind):
    """Every kind x route over the full 8-device group vs numpy."""
    topo_name, kw = ROUTES[route]
    topo = build_topo(topo_name)
    req = request(kind, range(N), **kw)
    check_collective(kind, topo, req, tuple(range(N)), n=N,
                     seed=hash((route, kind)) % 2**32)


@pytest.mark.parametrize("route", ["flat_ring", "hier_grid"])
def test_pipelined_all_reduce(route):
    """The chunk-pipelined RS->AG junction (per-chunk release floors)
    collapses to wave order at execution and stays numerically exact."""
    topo_name, kw = ROUTES[route]
    topo = build_topo(topo_name)
    req = request("all_reduce", range(N), pipelined=True, **kw)
    check_collective("all_reduce", topo, req, tuple(range(N)), n=N, seed=7)


@pytest.mark.parametrize("kind", KINDS)
def test_vs_lax_reference(kind):
    """PCCL vs the XLA built-in inside one traced program, on the
    hierarchical grid route: all_gather / psum_scatter / psum / all_to_all."""
    import jax
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.comms import primitives
    from repro.jaxcompat import make_mesh, shard_map

    topo = build_topo("grid23")
    req = request(kind, range(N), hierarchy="always")
    fn = getattr(primitives, f"pccl_{kind}")
    x = make_input(kind, tuple(range(N)), N, seed=11)
    mesh = make_mesh((N,), ("x",))

    def f(xl):
        v = xl[0]
        mine = fn(v, "x", topo, req)
        if kind == "all_gather":
            ref = lax.all_gather(v, "x")
        elif kind == "reduce_scatter":
            ref = lax.psum_scatter(v, "x", scatter_dimension=0, tiled=False)
        elif kind == "all_reduce":
            ref = lax.psum(v, "x")
        else:
            ref = lax.all_to_all(v[:, None], "x", split_axis=0,
                                 concat_axis=0)[:, 0]
        return mine[None], ref[None]

    run = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"),
                            out_specs=(P("x"), P("x"))))
    mine, ref = run(x)
    assert_conformant(kind, np.asarray(mine), np.asarray(ref),
                      f"{kind} vs lax built-in")


# strict-subset process groups: (topology, group). line8 groups force
# forwarding through out-of-group devices; the grid/multipod groups span
# both pods, so subset-group traffic rides the hierarchical machinery.
SUBSET_CASES = [
    ("line8", (0, 3, 7), {}),
    ("grid23", (0, 2, 5, 6), {}),
    ("mp222", (1, 2, 4, 7), {}),
]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("case", range(len(SUBSET_CASES)),
                         ids=[c[0] for c in SUBSET_CASES])
def test_subset_groups_leave_non_participants_untouched(case, kind):
    topo_name, group, kw = SUBSET_CASES[case]
    topo = build_topo(topo_name)
    req = request(kind, group, **kw)
    check_collective(kind, topo, req, group, n=N,
                     seed=hash((topo_name, group, kind)) % 2**32)


@pytest.mark.parametrize("kind", KINDS)
def test_repaired_plan_route(kind):
    """Degrade a pod-internal link, repair the captured PhasePlan, lower the
    repaired algorithm with ``lower_algorithm``, execute it via the
    ``program=`` override, and check numerics."""
    from repro.comms import lower_algorithm
    from repro.core import AlgorithmRegistry, DegradationEvent, PlanRepairer

    topo = build_topo("grid23")
    group = tuple(range(N))
    rp = PlanRepairer(topo, registry=AlgorithmRegistry(), pipeline=False)
    req = request(kind, group, hierarchy="always")
    rp.plan(req)
    boundary = {b.id for b in topo.boundary_links()}
    victim = next(l.id for l in topo.links if l.id not in boundary)
    res = rp.repair(req, DegradationEvent(failed_links=[victim]))
    res.algorithm.validate()
    prog_plan = lower_algorithm(res.algorithm,
                                key=("conformance-repair", kind, victim))
    check_collective(kind, None, req, group, n=N, seed=13,
                     program=prog_plan)


def test_planner_program_roundtrip():
    """A MeshCollectivePlanner/PlanService-served program executes through
    the primitives' program= override — the serving path train_lm uses."""
    from repro.core import CollectiveRequest
    from repro.core.planservice import PlanService

    topo = build_topo("grid23")
    svc = PlanService()
    try:
        prog_plan = svc.program(
            topo, {"x": N},
            CollectiveRequest("all_reduce", hierarchy="always"), "x")
        req = request("all_reduce", range(N), hierarchy="always")
        check_collective("all_reduce", topo, req, tuple(range(N)), n=N,
                         seed=17, program=prog_plan)
    finally:
        svc.close()


@pytest.mark.slow
def test_train_lm_step_matches_xla_baseline():
    """One data-parallel train_lm step with PCCL-executed gradient
    all-reduce matches the lax.pmean baseline (loss and updated params)."""
    import re
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).resolve().parent.parent
    env_cmd = [sys.executable, str(root / "examples" / "train_lm.py"),
               "--model", "tiny", "--steps", "2", "--batch", "8",
               "--seq", "32", "--dp", "8", "--host-devices", "8",
               "--compare-collectives", "--seed", "0"]
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)  # train_lm sets it from --host-devices
    out = subprocess.run(env_cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"train_lm failed:\n{out.stdout}\n{out.stderr}"
    m = re.search(r"PCCL_CONFORMANCE max_loss_diff=([0-9.e+-]+) "
                  r"max_param_diff=([0-9.e+-]+)", out.stdout)
    assert m, f"no conformance line in output:\n{out.stdout}"
    loss_diff, param_diff = float(m.group(1)), float(m.group(2))
    assert loss_diff < 1e-4, out.stdout
    assert param_diff < 1e-3, out.stdout
