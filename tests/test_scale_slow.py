"""Scale smoke tests (slow tier): the array-backed core must hold the
paper's Fig. 11 trajectory — a 144-NPU mesh All-to-All synthesizes and
validates inside a hard wall-clock budget — and the multi-level
hierarchical pipeline must keep a cold three-level 2048-NPU All-Gather
inside its budget with registry misses bounded independent of fabric
size. Run with ``pytest -m slow`` (a non-blocking CI job does); the quick
tier skips these.
"""

import time

import pytest

from repro.core import AlgorithmRegistry
from repro.core.engine import SynthesisEngine
from repro.topology import mesh2d, three_level

# generous for CI-class machines: the reference loop needs ~15-20s for the
# synthesis alone on a dev box, the event-frontier core ~3-4s
_BUDGET_SECONDS = 120.0

# cold 2048-NPU three-level All-Gather: ~12s synthesis + ~4s bulk
# validation on a dev box; generous headroom for CI-class machines
_HIER3_BUDGET_SECONDS = 300.0

# cold 512-NPU TE-vs-RR comparison: ~7s for both strategies + bulk
# validation on a dev box; generous headroom for CI-class machines
_TE_BUDGET_SECONDS = 180.0


@pytest.mark.slow
def test_mesh12x12_all_to_all_within_budget():
    topo = mesh2d(12, 12)
    n = 144
    t0 = time.perf_counter()
    alg = SynthesisEngine(topo).all_to_all(list(range(n)))
    synth_s = time.perf_counter() - t0
    alg.validate()
    wall_s = time.perf_counter() - t0
    assert len(alg.conditions) == n * (n - 1)
    assert alg.makespan > 0
    assert wall_s < _BUDGET_SECONDS, (
        f"12x12 All-to-All took {wall_s:.1f}s (synthesis {synth_s:.1f}s), "
        f"budget {_BUDGET_SECONDS}s — the scaling regression gate failed"
    )


@pytest.mark.slow
def test_three_level_2048_all_gather_within_budget():
    """Cold multi-level (rack -> pod -> plane) 2048-NPU All-Gather: the
    recursion must synthesize + bulk-validate inside the budget, taking
    the truly hierarchical route, with registry misses bounded by
    (phase kinds x levels) + the named route — independent of fabric
    size (16 pods x 16 racks pay for ~one of each phase kind per level)."""
    topo = three_level(16, 16, 8, unit_links=True)
    n = 2048
    reg = AlgorithmRegistry()
    t0 = time.perf_counter()
    alg = SynthesisEngine(topo, registry=reg).all_gather(topo.npus)
    synth_s = time.perf_counter() - t0
    alg.validate(mode="bulk")
    wall_s = time.perf_counter() - t0
    assert alg.name == "pccl_hier_all_gather"
    assert len(alg.conditions) == n
    assert any("/" in name for name, _, _ in alg.phase_spans), (
        "2048-NPU plan must carry nested (recursive) phase provenance")
    kinds, levels = 3, 3  # intra/inter/scatter x rack/pod/plane
    assert reg.stats.misses <= kinds * levels + 1, (
        f"registry misses {reg.stats.misses} exceed the (kinds x levels) "
        f"bound — per-rack/per-pod plan sharing has regressed")
    assert wall_s < _HIER3_BUDGET_SECONDS, (
        f"three-level 2048-NPU All-Gather took {wall_s:.1f}s (synthesis "
        f"{synth_s:.1f}s), budget {_HIER3_BUDGET_SECONDS}s"
    )


@pytest.mark.slow
def test_three_level_512_te_vs_rr_within_budget():
    """Cold 512-NPU three-level All-Gather under both gateway strategies:
    the traffic-engineered assignment (greedy min-max + refinement over
    512 multicast demands) must stay inside the wall-clock budget — the
    scaling gate for the TE machinery itself — and must land within a few
    percent of round-robin on this uniform fabric (count cycling is
    already load-balanced there; only tie-break alignment differs)."""
    t0 = time.perf_counter()
    spans = {}
    for strategy in ("round_robin", "te"):
        topo = three_level(8, 8, 8, unit_links=True)
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              gateway_strategy=strategy)
        alg = eng.all_gather(topo.npus)
        alg.validate(mode="bulk")
        assert alg.name == "pccl_hier_all_gather"
        spans[strategy] = alg.makespan
    wall_s = time.perf_counter() - t0
    assert spans["te"] <= 1.05 * spans["round_robin"], (
        f"TE makespan {spans['te']} strays from round-robin "
        f"{spans['round_robin']} on a uniform 512-NPU fabric")
    assert wall_s < _TE_BUDGET_SECONDS, (
        f"512-NPU TE-vs-RR comparison took {wall_s:.1f}s, budget "
        f"{_TE_BUDGET_SECONDS}s — the TE assignment machinery has "
        f"stopped scaling"
    )


# cold 2048-NPU three-level *pipelined* All-Reduce: ~85s synthesis + ~15s
# bulk validation on a dev box — the chunk-granular junction plus forced
# in-pod replication keeps the barrier-free route inside the same order
# of magnitude as the sequential one
_HIER3_PIPE_AR_BUDGET_SECONDS = 120.0


@pytest.mark.slow
def test_three_level_2048_pipelined_all_reduce_within_budget():
    """Cold multi-level 2048-NPU chunk-granular (pipeline=True) All-Reduce:
    synthesize + bulk-validate inside the budget, with registry misses
    bounded by (phase kinds x levels) + 1 — the release-stripped uniform
    phases keep sharing canonical per-pod plans, and the release-bearing
    scatter/inter phases bypass the registry without churning it."""
    topo = three_level(16, 16, 8, unit_links=True)
    reg = AlgorithmRegistry()
    eng = SynthesisEngine(topo, registry=reg)
    t0 = time.perf_counter()
    alg = eng.hierarchical().all_reduce(topo.npus, pipeline=True)
    synth_s = time.perf_counter() - t0
    alg.validate(mode="bulk")
    wall_s = time.perf_counter() - t0
    assert alg.name == "pccl_hier_all_reduce"
    assert len(alg.conditions) == 2048
    # the chunk-granular junction's release provenance is present
    assert any(n == "all_gather/@release" for n, _, _ in alg.phase_spans)
    kinds, levels = 3, 3
    assert reg.stats.misses <= kinds * levels + 1, (
        f"registry misses {reg.stats.misses} exceed the (kinds x levels) "
        f"bound — pipelined phases are churning the registry")
    assert wall_s < _HIER3_PIPE_AR_BUDGET_SECONDS, (
        f"three-level 2048-NPU pipelined All-Reduce took {wall_s:.1f}s "
        f"(synthesis {synth_s:.1f}s), budget "
        f"{_HIER3_PIPE_AR_BUDGET_SECONDS}s"
    )
