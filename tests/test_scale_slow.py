"""Scale smoke tests (slow tier): the array-backed core must hold the
paper's Fig. 11 trajectory — a 144-NPU mesh All-to-All synthesizes and
validates inside a hard wall-clock budget. Run with ``pytest -m slow``
(a non-blocking CI job does); the quick tier skips these.
"""

import time

import pytest

from repro.core.engine import SynthesisEngine
from repro.topology import mesh2d

# generous for CI-class machines: the reference loop needs ~15-20s for the
# synthesis alone on a dev box, the event-frontier core ~3-4s
_BUDGET_SECONDS = 120.0


@pytest.mark.slow
def test_mesh12x12_all_to_all_within_budget():
    topo = mesh2d(12, 12)
    n = 144
    t0 = time.perf_counter()
    alg = SynthesisEngine(topo).all_to_all(list(range(n)))
    synth_s = time.perf_counter() - t0
    alg.validate()
    wall_s = time.perf_counter() - t0
    assert len(alg.conditions) == n * (n - 1)
    assert alg.makespan > 0
    assert wall_s < _BUDGET_SECONDS, (
        f"12x12 All-to-All took {wall_s:.1f}s (synthesis {synth_s:.1f}s), "
        f"budget {_BUDGET_SECONDS}s — the scaling regression gate failed"
    )
