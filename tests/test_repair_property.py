"""Property tests for fault-aware incremental plan repair.

One claim, over randomized (fabric, collective, degradation) triples:
repair has exactly two outcomes. Either it returns a plan that passes the
reference oracle and fulfils the *identical* per-chunk final conditions a
cold synthesis on the degraded fabric produces, or it raises
:class:`FabricDegradedError` — never a silently-wrong schedule, never an
uncontrolled error. And validation has teeth on the repaired plans too: a
single corrupted transfer duration flips the bulk validator.

Cases are generated from a ``random.Random`` seed, so the same generator
serves two harnesses: hypothesis drives the seed space when installed,
and a fixed seed sweep runs otherwise — the gate never silently skips.
"""

import random

import pytest

from repro.core import (
    AlgorithmRegistry,
    CollectiveRequest,
    DegradationEvent,
    FabricDegradedError,
    PlanRepairer,
    SynthesisEngine,
)
from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.core.conditions import ReduceCondition
from repro.topology import multi_pod, ring, three_level, two_level_switch

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

FABRICS = (
    lambda: multi_pod(2, 2, 3, unit_links=True, dci_ports_per_pod=2),
    lambda: multi_pod(3, 2, 2, unit_links=True, dci_ports_per_pod=1),
    lambda: three_level(2, 2, 2, unit_links=True),
    lambda: two_level_switch(3, npus_per_node=4),
    lambda: ring(8),  # unpartitioned: repair must route via resynthesis
)

KINDS = ("all_gather", "all_to_all", "reduce_scatter", "all_reduce",
         "reduce")


def _delivery(alg):
    out = []
    for c in alg.conditions:
        if isinstance(c, ReduceCondition):
            out.append((c.chunk, tuple(sorted(c.srcs)),
                        tuple(sorted(c.dests))))
        else:
            out.append((c.chunk, c.src, tuple(sorted(c.dests))))
    return sorted(out)


def check_repair_seed(seed: int) -> None:
    rng = random.Random(seed)
    topo = rng.choice(FABRICS)()
    kind = rng.choice(KINDS)
    group = tuple(topo.npus)
    if kind == "reduce":
        req = CollectiveRequest(kind, group=group, root=rng.choice(group))
    else:
        req = CollectiveRequest(kind, group=group)
    rp = PlanRepairer(topo, registry=AlgorithmRegistry(), pipeline=False)
    if rng.random() < 0.7:  # exercise planned and unplanned repairs
        rp.plan(req)
    links = rng.sample(range(topo.num_links),
                       rng.randint(0, min(3, topo.num_links)))
    npus = rng.sample(list(topo.npus), rng.randint(0, 1))
    event = DegradationEvent(failed_links=links, failed_npus=npus)
    try:
        res = rp.repair(req, event)
    except FabricDegradedError:
        return  # the one legal refusal: loud, typed, no schedule
    # outcome 2: a plan on the surviving fabric that oracle-validates and
    # agrees with cold degraded synthesis on every final condition
    res.algorithm.validate(mode="oracle")
    dtopo = topo.degraded(event.failed_links, event.failed_npus).topology
    cold = SynthesisEngine(
        dtopo, registry=AlgorithmRegistry()).collective(res.request)
    assert _delivery(res.algorithm) == _delivery(cold), (
        f"seed {seed}: repaired conditions diverge from cold synthesis "
        f"({res.strategy} strategy on {topo.name})")
    # corruption flips: stretch one repaired transfer's duration
    ts = list(res.algorithm.transfers)
    if ts:
        k = rng.randrange(len(ts))
        t = ts[k]
        ts[k] = Transfer(t.chunk, t.link, t.src, t.dst, t.start,
                         t.end + 0.5, t.reduce)
        bad = CollectiveAlgorithm(res.algorithm.topology,
                                  list(res.algorithm.conditions), ts,
                                  name=res.algorithm.name)
        with pytest.raises((ValueError, AssertionError)):
            bad.validate(mode="bulk")


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_repair_two_outcomes_hypothesis(seed):
        check_repair_seed(seed)

else:  # pragma: no cover - fallback sweep when hypothesis is absent

    @pytest.mark.parametrize("seed", range(25))
    def test_repair_two_outcomes_sweep(seed):
        check_repair_seed(seed)
