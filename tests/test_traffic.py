"""Inter-pod traffic engineering: the min-max gateway assigner, the
CommSketch constraint surface, and their integration contracts.

Four claims:

1. **The assigner balances time, not counts.** On a hand-built boundary
   star with one fast and one slow uplink, greedy min-max assignment beats
   the count-balanced round-robin spread, the exact refinement pass never
   raises the peak, and ``better_of`` adopts a strictly better reference
   assignment wholesale (the never-worse guarantee).
2. **Sketches are hard constraints.** Gateway affinities confine a pod's
   boundary traffic to the named gateways, node/link exclusions keep every
   transfer off the excluded hardware, port caps bound the distinct
   gateways a pod opens — and an unsatisfiable sketch raises
   ``SketchInfeasibleError`` through the engine's named entry points
   instead of silently falling back to an unconstrained (flat or legacy)
   plan.
3. **The registry never cross-serves strategies.** A plan cached under
   round-robin must miss for a TE request, and an unconstrained plan must
   miss for a sketch-constrained one (and vice versa): the strategy and
   sketch fingerprint are part of the route/phase key.
4. **Nearest-gateway resolution is memoized.** Bulk All-to-Alls resolve
   the same (pod, node) pair once; the per-gateway BFS row count is pinned
   so an accidental cache bypass shows up as a counted regression.
"""

import pytest

from repro.core import (
    AlgorithmRegistry,
    CommSketch,
    SketchInfeasibleError,
    SynthesisEngine,
    TrafficEngineer,
)
from repro.core.hierarchy import HierarchyError
from repro.topology import multi_pod
from repro.topology.topology import NodeType, Topology

KINDS = ["all_gather", "all_to_all", "reduce_scatter", "all_reduce"]


def _unit_pod(num_pods=2):
    return multi_pod(num_pods, 2, 4, unit_links=True, dci_ports_per_pod=4)


def _uplinks(topo, p):
    """[(link id, gateway npu)] for pod p's uplinks to the DCI switch."""
    gws = set(topo.gateways(p))
    return [(l.id, l.src) for l in topo.links
            if l.src in gws and topo.nodes[l.dst].type == NodeType.SWITCH]


class TestCommSketch:
    def test_normalization_order_independent(self):
        a = CommSketch(gateway_affinity={1: [7, 3], 0: [2]},
                       max_pod_ports=[(1, 2), (0, 1)])
        b = CommSketch(gateway_affinity=[(0, (2,)), (1, (3, 7))],
                       max_pod_ports={0: 1, 1: 2})
        assert a == b
        assert a.fingerprint() == b.fingerprint()
        assert a.allowed_gateways(1) == (3, 7)
        assert a.allowed_gateways(5) is None
        assert a.port_cap(0) == 1
        assert a.port_cap(9) is None

    def test_fingerprint_distinguishes_constraints(self):
        prints = {
            CommSketch().fingerprint(),
            CommSketch(gateway_affinity={0: [2]}).fingerprint(),
            CommSketch(exclude_nodes=[4]).fingerprint(),
            CommSketch(exclude_links=[4]).fingerprint(),
            CommSketch(max_pod_ports={0: 1}).fingerprint(),
        }
        assert len(prints) == 5

    def test_excludes_hardware(self):
        assert not CommSketch(max_pod_ports={0: 2}).excludes_hardware
        assert CommSketch(exclude_nodes=[1]).excludes_hardware
        assert CommSketch(exclude_links=[1]).excludes_hardware


def _star_boundary():
    """A boundary fabric in miniature: pod-0 gateways g_fast/g_slow uplink
    to a switch (beta 1.0 vs 4.0), pod-1 gateways h0/h1 downlink at beta
    1.0. Returns (topology, identity to_local, node ids)."""
    t = Topology("te_star")
    g_fast, g_slow, h0, h1 = t.add_npus(4)
    sw = t.add_node(NodeType.SWITCH)
    t.add_bidir_link(g_fast, sw, 0.0, 1.0)
    t.add_bidir_link(g_slow, sw, 0.0, 4.0)
    t.add_bidir_link(h0, sw, 0.0, 1.0)
    t.add_bidir_link(h1, sw, 0.0, 1.0)
    to_local = {n: n for n in range(t.num_nodes)}
    return t, to_local, (g_fast, g_slow, h0, h1)


class TestTrafficEngineerUnit:
    def test_min_max_beats_round_robin_counts(self):
        t, to_local, (g_fast, g_slow, h0, h1) = _star_boundary()
        te = TrafficEngineer(t, to_local)
        for k in range(4):
            te.assign(k, 0, [g_fast, g_slow], {1: [h0, h1]}, 1.0)
        te.refine()
        # count-balanced RR: 2 chunks through the beta-4 uplink = peak 8
        rr = [(g_fast if k % 2 == 0 else g_slow,
               {1: h0 if k % 2 == 0 else h1}) for k in range(4)]
        assert te.simulate(rr) == pytest.approx(8.0)
        # time-balanced: worst uplink carries at most all-fast (4) units
        assert te.peak() <= 4.0 + 1e-9
        assert not te.better_of(rr)  # RR is worse: never adopted

    def test_refine_never_raises_peak(self):
        t, to_local, (g_fast, g_slow, h0, h1) = _star_boundary()
        te = TrafficEngineer(t, to_local)
        for k in range(6):
            te.assign(k, 0, [g_fast, g_slow], {1: [h0, h1]}, 1.0)
        before = te.peak()
        te.refine()
        assert te.peak() <= before + 1e-12

    def test_better_of_adopts_superior_reference(self):
        t, to_local, (g_fast, g_slow, h0, h1) = _star_boundary()
        te = TrafficEngineer(t, to_local)
        # force every demand through the slow uplink
        for k in range(3):
            te.assign(k, 0, [g_slow], {1: [h0]}, 1.0)
        assert te.peak() == pytest.approx(12.0)
        ref = [(g_fast, {1: h0})] * 3
        assert te.better_of(ref)
        assert te.peak() == pytest.approx(3.0)
        assert [e for _, e, _ in te.assignments()] == [g_fast] * 3

    def test_route_deterministic_and_memoized(self):
        t, to_local, (g_fast, g_slow, h0, h1) = _star_boundary()
        cache = {}
        te = TrafficEngineer(t, to_local, route_cache=cache)
        cost, links = te.route(g_fast, h0)
        assert cost == pytest.approx(2.0)  # beta-1 up + beta-1 down
        assert te.route(g_fast, h0) == (cost, links)
        assert cache[(g_fast, h0)] == (cost, links)
        assert te.route(g_fast, g_fast) == (0.0, ())

    def test_unroutable_demand_raises(self):
        t = Topology("te_island")
        a, b = t.add_npus(2)  # no links at all
        te = TrafficEngineer(t, {a: a, b: b})
        with pytest.raises(ValueError):
            te.assign(0, 0, [a], {1: [b]}, 1.0)

    def test_port_cap_reuses_open_gateway(self):
        t, to_local, (g_fast, g_slow, h0, h1) = _star_boundary()
        te = TrafficEngineer(t, to_local,
                             sketch=CommSketch(max_pod_ports={1: 1}))
        picks = set()
        for k in range(4):
            _, ing = te.assign(k, 0, [g_fast, g_slow], {1: [h0, h1]}, 1.0)
            picks.add(ing[1])
        assert len(picks) == 1  # pod 1 opened exactly one ingress port


class TestSketchConstraints:
    def test_affinity_confines_boundary_traffic(self):
        topo = _unit_pod()
        allow = {p: [_uplinks(topo, p)[1][1]] for p in range(2)}
        sk = CommSketch(gateway_affinity=allow)
        alg = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              sketch=sk).all_gather(topo.npus)
        alg.validate(mode="oracle")
        for p in range(2):
            used = {src for lid, src in _uplinks(topo, p)
                    if any(tr.link == lid for tr in alg.transfers)}
            assert used <= set(allow[p])

    def test_link_exclusion_keeps_traffic_off(self):
        topo = _unit_pod()
        banned = set()
        for lid, src in _uplinks(topo, 0)[:2]:
            banned.add(lid)
            # ban both directions of the uplink
            banned.update(l.id for l in topo.links
                          if l.dst == src
                          and topo.nodes[l.src].type == NodeType.SWITCH)
        sk = CommSketch(exclude_links=banned)
        alg = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              sketch=sk).all_to_all(topo.npus)
        alg.validate(mode="oracle")
        assert not {tr.link for tr in alg.transfers} & banned

    def test_node_exclusion_drops_adjacent_boundary_links(self):
        topo = _unit_pod()
        victim = _uplinks(topo, 0)[0][1]
        boundary = set(topo.boundary_subtopology().links)
        adjacent = {l.id for l in topo.links
                    if l.id in boundary and victim in (l.src, l.dst)}
        assert adjacent
        sk = CommSketch(exclude_nodes=[victim])
        alg = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              sketch=sk).all_gather(topo.npus)
        alg.validate(mode="oracle")
        assert not {tr.link for tr in alg.transfers} & adjacent

    def test_port_cap_bounds_distinct_gateways(self):
        topo = _unit_pod()
        sk = CommSketch(max_pod_ports={0: 1, 1: 1})
        alg = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              sketch=sk).all_gather(topo.npus)
        alg.validate(mode="oracle")
        for p in range(2):
            used = {src for lid, src in _uplinks(topo, p)
                    if any(tr.link == lid for tr in alg.transfers)}
            assert len(used) <= 1

    @pytest.mark.parametrize("kind", KINDS)
    def test_infeasible_sketch_raises_through_engine(self, kind):
        """An unsatisfiable sketch must surface, not degrade to a flat or
        unconstrained plan — on every named entry point."""
        topo = _unit_pod()
        non_gateway = topo.npus[len(topo.npus) // 2 - 1]
        assert non_gateway not in topo.gateways(0)
        eng = SynthesisEngine(
            topo, registry=AlgorithmRegistry(),
            sketch=CommSketch(gateway_affinity={0: [non_gateway]}))
        with pytest.raises(SketchInfeasibleError):
            getattr(eng, kind)(topo.npus)

    def test_exclusion_starving_a_pod_is_infeasible(self):
        topo = _unit_pod()
        sk = CommSketch(exclude_nodes=[src for _, src in _uplinks(topo, 0)])
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry(), sketch=sk)
        with pytest.raises(SketchInfeasibleError):
            eng.all_gather(topo.npus)

    def test_sketch_is_not_a_hierarchy_error(self):
        # HierarchyError triggers the engine's silent flat fallback; an
        # infeasible sketch must never ride that path
        assert not issubclass(SketchInfeasibleError, HierarchyError)
        assert issubclass(SketchInfeasibleError, ValueError)


class TestRegistryStrategyKeys:
    """Strategy and sketch fingerprint are registry key components: plans
    synthesized under one gateway policy must never be served to another."""

    def test_rr_cached_plan_misses_for_te(self):
        topo = _unit_pod()
        reg = AlgorithmRegistry()
        SynthesisEngine(topo, registry=reg,
                        gateway_strategy="round_robin").all_gather(topo.npus)
        misses = reg.stats.misses
        SynthesisEngine(topo, registry=reg,
                        gateway_strategy="te").all_gather(topo.npus)
        assert reg.stats.misses > misses, (
            "TE request was served the cached round-robin plan")

    def test_unconstrained_plan_misses_for_sketch(self):
        topo = _unit_pod()
        reg = AlgorithmRegistry()
        SynthesisEngine(topo, registry=reg).all_gather(topo.npus)
        misses = reg.stats.misses
        gw = _uplinks(topo, 0)[0][1]
        alg = SynthesisEngine(
            topo, registry=reg,
            sketch=CommSketch(gateway_affinity={0: [gw]}),
        ).all_gather(topo.npus)
        assert reg.stats.misses > misses, (
            "sketch-constrained request was served the unconstrained plan")
        alg.validate(mode="oracle")

    def test_sketch_plan_misses_for_unconstrained(self):
        topo = _unit_pod()
        reg = AlgorithmRegistry()
        gw = _uplinks(topo, 0)[0][1]
        SynthesisEngine(
            topo, registry=reg,
            sketch=CommSketch(gateway_affinity={0: [gw]}),
        ).all_gather(topo.npus)
        misses = reg.stats.misses
        SynthesisEngine(topo, registry=reg).all_gather(topo.npus)
        assert reg.stats.misses > misses, (
            "unconstrained request was served the sketch-constrained plan")

    def test_same_strategy_hits(self):
        topo = _unit_pod()
        reg = AlgorithmRegistry()
        a = SynthesisEngine(topo, registry=reg,
                            gateway_strategy="te").all_gather(topo.npus)
        misses = reg.stats.misses
        b = SynthesisEngine(topo, registry=reg,
                            gateway_strategy="te").all_gather(topo.npus)
        assert reg.stats.misses == misses
        assert a.makespan == b.makespan


class TestNearestGatewayMemoized:
    def test_bfs_row_count_pinned(self, monkeypatch):
        """Resolving every (pod, node) pair twice must run at most one
        node->gateway BFS row per (pod, gateway): the per-pair results and
        the per-gateway distance rows are both cached."""
        from repro.topology.topology import Topology as T

        calls = {"n": 0}
        orig = T.hop_distances_to

        def counted(self, *a, **kw):
            calls["n"] += 1
            return orig(self, *a, **kw)

        monkeypatch.setattr(T, "hop_distances_to", counted)
        topo = _unit_pod()
        h = SynthesisEngine(topo).hierarchical()
        for _ in range(2):
            for p in range(topo.num_pods):
                for n in topo.npus:
                    if topo.partition[n] == p:
                        h._nearest_gateway(p, n)
        per_pod_gws = len(topo.gateways(0))
        assert calls["n"] <= topo.num_pods * per_pod_gws, (
            f"{calls['n']} BFS rows for {topo.num_pods} pods x "
            f"{per_pod_gws} gateways — nearest-gateway memoization regressed")
        again = calls["n"]
        for p in range(topo.num_pods):
            for n in topo.npus:
                if topo.partition[n] == p:
                    h._nearest_gateway(p, n)
        assert calls["n"] == again  # fully warm: zero new BFS rows
