"""Property and differential tests for the traffic-engineered gateway
assignment.

Three claims:

1. **Never worse than round-robin.** Over randomized boundary fabrics
   (skewed uplink counts and bandwidths, random demand matrices), the
   engineered assignment's modeled peak link busy-time never exceeds the
   count-balanced round-robin reference scored under the same load model —
   the ``better_of`` anytime guarantee, exercised end to end through
   greedy assignment + refinement.
2. **TE plans are correct plans.** Forcing ``gateway_strategy="te"`` on
   the partitioned fabric families (multi_pod, two_level_switch,
   three_level) still yields plans that pass bulk and oracle validation —
   the assignment only re-points gateways; the delivery contract is
   untouched.
3. **Symmetric fabrics are undisturbed.** On uniform-uplink fabrics the
   engineered and round-robin assignments produce makespan-equal plans
   for the spanning collectives (count balancing IS load balancing
   there), the All-to-All engineered plan is never slower than the legacy
   nearest-gateway default, and ``"auto"`` resolves away from TE — the
   legacy schedules are byte-for-byte safe.

Cases are generated from a ``random.Random`` seed, so the same generator
serves two harnesses: hypothesis drives the seed space when installed,
and a fixed seed sweep runs otherwise — the gate never silently skips.
"""

import random

import pytest

from repro.core import AlgorithmRegistry, SynthesisEngine, TrafficEngineer
from repro.topology import multi_pod, three_level, two_level_switch
from repro.topology.topology import NodeType, Topology

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _gen_boundary(rng: random.Random):
    """A random boundary fabric: P pods with 1-4 gateways each, every
    gateway uplinked to a shared switch at a random per-link bandwidth
    (beta drawn from a skewed palette). Returns (topology, identity
    to_local, {pod: [gateways]})."""
    t = Topology("prop_boundary")
    pods = rng.randint(2, 4)
    gws: dict[int, list[int]] = {}
    for p in range(pods):
        gws[p] = list(t.add_npus(rng.randint(1, 4)))
    sw = t.add_node(NodeType.SWITCH)
    for p in range(pods):
        for g in gws[p]:
            beta = rng.choice([1.0, 1.0, 2.0, 4.0, 8.0])
            alpha = rng.choice([0.0, 1.0])
            t.add_bidir_link(g, sw, alpha, beta)
    return t, {n: n for n in range(t.num_nodes)}, gws


def check_never_worse_seed(seed: int) -> None:
    """Claim 1: modeled TE peak <= modeled round-robin peak, always."""
    rng = random.Random(seed)
    t, to_local, gws = _gen_boundary(rng)
    pods = sorted(gws)
    te = TrafficEngineer(t, to_local)
    rr = []
    for key in range(rng.randint(2, 20)):
        p = rng.choice(pods)
        qs = rng.sample([q for q in pods if q != p],
                        rng.randint(1, len(pods) - 1))
        nbytes = rng.choice([1.0, 4.0])
        te.assign(key, p, gws[p], {q: gws[q] for q in qs}, nbytes)
        e = gws[p][key % len(gws[p])]
        rr.append((e, {q: gws[q][key % len(gws[q])] for q in qs}))
    te.refine()
    rr_peak = te.simulate(rr)
    te.better_of(rr)
    assert te.peak() <= rr_peak + 1e-9, (
        f"seed {seed}: engineered peak {te.peak()} exceeds round-robin "
        f"reference {rr_peak}")


FABRICS = [
    multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4),
    multi_pod(3, 2, 4, dci_port_gbps=[100.0, 10.0, 10.0, 10.0]),
    two_level_switch(3, 4),
    three_level(2, 2, 3, unit_links=True),
]
SPANNING = ["all_gather", "reduce_scatter", "all_reduce"]


@pytest.mark.parametrize("topo", FABRICS, ids=lambda t: t.name)
@pytest.mark.parametrize("kind", SPANNING + ["all_to_all"])
def test_te_plans_validate(topo, kind):
    """Claim 2: forced-TE plans pass bulk + oracle validation."""
    eng = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                          gateway_strategy="te")
    try:
        alg = getattr(eng.hierarchical(), kind)(topo.npus)
    except Exception as err:
        from repro.core.hierarchy import HierarchyError

        if isinstance(err, HierarchyError):
            pytest.skip(f"{kind} not hierarchically routable: {err}")
        raise
    alg.validate(mode="bulk")
    alg.validate(mode="oracle")


SYMMETRIC = [
    multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4),
    multi_pod(3, 2, 4, unit_links=True, dci_ports_per_pod=2),
    three_level(2, 2, 3, unit_links=True),
]


@pytest.mark.parametrize("topo", SYMMETRIC, ids=lambda t: t.name)
@pytest.mark.parametrize("kind", SPANNING)
def test_symmetric_fabrics_makespan_equal(topo, kind):
    """Claim 3 (spanning): uniform uplinks -> TE and round-robin tie."""
    spans = {}
    for strategy in ("round_robin", "te"):
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              gateway_strategy=strategy)
        alg = getattr(eng.hierarchical(), kind)(topo.npus)
        alg.validate(mode="bulk")
        spans[strategy] = alg.makespan
    assert spans["te"] == pytest.approx(spans["round_robin"]), (
        f"{topo.name} {kind}: TE perturbed a symmetric fabric "
        f"({spans['te']} vs {spans['round_robin']})")


@pytest.mark.parametrize("topo", SYMMETRIC, ids=lambda t: t.name)
def test_symmetric_all_to_all_not_slower_than_rr(topo):
    """Claim 3 (All-to-All): TE never loses to the count-cycled
    round-robin assignment on uniform fabrics — and may strictly win,
    since per-source ordinal cycling can still collide at a shared DCI
    switch where the min-max objective spreads. (The legacy *nearest*
    default can beat both via its intra-pod distance objective — which is
    why "auto" keeps it on these fabrics, pinned below.)"""
    spans = {}
    for strategy in ("round_robin", "te"):
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry(),
                              gateway_strategy=strategy)
        alg = eng.hierarchical().all_to_all(topo.npus)
        alg.validate(mode="bulk")
        spans[strategy] = alg.makespan
    assert spans["te"] <= spans["round_robin"] + 1e-9


@pytest.mark.parametrize("topo", SYMMETRIC + [two_level_switch(3, 4)],
                         ids=lambda t: t.name)
def test_auto_resolves_away_from_te_on_uniform_uplinks(topo):
    """Claim 3 (auto): no pod has mutually heterogeneous uplinks on these
    fabrics, so "auto" must keep the legacy per-collective default."""
    h = SynthesisEngine(topo).hierarchical()
    assert h._effective_strategy() == "auto"


def test_auto_engages_te_on_skewed_uplinks():
    topo = multi_pod(2, 2, 4, dci_port_gbps=[100.0, 10.0, 10.0, 10.0])
    h = SynthesisEngine(topo).hierarchical()
    assert h._effective_strategy() == "te"


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_te_never_worse_than_round_robin(seed):
        check_never_worse_seed(seed)

else:  # seed-sweep fallback: same generator, fixed seeds

    @pytest.mark.parametrize("seed", range(0, 60))
    def test_te_never_worse_than_round_robin(seed):
        check_never_worse_seed(seed)
