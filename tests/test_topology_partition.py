"""Tests for Topology partition metadata (pods, boundary views, pod graph,
nested partition trees) and the cache-carrying ``reversed()`` view."""

import numpy as np
import pytest

from repro.core.registry import topology_fingerprint
from repro.topology import (NodeType, Topology, multi_pod, three_level,
                            two_level_switch)
from repro.topology.generators import grid_hypercube


class TestPartition:
    def test_multi_pod_auto_partition(self):
        topo = multi_pod(2, 4, 8)
        assert topo.num_pods == 2
        assert topo.partition[-1] == -1  # the DCI switch is shared
        assert topo.pods()[0] == list(range(32))
        assert len(topo.boundary_links()) == 2 * 2 * 8  # bidir uplinks
        assert topo.gateways(0) == list(range(8))  # edge row, cols 0..7

    def test_two_level_switch_partition(self):
        topo = two_level_switch(3, npus_per_node=4)
        assert topo.num_pods == 3
        # pods own their local switch; gateways fall back to the NPUs one
        # hop inside the boundary port (the local switch itself)
        assert topo.gateways(1) == [4, 5, 6, 7]
        spine = topo.num_nodes - 1
        assert topo.partition[spine] == -1

    def test_grid_hypercube_partition_planes(self):
        topo = grid_hypercube(4, 3)
        assert topo.num_pods == 4
        assert all(len(p) == 16 for p in topo.pods())
        # every NPU touches a dim-0 (boundary) link
        assert len(topo.gateways(0)) == 16

    def test_pod_subtopologies_isomorphic(self):
        topo = multi_pod(4, 4, 4)
        fps = {topology_fingerprint(topo.pod_subtopology(p).topology)
               for p in range(4)}
        assert len(fps) == 1  # one canonical pod plan serves every pod

    def test_view_lift_maps(self):
        topo = multi_pod(2, 4, 8)
        view = topo.pod_subtopology(1)
        # local node i is global node nodes[i]; links carry timing over
        for ll, gl in zip(view.topology.links, view.links):
            g = topo.links[gl]
            assert (view.nodes[ll.src], view.nodes[ll.dst]) == (g.src, g.dst)
            assert (ll.alpha, ll.beta) == (g.alpha, g.beta)

    def test_boundary_subtopology_covers_gateways(self):
        topo = multi_pod(2, 4, 8)
        b = topo.boundary_subtopology()
        got = set(b.nodes)
        for p in range(2):
            assert set(topo.gateways(p)) <= got

    def test_pod_graph_quotient(self):
        topo = multi_pod(3, 4, 4, dci_ports_per_pod=4)
        g = topo.pod_graph()
        assert len(g.npus) == 3  # one node per pod
        assert len(g.switches) == 1  # shared DCI
        assert g.num_links == len(topo.boundary_links())

    def test_set_partition_validation(self):
        topo = Topology("t")
        topo.add_npus(4)
        with pytest.raises(ValueError):
            topo.set_partition([0, 1])  # wrong length
        with pytest.raises(ValueError):
            topo.set_partition([0, 2, 2, 0])  # not dense
        topo.set_partition([0, 0, 1, 1])
        assert topo.num_pods == 2
        # nodes added later start unassigned
        topo.add_node(NodeType.SWITCH)
        assert topo.partition[-1] == -1

    def test_mutation_invalidates_views(self):
        topo = multi_pod(2, 2, 2)
        before = len(topo.boundary_links())
        topo.add_link(0, topo.num_nodes - 1, 1.0, 1.0)
        assert len(topo.boundary_links()) == before + 1


class TestNestedPartition:
    """The recursive partition tree: nested set_partition specs, sub-view
    partition carriage, composed lifting, and the tree fingerprint."""

    def test_three_level_auto_partition(self):
        topo = three_level(2, 3, 4, unit_links=True)
        assert topo.num_pods == 2
        assert topo.partition_depth == 2
        # NPU paths are (pod, rack); agg switches (p, -1); DCI (-1,)
        assert topo.partition_paths[0] == (0, 0)
        assert topo.partition_paths[4] == (0, 1)
        assert topo.partition_paths[24] == (0, -1)
        assert topo.partition_paths[-1] == (-1,)
        # top-level view unchanged by nesting
        assert topo.partition[:12] == (0,) * 12
        assert topo.gateways(0) == [0, 4, 8]  # rack gateways uplink to DCI

    def test_pod_subtopology_carries_next_level(self):
        topo = three_level(2, 3, 4, unit_links=True)
        sub = topo.pod_subtopology(1).topology
        assert sub.num_pods == 3  # racks
        assert sub.partition_depth == 1
        assert sub.partition[-1] == -1  # the pod aggregation switch
        assert [len(p) for p in sub.pods()] == [4, 4, 4]
        # rack gateways at the sub level are the agg-switch uplink NPUs
        assert sub.gateways(0) == [0]

    def test_lifting_composes_across_levels(self):
        """Global id of a node reached through two stacked views equals the
        composition of the two parent maps — what nested PhasePlan lifting
        relies on."""
        topo = three_level(2, 3, 4, unit_links=True)
        mid = topo.pod_subtopology(1)
        leaf = mid.topology.pod_subtopology(2)
        for local, mid_id in enumerate(leaf.nodes):
            global_id = mid.nodes[mid_id]
            assert topo.partition_paths[global_id] == (1, 2)
            # link timing survives both hops
        for ll, mid_l in zip(leaf.topology.links, leaf.links):
            g = topo.links[mid.links[mid_l]]
            assert (ll.alpha, ll.beta) == (g.alpha, g.beta)

    def test_nested_spec_validation(self):
        topo = Topology("t")
        topo.add_npus(4)
        with pytest.raises(ValueError, match="dense"):
            topo.set_partition([(0, 0), (0, 2), (1, 0), (1, 1)])
        with pytest.raises(ValueError, match="terminate"):
            topo.set_partition([(0, 0), (-1, 0), (1, 0), (1, 1)])
        with pytest.raises(ValueError, match="empty"):
            topo.set_partition([(), (0,), (0,), (1,)])
        topo.set_partition([(0, 0), (0, 1), (1, 0), (1, 1)])
        assert topo.partition == (0, 0, 1, 1)
        assert topo.partition_depth == 2
        # mixed int/path specs are legal: ints are depth-1 paths
        topo.set_partition([0, (0, 0), 1, (1, 0)])
        assert topo.partition_paths == ((0,), (0, 0), (1,), (1, 0))

    def test_partition_fingerprint_tracks_tree(self):
        a = three_level(2, 2, 3, unit_links=True)
        b = three_level(2, 2, 3, unit_links=True)
        assert a.partition_fingerprint() == b.partition_fingerprint()
        b.set_partition([p[0] for p in b.partition_paths])  # flatten
        assert a.partition_fingerprint() != b.partition_fingerprint()
        assert topology_fingerprint(a) == topology_fingerprint(b)
        assert Topology("x").partition_fingerprint() is None

    def test_nodes_added_later_unassigned_in_tree(self):
        topo = three_level(2, 2, 2, unit_links=True)
        topo.add_node(NodeType.SWITCH)
        assert topo.partition_paths[-1] == (-1,)
        assert topo.partition[-1] == -1

    def test_reversed_carries_partition_tree(self):
        topo = three_level(2, 2, 3, unit_links=True)
        rev = topo.reversed()
        assert rev.partition_paths == topo.partition_paths
        assert rev.partition_fingerprint() == topo.partition_fingerprint()
        # reversed pod sub-views carry the same nested partition
        assert (rev.pod_subtopology(0).topology.partition
                == topo.pod_subtopology(0).topology.partition)

    def test_isomorphic_pods_share_nested_fingerprints(self):
        topo = three_level(3, 2, 3, unit_links=True)
        subs = [topo.pod_subtopology(p).topology for p in range(3)]
        assert len({topology_fingerprint(s) for s in subs}) == 1
        assert len({s.partition_fingerprint() for s in subs}) == 1


class TestReversedCaches:
    def test_reversed_shares_hop_matrix(self):
        topo = multi_pod(2, 2, 4)
        fwd = topo.hop_matrix()
        rev = topo.reversed()
        # shared by transpose, not recomputed
        assert rev._hop_matrix_cache[0].base is not None or np.shares_memory(
            rev._hop_matrix_cache[0], fwd
        )
        assert np.array_equal(np.asarray(rev.hop_matrix()), fwd.T)

    def test_reversed_distances_match_fresh_build(self):
        """No stale adjacency: the shared-cache reversed view must agree
        with a reversed topology built from scratch, for every source."""
        topo = two_level_switch(2, npus_per_node=4)
        topo.hop_matrix()  # warm the forward cache
        shared = topo.reversed()
        fresh = two_level_switch(2, npus_per_node=4).reversed()
        for src in range(topo.num_nodes):
            assert shared.hop_distances_from(src) == \
                fresh.hop_distances_from(src)
            assert shared.hop_distances_to(src) == fresh.hop_distances_to(src)

    def test_reversed_before_forward_cache_stays_lazy(self):
        topo = multi_pod(2, 2, 2)
        rev = topo.reversed()  # forward matrix never computed
        assert not hasattr(rev, "_hop_matrix_cache")
        # still correct, built lazily against the reversed adjacency
        d = rev.hop_distances_from(0)
        assert d[0] == 0 and max(d) > 0

    def test_reversed_view_is_isolated_from_mutation(self):
        """Mutating the forward fabric after reversing must not leak into
        the reversed view's adjacency or cached distances."""
        topo = multi_pod(2, 2, 2)
        topo.hop_matrix()
        rev = topo.reversed()
        before = rev.hop_distances_from(1)
        topo.add_link(1, topo.num_nodes - 1, 1.0, 1.0)
        topo.hop_matrix()
        assert rev.hop_distances_from(1) == before
        assert rev.num_links == topo.num_links - 1

    def test_reversed_carries_partition(self):
        topo = multi_pod(2, 2, 2)
        assert topo.reversed().partition == topo.partition

    def test_reversed_round_trips(self):
        """reversed() memoizes with a backlink: reversed-of-reversed is the
        original object, and link ids carry over with endpoints swapped —
        the property reduction time reversal relies on."""
        topo = multi_pod(2, 2, 4, unit_links=True)
        rev = topo.reversed()
        assert topo.reversed() is rev  # memoized
        assert rev.reversed() is topo  # round-trip
        for f, r in zip(topo.links, rev.links):
            assert (f.id, f.src, f.dst) == (r.id, r.dst, r.src)
        # mutation drops the memo and a fresh view is built
        topo.add_link(0, 1, 1.0, 1.0)
        rev2 = topo.reversed()
        assert rev2 is not rev
        assert rev2.num_links == topo.num_links

    def test_reversed_pod_views_round_trip(self):
        """Pod/boundary sub-topologies derived on the reversed fabric are
        the link-reversals of the forward ones, over identical parent
        node/link id sets — so per-pod reduce phases lift back onto the
        forward fabric coordinates unchanged."""
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        rev = topo.reversed()
        for p in range(topo.num_pods):
            f = topo.pod_subtopology(p)
            r = rev.pod_subtopology(p)
            assert r.nodes == f.nodes and r.links == f.links
            assert topology_fingerprint(r.topology) == \
                topology_fingerprint(f.topology.reversed())
            # reversed-of-reversed pod sub-topology restores the forward
            assert topology_fingerprint(r.topology.reversed()) == \
                topology_fingerprint(f.topology)
            assert rev.gateways(p) == topo.gateways(p)
        fb = topo.boundary_subtopology()
        rb = rev.boundary_subtopology()
        assert rb.nodes == fb.nodes and rb.links == fb.links
        assert topology_fingerprint(rb.topology.reversed()) == \
            topology_fingerprint(fb.topology)
