"""Property tests for multi-level hierarchical synthesis.

Two claims, over randomized nested fabrics (depth 1-3, uneven pod/rack
sizes, optional degenerate partitions):

1. **Never silently wrong.** A random nested partition spec either
   synthesizes a schedule that passes full validation, or raises
   :class:`HierarchyError` — in which case the engine's ``hierarchy="auto"``
   route falls back to flat synthesis, whose schedule also validates and
   fulfils the identical final conditions. There is no third outcome.
2. **Validation has teeth.** A single-transfer mutation of a synthesized
   schedule (corrupted duration, unknown chunk, dropped delivery, premature
   start) flips ``validate(mode="bulk")`` to invalid — the oracle the
   differential claims rest on is not vacuously accepting.

Cases are generated from a ``random.Random`` seed, so the same generator
serves two harnesses: hypothesis drives the seed space (with its database
and shrinking) when installed, and a fixed seed sweep runs otherwise — the
gate never silently skips.
"""

import random

import pytest

from repro.core import AlgorithmRegistry, CollectiveRequest, SynthesisEngine
from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.core.conditions import Condition
from repro.core.hierarchy import HierarchyError
from repro.topology.topology import NodeType, Topology

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _gen_fabric(rng: random.Random):
    """A random nested fabric: leaf groups of 1-4 NPUs on a bidirectional
    ring, joined at each level by a switch the child gateways uplink to.
    Depth 1-3, uneven arities. Returns the partitioned topology."""
    depth = rng.randint(1, 3)

    def gen_spec(d):
        if d == 0:
            return rng.randint(1, 4)  # leaf: NPU count
        return [gen_spec(d - 1) for _ in range(rng.randint(1, 3))]

    spec = gen_spec(depth)
    if isinstance(spec, int):  # degenerate: a single flat leaf group
        spec = [spec]
        depth = 1

    topo = Topology("prop")

    def build(node_spec, path):
        """Build one subtree; returns (gateway npu id, member npu ids)."""
        if isinstance(node_spec, int):
            ids = topo.add_npus(node_spec)
            for n in ids:
                paths[n] = tuple(path)
            if node_spec == 2:
                topo.add_bidir_link(ids[0], ids[1])
            elif node_spec > 2:
                for i in range(node_spec):
                    topo.add_bidir_link(ids[i], ids[(i + 1) % node_spec])
            return ids[0], ids
        gws, members = [], []
        for i, child in enumerate(node_spec):
            g, m = build(child, path + [i])
            gws.append(g)
            members.extend(m)
        sw = topo.add_node(NodeType.SWITCH)
        paths[sw] = tuple(path) + (-1,) if path else (-1,)
        for g in gws:
            topo.add_bidir_link(g, sw)
        return gws[0], members

    paths: dict[int, tuple] = {}
    build(spec, [])
    # occasionally corrupt the partition to exercise the error/fallback
    # path: truncate a random NPU's path or mark it shared
    pod_of = [paths[n] for n in range(topo.num_nodes)]
    if rng.random() < 0.25 and len(topo.npus) > 2:
        victim = rng.choice(topo.npus)
        pod_of[victim] = (-1,) if rng.random() < 0.5 else \
            pod_of[victim][:max(1, len(pod_of[victim]) - 1)]
    try:
        topo.set_partition(pod_of)
    except ValueError:
        # corruption may break density — set_partition legally refuses;
        # degrade to the top level, or to no partition at all
        try:
            topo.set_partition([p[0] for p in pod_of])
        except ValueError:
            pass
    return topo


def check_synthesis_seed(seed: int) -> None:
    """Claim 1: valid schedule, or HierarchyError + validating fallback."""
    rng = random.Random(seed)
    topo = _gen_fabric(rng)
    group = topo.npus
    eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
    kind = rng.choice(["all_gather", "all_to_all", "reduce_scatter",
                       "all_reduce"])
    try:
        hier = getattr(eng.hierarchical(), kind)(group)
    except HierarchyError:
        hier = None  # the legal refusal: fall back flat below
    if hier is not None:
        hier.validate(mode="oracle")
    auto = getattr(eng, kind)(group)  # auto route: hier or flat fallback
    auto.validate(mode="oracle")
    flat = eng.collective(
        CollectiveRequest(kind, group=tuple(group), hierarchy="never"))
    key = lambda a: sorted(
        (c.chunk, tuple(sorted(getattr(c, "srcs", [getattr(c, "src", -1)]))),
         tuple(sorted(c.dests)))
        for c in a.conditions)
    assert key(auto) == key(flat)
    if hier is not None:
        assert key(hier) == key(flat)


def _corrupt(alg: CollectiveAlgorithm, rng: random.Random):
    """One guaranteed-invalid single-transfer mutation, or None if this
    schedule offers no target for the drawn mutation kind."""
    ts = list(alg.transfers)
    if not ts:
        return None
    k = rng.randrange(len(ts))
    t = ts[k]
    kind = rng.choice(["duration", "unknown_chunk", "drop", "early"])
    if kind == "duration":
        ts[k] = Transfer(t.chunk, t.link, t.src, t.dst, t.start,
                         t.end + 0.5, t.reduce)
    elif kind == "unknown_chunk":
        bogus = max(c.chunk for c in alg.conditions) + 1
        ts[k] = Transfer(bogus, t.link, t.src, t.dst, t.start, t.end,
                         t.reduce)
    elif kind == "drop":
        # drop the sole delivery of some (chunk, dest) pair
        arrivals: dict[tuple[int, int], list[int]] = {}
        for i, x in enumerate(ts):
            arrivals.setdefault((x.chunk, x.dst), []).append(i)
        dest_of = {}
        for c in alg.conditions:
            for d in c.dests:
                dest_of.setdefault(c.chunk, set()).add(d)
        victims = [i for (ch, d), idx in arrivals.items()
                   if len(idx) == 1 and d in dest_of.get(ch, ())
                   for i in idx]
        if not victims:
            return None
        ts.pop(rng.choice(victims))
    else:  # early: an origin transfer starts before its chunk's release
        origins = [i for i, x in enumerate(ts)
                   if x.start <= min(r.start for r in ts
                                     if r.chunk == x.chunk)]
        i = rng.choice(origins)
        t = ts[i]
        ts[i] = Transfer(t.chunk, t.link, t.src, t.dst, t.start - 1.0,
                         t.end - 1.0, t.reduce)
        # shifting the earliest transfer of a release-0 chunk one step
        # earlier lands it before the release — always a violation
        rel = {c.chunk: c.release for c in alg.conditions}
        if ts[i].start >= rel[t.chunk]:
            return None
    return CollectiveAlgorithm(alg.topology, list(alg.conditions), ts,
                               name=alg.name)


def check_release_floor_seed(seed: int) -> None:
    """Claim 3: per-chunk release floors are only ever *raised* through
    phase composition. Whatever regime ``spanning()`` resolves, a
    condition's release survives every phase kind it crosses — intra
    resolution, the boundary inter phase, and the per-pod scatter — so no
    transfer of a chunk ever starts below the caller's floor."""
    rng = random.Random(seed)
    topo = _gen_fabric(rng)
    if topo.partition is None:
        return
    eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
    npus = topo.npus
    conds = []
    for ck in range(rng.randint(1, 6)):
        src = rng.choice(npus)
        others = [n for n in npus if n != src]
        if not others:
            return
        dests = rng.sample(others, rng.randint(1, min(4, len(others))))
        conds.append(Condition(ck, src, frozenset(dests),
                               release=float(rng.randint(0, 8))))
    try:
        alg = eng.hierarchical().spanning(conds)
    except HierarchyError:
        return  # legal refusal (single pod, missing gateways, ...)
    alg.validate(mode="oracle")
    rel = {c.chunk: c.release for c in conds}
    for t in alg.transfers:
        assert t.start >= rel[t.chunk], (
            f"chunk {t.chunk}: transfer at {t.start} starts below the "
            f"caller's release {rel[t.chunk]} — a phase lowered the floor")


def check_corruption_seed(seed: int) -> None:
    """Claim 2: a single-transfer mutation flips bulk validation."""
    rng = random.Random(seed)
    topo = _gen_fabric(rng)
    eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
    kind = rng.choice(["all_gather", "all_to_all"])
    alg = getattr(eng, kind)(topo.npus)
    alg.validate(mode="bulk")  # the uncorrupted schedule passes
    bad = _corrupt(alg, rng)
    if bad is None:
        return  # no target for the drawn mutation on this schedule
    with pytest.raises(AssertionError):
        bad.validate(mode="bulk")


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_nested_partition_synthesis(seed):
        check_synthesis_seed(seed)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_corruption_flips_bulk_validation(seed):
        check_corruption_seed(seed)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_release_floors_never_lowered(seed):
        check_release_floor_seed(seed)

else:  # seed-sweep fallback: same generator, fixed seeds

    @pytest.mark.parametrize("seed", range(0, 60))
    def test_random_nested_partition_synthesis(seed):
        check_synthesis_seed(seed)

    @pytest.mark.parametrize("seed", range(1000, 1060))
    def test_random_corruption_flips_bulk_validation(seed):
        check_corruption_seed(seed)

    @pytest.mark.parametrize("seed", range(2000, 2060))
    def test_random_release_floors_never_lowered(seed):
        check_release_floor_seed(seed)
