"""Shared pytest wiring: the ``mesh`` marker.

``mesh``-marked tests execute collectives on a multi-device jax mesh and
need at least 8 devices — in CI that is the host-CPU mesh forced via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
initializes). When fewer devices are available the tests are skipped, so
plain tier-1 runs stay green on a single-device install while
``pytest -m mesh`` exercises the executor end to end.
"""

import pytest


def pytest_collection_modifyitems(config, items):
    if not any(item.get_closest_marker("mesh") for item in items):
        return
    try:
        import jax

        n = jax.device_count()
    except Exception:  # noqa: BLE001 - any import/backend failure means no mesh
        n = 0
    if n >= 8:
        return
    skip = pytest.mark.skip(
        reason=f"mesh tests need >= 8 jax devices (have {n}); run with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=8"
    )
    for item in items:
        if item.get_closest_marker("mesh"):
            item.add_marker(skip)
