"""Tests for the multi-tenant plan service: planner memoization, warm()
prefetch accounting, eviction/bytes metrics, and cross-process safety of a
shared ``PCCL_CACHE_DIR`` under concurrent readers + a churning writer."""

import subprocess
import sys

import pytest

from repro.core import (AlgorithmRegistry, CollectiveRequest, PlanService,
                        SynthesisEngine)
from repro.topology import torus2d

AXES = {"data": 4, "model": 4}


def torus_rows(rows, cols):
    return [[r * cols + c for c in range(cols)] for r in range(rows)]


class TestPlanService:
    def test_warm_prefetch_hit_accounting(self):
        svc = PlanService(registry=AlgorithmRegistry())
        topo = torus2d(4, 4)
        stats = svc.warm(topo, AXES, kinds=("all_gather",))
        # 2 axes x 4 groups = 8 lookups, one cold synthesis per axis
        assert stats["misses"] == 2
        assert stats["hits"] == 6
        # a second warm of the same working set is all hits
        stats = svc.warm(topo, AXES, kinds=("all_gather",))
        assert stats["misses"] == 2
        assert stats["hits"] == 14
        m = svc.metrics()
        assert m["warm_requested"] == 2 and m["warm_completed"] == 2
        assert m["warm_failed"] == 0
        assert m["entries"] == 2 and m["planners"] == 1

    def test_background_warm_and_drain(self):
        with PlanService(registry=AlgorithmRegistry()) as svc:
            topo = torus2d(4, 4)
            fut = svc.warm(topo, AXES, kinds=("all_gather",), block=False)
            svc.drain()
            assert fut.done()
            assert fut.result()["misses"] == 2
            # the prefetched working set serves plan() as pure hits
            before = svc.metrics()["misses"]
            alg = svc.plan(topo, AXES, "all_gather", "data", 3)
            alg.validate()
            assert svc.metrics()["misses"] == before

    def test_planner_memoized_per_topology_and_axes(self):
        svc = PlanService(registry=AlgorithmRegistry())
        topo = torus2d(4, 4)
        p1 = svc.planner(topo, AXES)
        p2 = svc.planner(topo, AXES)
        assert p1 is p2
        p3 = svc.planner(topo, {"data": 2, "model": 8})
        assert p3 is not p1
        assert svc.metrics()["planners"] == 2

    def test_eviction_metrics(self):
        svc = PlanService(registry=AlgorithmRegistry(max_entries=1))
        topo = torus2d(4, 4)
        svc.plan(topo, AXES, "all_gather", "data")
        svc.plan(topo, AXES, "all_to_all", "data")  # evicts the all_gather
        svc.plan(topo, AXES, "all_gather", "data")  # re-synthesizes
        m = svc.metrics()
        assert m["evictions"] == 2
        assert m["misses"] == 3
        assert m["entries"] == 1

    def test_disk_byte_metrics(self, tmp_path):
        svc = PlanService(cache_dir=str(tmp_path))
        topo = torus2d(4, 4)
        svc.warm(topo, AXES, kinds=("all_gather",))
        m = svc.metrics()
        assert m["bytes_stored"] > 0 and m["bytes_loaded"] == 0
        # a second tenant (fresh service, same dir) loads instead of storing
        svc2 = PlanService(cache_dir=str(tmp_path))
        svc2.warm(topo, AXES, kinds=("all_gather",))
        m2 = svc2.metrics()
        assert m2["disk_hits"] == 2 and m2["misses"] == 0
        assert m2["bytes_loaded"] > 0


# Each worker makes `iters` passes over the shared cache dir with a fresh
# registry per pass (forcing the disk path); the writer additionally retires
# every entry before each pass, so readers race against unlink + atomic
# rewrite. Any exception (partial read, crash on a half-visible entry) fails
# the worker.
_STRESS_WORKER = """
import os, sys
from repro.core import AlgorithmRegistry, CollectiveRequest, SynthesisEngine
from repro.topology import torus2d

cache, role, iters = sys.argv[1], sys.argv[2], int(sys.argv[3])
topo = torus2d(4, 4)
rows = [[r * 4 + c for c in range(4)] for r in range(4)]
expected = {}
for i in range(iters):
    if role == "writer":
        for f in os.listdir(cache):
            if f.endswith(".npz"):
                try:
                    os.remove(os.path.join(cache, f))
                except OSError:
                    pass
    reg = AlgorithmRegistry(cache_dir=cache)
    eng = SynthesisEngine(topo, registry=reg)
    nbytes = float(i % 2 + 1)
    alg = eng.collective(CollectiveRequest(
        "all_gather", group=tuple(rows[i % 4]), bytes=nbytes))
    alg.validate()
    key = nbytes
    if key in expected:
        assert alg.makespan == expected[key], "nondeterministic plan"
    expected[key] = alg.makespan
print("ok")
"""


@pytest.mark.slow
def test_shared_cache_dir_concurrent_readers_one_writer(tmp_path):
    """Three reader processes + one writer churning a shared PCCL_CACHE_DIR:
    nobody may crash, and every served plan must validate."""
    cache = tmp_path / "cache"
    cache.mkdir()
    procs = []
    for role, iters in (("writer", 30), ("reader", 40), ("reader", 40),
                        ("reader", 40)):
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _STRESS_WORKER, str(cache), role,
             str(iters)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, f"worker failed:\n{err}"
        assert out.strip() == "ok"
    # the survivors on disk are valid, loadable entries
    reg = AlgorithmRegistry(cache_dir=str(cache))
    eng = SynthesisEngine(torus2d(4, 4), registry=reg)
    eng.all_gather([0, 1, 2, 3]).validate()


class TestDiskEviction:
    """Size-capped disk-tier LRU: the shared cache dir stays under
    ``max_disk_bytes``, stalest entries (by manifest access time) go
    first, and the sweep survives corrupt manifests and races."""

    def _store(self, reg, nbytes):
        import os
        before = {f for f in os.listdir(reg.cache_dir)
                  if f.endswith(".npz")}
        eng = SynthesisEngine(torus2d(4, 4), registry=reg)
        eng.collective(CollectiveRequest(
            "all_gather", group=tuple(range(16)), bytes=nbytes))
        after = {f for f in os.listdir(reg.cache_dir)
                 if f.endswith(".npz")}
        new = after - before
        return next(iter(new)) if new else None

    def test_size_capped_lru(self, tmp_path):
        import os
        probe = AlgorithmRegistry(cache_dir=str(tmp_path))
        self._store(probe, 1.0)
        one = probe.stats.bytes_stored
        assert one > 0
        cap = int(one * 2.5)
        reg = AlgorithmRegistry(cache_dir=str(tmp_path),
                                max_disk_bytes=cap)
        for b in (2.0, 3.0, 4.0):
            self._store(reg, b)
        m = reg.stats.as_dict()
        assert m["disk_evictions"] >= 1
        assert 0 < m["disk_bytes"] <= cap
        files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert sum(os.path.getsize(tmp_path / f) for f in files) <= cap

    def test_lru_prefers_stale_entries(self, tmp_path):
        import time

        big = 1 << 40
        reg = AlgorithmRegistry(cache_dir=str(tmp_path),
                                max_disk_bytes=big)
        a = self._store(reg, 1.0)
        time.sleep(0.01)
        b = self._store(reg, 2.0)
        one = reg.stats.bytes_stored // 2
        time.sleep(0.01)
        # a fresh tenant loads entry A from disk: A is now *fresher* than B
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path),
                                 max_disk_bytes=big)
        self._store(reg2, 1.0)
        assert reg2.stats.disk_hits == 1
        time.sleep(0.01)
        # a capped store forces a sweep: B (stalest) goes, A survives
        reg3 = AlgorithmRegistry(cache_dir=str(tmp_path),
                                 max_disk_bytes=int(one * 2.5))
        c = self._store(reg3, 3.0)
        assert reg3.stats.disk_evictions >= 1
        assert (tmp_path / a).exists(), "recently-loaded entry was evicted"
        assert not (tmp_path / b).exists(), "stalest entry survived the cap"
        assert c is not None and (tmp_path / c).exists()

    def test_cache_max_bytes_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("PCCL_CACHE_MAX_BYTES", "12345")
        assert AlgorithmRegistry(
            cache_dir=str(tmp_path)).max_disk_bytes == 12345
        monkeypatch.setenv("PCCL_CACHE_MAX_BYTES", "not-a-number")
        assert AlgorithmRegistry(
            cache_dir=str(tmp_path)).max_disk_bytes is None
        monkeypatch.delenv("PCCL_CACHE_MAX_BYTES")
        assert AlgorithmRegistry(
            cache_dir=str(tmp_path), max_disk_bytes=7).max_disk_bytes == 7

    def test_sweep_tolerates_corruption_and_races(self, tmp_path):
        import os
        probe = AlgorithmRegistry(cache_dir=str(tmp_path))
        first = self._store(probe, 1.0)
        one = probe.stats.bytes_stored
        reg = AlgorithmRegistry(cache_dir=str(tmp_path),
                                max_disk_bytes=int(one * 1.5))
        # a killed writer left a corrupt manifest; a concurrent evictor
        # removed an entry behind our back
        (tmp_path / "manifest.json").write_text("{definitely not json")
        os.remove(tmp_path / first)
        self._store(reg, 2.0)
        self._store(reg, 3.0)
        m = reg.stats.as_dict()
        assert m["disk_bytes"] <= int(one * 1.5)
        # the dir is still serviceable: a fresh tenant loads what survived
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        eng = SynthesisEngine(torus2d(4, 4), registry=reg2)
        eng.collective(CollectiveRequest(
            "all_gather", group=tuple(range(16)), bytes=3.0)).validate()

    def test_metrics_expose_disk_eviction_counters(self, tmp_path):
        svc = PlanService(cache_dir=str(tmp_path), max_disk_bytes=1 << 40)
        topo = torus2d(4, 4)
        svc.warm(topo, AXES, kinds=("all_gather",))
        m = svc.metrics()
        assert m["disk_evictions"] == 0
        assert m["disk_bytes"] > 0  # the sweep ran and measured the dir
