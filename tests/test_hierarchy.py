"""Tests for the hierarchical synthesis pipeline: phase composition
(PhasePlan), chunk-delivery equivalence against flat synthesis, makespan
bounds, per-pod plan reuse through the registry, and the launch-layer
routing."""

import pytest

from repro.core import (
    AlgorithmRegistry,
    ChunkIds,
    CollectiveRequest,
    Condition,
    HierarchicalSynthesizer,
    HierarchyError,
    PhasePlan,
    PhaseSpec,
    SynthesisEngine,
    phase_breakdown,
    replay_algorithm,
)
from repro.topology import multi_pod, ring, star_switch, two_level_switch
from repro.topology.generators import grid_hypercube

# hierarchical simulated makespan must stay within this factor of flat
# synthesis on fabrics where flat is feasible (ISSUE-3 acceptance bound)
_MAKESPAN_BOUND = 1.25


def _delivery(alg):
    """(chunk, src-or-srcs, dests) per condition — the delivery contract."""
    return sorted((c.chunk, c.src, tuple(sorted(c.dests)))
                  for c in alg.conditions)


class TestPhasePlan:
    def test_two_phase_chain(self):
        topo = ring(4)
        eng = SynthesisEngine(topo)
        c1 = [Condition(0, 0, frozenset([1]))]
        c2 = [Condition(1, 1, frozenset([2]))]
        alg = eng.synthesize_plan(PhasePlan(
            [PhaseSpec("a", conds=c1),
             PhaseSpec("b", conds=c2, after=("a",))],
            conditions=c1 + c2, name="chain"))
        alg.validate()
        bd = phase_breakdown(alg)
        assert bd["b"]["start"] >= bd["a"]["end"]

    def test_algorithm_phase_shifted_to_floor(self):
        topo = ring(4)
        eng = SynthesisEngine(topo)
        pre = eng.synthesize([Condition(0, 0, frozenset([1]))])
        alg = eng.synthesize_plan(PhasePlan(
            [PhaseSpec("x", conds=[Condition(1, 0, frozenset([1]))]),
             PhaseSpec("y", algorithm=pre, after=("x",),
                       chunk_map={0: 2})],
            conditions=[Condition(1, 0, frozenset([1])),
                        Condition(2, 0, frozenset([1]))]))
        alg.validate()
        ys = [t for t in alg.transfers if t.chunk == 2]
        assert min(t.start for t in ys) >= phase_breakdown(alg)["x"]["end"]

    def test_preload_from_shifted_phase_occupies_real_window(self):
        """Preloading a floor-shifted algorithm phase must commit its
        *effective* (shifted) occupancy, not its local times — otherwise a
        later phase schedules into the shifted window and congests."""
        topo = ring(4)
        eng = SynthesisEngine(topo)
        pre = eng.synthesize([Condition(1, 0, frozenset([1]))])
        alg = eng.synthesize_plan(PhasePlan(
            [PhaseSpec("a", conds=[Condition(0, 0, frozenset([2]))]),
             PhaseSpec("b", algorithm=pre, after=("a",)),
             PhaseSpec("c", conds=[Condition(2, 0, frozenset([1]))],
                       after=("a",), preload_from=("b",))],
            conditions=[Condition(0, 0, frozenset([2])),
                        Condition(1, 0, frozenset([1])),
                        Condition(2, 0, frozenset([1]))]))
        alg.validate()

    def test_duplicate_phase_name_rejected(self):
        eng = SynthesisEngine(ring(4))
        c = [Condition(0, 0, frozenset([1]))]
        with pytest.raises(ValueError, match="duplicate"):
            eng.synthesize_plan(PhasePlan(
                [PhaseSpec("a", conds=c), PhaseSpec("a", conds=c)],
                conditions=c))

    def test_unknown_dependency_rejected(self):
        eng = SynthesisEngine(ring(4))
        c = [Condition(0, 0, frozenset([1]))]
        with pytest.raises(ValueError, match="unknown"):
            eng.synthesize_plan(PhasePlan(
                [PhaseSpec("a", conds=c, after=("missing",))],
                conditions=c))

    def test_preload_from_cross_topology_rejected(self):
        topo = multi_pod(2, 2, 2, unit_links=True)
        eng = SynthesisEngine(topo)
        sub = topo.pod_subtopology(0)
        with pytest.raises(ValueError, match="different topology"):
            eng.synthesize_plan(PhasePlan(
                [PhaseSpec("a", conds=[Condition(0, 0, frozenset([1]))]),
                 PhaseSpec("b",
                           conds=[Condition(1, 0, frozenset([1]))],
                           topology=sub.topology, node_map=sub.nodes,
                           link_map=sub.links, preload_from=("a",))],
                conditions=[]))

    def test_all_reduce_still_composes(self):
        # the refactor of all-reduce onto PhasePlan keeps its contract
        eng = SynthesisEngine(ring(4))
        alg = eng.all_reduce(list(range(4)))
        alg.validate()
        assert [n for n, _, _ in alg.phase_spans] == \
            ["reduce_scatter", "all_gather"]


class TestDifferentialEquivalence:
    """Flat and hierarchical synthesis must fulfil the same conditions with
    every chunk delivered; hierarchical makespan stays within the bound."""

    @pytest.fixture(scope="class")
    def fabric(self):
        return multi_pod(2, 4, 8, unit_links=True)

    @pytest.mark.parametrize("kind", ["all_gather", "all_to_all"])
    def test_chunk_delivery_equivalence(self, fabric, kind):
        eng = SynthesisEngine(fabric, registry=AlgorithmRegistry())
        hier = getattr(eng, kind)(fabric.npus)
        flat = eng.collective(CollectiveRequest(
            kind, group=tuple(fabric.npus), hierarchy="never"))
        assert hier.name.startswith("pccl_hier")
        hier.validate()  # every chunk delivered per its conditions
        flat.validate()
        assert _delivery(hier) == _delivery(flat)
        # replay agrees: same chunks complete, none missing
        assert set(replay_algorithm(hier).completion) == \
            set(replay_algorithm(flat).completion)

    @pytest.mark.parametrize("kind", ["all_gather", "all_to_all"])
    def test_makespan_within_bound(self, fabric, kind):
        eng = SynthesisEngine(fabric, registry=AlgorithmRegistry())
        hier = getattr(eng, kind)(fabric.npus)
        flat = eng.collective(CollectiveRequest(
            kind, group=tuple(fabric.npus), hierarchy="never"))
        assert hier.makespan <= _MAKESPAN_BOUND * flat.makespan, (
            f"{kind}: hierarchical {hier.makespan} vs flat {flat.makespan}"
        )

    def test_sequential_regime_also_valid(self, fabric):
        h = HierarchicalSynthesizer(SynthesisEngine(fabric))
        for kind in ("all_gather", "all_to_all"):
            alg = getattr(h, kind)(fabric.npus, pipeline=False)
            alg.validate()
            names = [n for n, _, _ in alg.phase_spans]
            assert "inter" in names and any(
                n.startswith("intra:") for n in names)


class TestFabricFamilies:
    def test_heterogeneous_multi_pod(self):
        topo = multi_pod(2, 4, 4, dci_ports_per_pod=4)  # real alpha-beta
        eng = SynthesisEngine(topo)
        alg = eng.all_gather(topo.npus)
        assert alg.name == "pccl_hier_all_gather"
        alg.validate()

    def test_two_level_switch_ports(self):
        # pods whose boundary ports are switches: gateways fall back to the
        # NPUs behind the port, pipelining is refused (shared links)
        topo = two_level_switch(3, npus_per_node=4)
        h = HierarchicalSynthesizer(SynthesisEngine(topo))
        alg = h.all_to_all(list(range(12)))
        alg.validate()
        with pytest.raises(HierarchyError, match="pipeline"):
            h.all_to_all(list(range(12)), pipeline=True)

    def test_grid_hypercube_planes(self):
        topo = grid_hypercube(4, 3)  # 64 NPUs, 4 plane-pods, no switch
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        for kind in ("all_gather", "all_to_all"):
            alg = getattr(eng, kind)(topo.npus)
            assert alg.name.startswith("pccl_hier")
            alg.validate()

    def test_subgroup_spanning_pods(self):
        topo = multi_pod(2, 4, 8, unit_links=True)
        group = list(range(8, 24)) + list(range(40, 56))  # interior rows
        eng = SynthesisEngine(topo)
        alg = eng.all_gather(group)
        alg.validate()
        assert len(alg.conditions) == len(group)

    def test_single_pod_group_stays_flat(self):
        topo = multi_pod(2, 4, 8, unit_links=True)
        eng = SynthesisEngine(topo)
        alg = eng.all_gather(list(range(32)))  # pod 0 only
        assert alg.name == "pccl_all_gather"
        alg.validate()

    def test_unpartitioned_fabric_stays_flat(self):
        eng = SynthesisEngine(ring(8))
        alg = eng.all_to_all(list(range(8)))
        assert alg.name == "pccl_all_to_all"
        with pytest.raises(HierarchyError):
            HierarchicalSynthesizer(eng).all_to_all(list(range(8)))


class TestPodPlanReuse:
    def test_isomorphic_pods_cost_one_synthesis(self):
        topo = multi_pod(4, 4, 4, unit_links=True, dci_ports_per_pod=4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        eng.hierarchical().all_gather(topo.npus, pipeline=False)
        # phases: intra x4 (1 miss + 3 hits), inter (1 miss),
        # scatter x4 (1 miss + 3 hits)
        assert reg.stats.misses == 3
        assert reg.stats.hits == 6

    def test_disk_roundtrip_of_pod_plans(self, tmp_path):
        topo = multi_pod(2, 2, 4, unit_links=True, dci_ports_per_pod=4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg1 = SynthesisEngine(topo, registry=reg1).hierarchical().all_gather(
            topo.npus, pipeline=False)
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg2 = SynthesisEngine(topo, registry=reg2).hierarchical().all_gather(
            topo.npus, pipeline=False)
        alg2.validate()
        assert reg2.stats.misses == 0 and reg2.stats.disk_hits > 0
        assert alg2.makespan == alg1.makespan


class TestHierarchicalReductions:
    """Reduce-Scatter/All-Reduce via per-phase time reversal: delivery
    contract and reduction algebra against the oracle, makespan no worse
    than flat, reversal invariants, registry reuse, and fallbacks."""

    @pytest.fixture(scope="class")
    def fabric(self):
        return multi_pod(2, 4, 8, unit_links=True)

    def _reduction_state(self, alg):
        """Independent replay of the reduction algebra: contributions held
        per (node, chunk) after executing the schedule in time order."""
        holdings = {}
        for c in alg.conditions:
            for s in c.srcs:
                holdings[(s, c.chunk)] = frozenset([s])
        full = {c.chunk: c.srcs for c in alg.conditions}
        for t in sorted(alg.transfers, key=lambda t: t.start):
            held = holdings[(t.src, t.chunk)]
            if t.reduce:
                prev = holdings.get((t.dst, t.chunk), frozenset())
                assert not (prev & held), "double-counted contribution"
                holdings[(t.dst, t.chunk)] = prev | held
                if held != full[t.chunk]:
                    del holdings[(t.src, t.chunk)]
            else:
                holdings[(t.dst, t.chunk)] = held
        return holdings, full

    def test_reduce_scatter_matches_oracle_state(self, fabric):
        eng = SynthesisEngine(fabric, registry=AlgorithmRegistry())
        hier = eng.reduce_scatter(fabric.npus)
        assert hier.name == "pccl_hier_reduce_scatter"
        hier.validate(mode="oracle")
        assert all(t.reduce for t in hier.transfers)
        # every owner ends with exactly the full contribution set
        holdings, full = self._reduction_state(hier)
        for c in hier.conditions:
            for d in c.dests:
                assert holdings[(d, c.chunk)] == full[c.chunk]
        # same ownership contract as the flat route
        flat = eng.collective(CollectiveRequest(
            "reduce_scatter", group=tuple(fabric.npus), hierarchy="never"))
        assert flat.name == "pccl_reduce_scatter"
        flat.validate(mode="oracle")
        key = lambda a: sorted(
            (c.chunk, tuple(sorted(c.srcs)), tuple(sorted(c.dests)))
            for c in a.conditions)
        assert key(hier) == key(flat)

    def test_all_reduce_composes_rs_then_ag(self, fabric):
        eng = SynthesisEngine(fabric, registry=AlgorithmRegistry())
        alg = eng.all_reduce(fabric.npus)
        assert alg.name == "pccl_hier_all_reduce"
        alg.validate(mode="oracle")
        assert [n for n, _, _ in alg.top_phase_spans()] == \
            ["reduce_scatter", "all_gather"]
        # sub-phase provenance rides along as nested "parent/child" spans
        nested = [n for n, _, _ in alg.phase_spans if "/" in n]
        assert any(n.startswith("reduce_scatter/") for n in nested)
        assert any(n.startswith("all_gather/") for n in nested)
        bd = phase_breakdown(alg)
        assert bd["all_gather"]["start"] >= bd["reduce_scatter"]["end"]

    @pytest.mark.parametrize("kind", ["reduce_scatter", "all_reduce"])
    def test_makespan_not_worse_than_flat(self, fabric, kind):
        eng = SynthesisEngine(fabric, registry=AlgorithmRegistry())
        hier = getattr(eng, kind)(fabric.npus)
        flat = eng.collective(CollectiveRequest(
            kind, group=tuple(fabric.npus), hierarchy="never"))
        assert hier.makespan <= flat.makespan, (
            f"{kind}: hierarchical {hier.makespan} vs flat {flat.makespan}")

    def test_reversal_invariants(self, fabric):
        """The reduction is an in-forest: per chunk each device forwards
        its partial at most once, and a forward never precedes a merged
        partial's arrival — the invariants time reversal promises."""
        eng = SynthesisEngine(fabric)
        alg = eng.hierarchical().reduce_scatter(fabric.npus)
        sent = set()
        arrivals = {}
        for t in alg.transfers:
            arrivals.setdefault((t.chunk, t.dst), []).append(t.end)
        for t in alg.transfers:
            assert (t.chunk, t.src) not in sent
            sent.add((t.chunk, t.src))
            for end in arrivals.get((t.chunk, t.src), ()):
                assert t.start >= end - 1e-9
        # reversal round-trip of the phase provenance: reversed spans run
        # scatter (leaf reduce) -> inter -> intra (final fold)
        names = [n for n, _, _ in alg.phase_spans]
        assert names.index("inter") > 0
        assert any(n.startswith("scatter:") for n in names)

    def test_sequential_regime_and_registry_reuse(self):
        topo = multi_pod(4, 4, 4, unit_links=True, dci_ports_per_pod=4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        alg = eng.hierarchical().reduce_scatter(topo.npus, pipeline=False)
        alg.validate()
        # reversed-fabric phases share plans exactly like the forward ones:
        # intra x4 (1 miss + 3 hits), inter (1 miss), scatter x4 (1 + 3)
        assert reg.stats.misses == 3
        assert reg.stats.hits == 6

    def test_grid_hypercube_reductions(self):
        topo = grid_hypercube(4, 3)  # 64 NPUs, 4 plane-pods, no switch
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        for kind in ("reduce_scatter", "all_reduce"):
            alg = getattr(eng, kind)(topo.npus)
            assert alg.name.startswith("pccl_hier")
            alg.validate()

    def test_shared_device_fabric_falls_back_flat(self):
        # two_level_switch pods share their local switches with the
        # boundary fabric: the reversed composition would double-forward
        # partials, so the in-forest guard routes reductions to flat
        topo = two_level_switch(3, npus_per_node=4)
        eng = SynthesisEngine(topo)
        alg = eng.reduce_scatter(list(range(12)))
        assert alg.name == "pccl_reduce_scatter"
        alg.validate()
        with pytest.raises(HierarchyError, match="in-forest"):
            eng.hierarchical().reduce_scatter(list(range(12)))

    def test_subgroup_spanning_pods(self, fabric):
        group = list(range(8, 24)) + list(range(40, 56))
        eng = SynthesisEngine(fabric)
        alg = eng.all_reduce(group)
        assert alg.name == "pccl_hier_all_reduce"
        alg.validate(mode="oracle")

    def test_single_pod_group_stays_flat(self, fabric):
        eng = SynthesisEngine(fabric)
        alg = eng.reduce_scatter(list(range(32)))  # pod 0 only
        assert alg.name == "pccl_reduce_scatter"
        alg.validate()

    def test_planner_routes_reductions(self):
        from repro.launch.sharding import MeshCollectivePlanner

        topo = multi_pod(2, 4, 8, unit_links=True)
        pl = MeshCollectivePlanner(
            topo, {"pod": 2, "data": 4, "model": 8},
            registry=AlgorithmRegistry())
        alg = pl.algorithm("reduce_scatter", "pod", 1)
        assert alg.name == "pccl_hier_reduce_scatter"
        alg.validate()
        ar = pl.algorithm("all_reduce", "pod", 0)
        assert ar.name == "pccl_hier_all_reduce"
        flat = pl.algorithm("reduce_scatter", "model", 0)
        assert flat.name == "pccl_reduce_scatter"


class TestPathReplication:
    def test_replicated_runs_stay_valid(self):
        topo = ring(6)
        eng = SynthesisEngine(topo)
        ids = ChunkIds()
        conds = [Condition(ids.next(), 0, frozenset([3]))
                 for _ in range(12)]
        rep = eng.synthesize(conds, replicate=True)
        ref = eng.synthesize(conds)
        rep.validate()
        ref.validate()
        assert rep.makespan == ref.makespan  # serial runs pack identically

    def test_replication_gated_off_on_limited_switch(self):
        topo = star_switch(4, buffer_limit=1)
        eng = SynthesisEngine(topo)
        ids = ChunkIds()
        conds = [Condition(ids.next(), 0, frozenset([2]))
                 for _ in range(4)]
        alg = eng.synthesize(conds, replicate=True)  # silently full search
        alg.validate()

    def test_flat_default_unchanged(self):
        # replicate defaults off: flat named collectives are byte-stable
        topo = ring(5)
        a = SynthesisEngine(topo).all_to_all(list(range(5)))
        b = SynthesisEngine(topo).all_to_all(list(range(5)))
        assert [(t.chunk, t.link, t.start) for t in a.transfers] == \
            [(t.chunk, t.link, t.start) for t in b.transfers]


class TestPlannerRouting:
    def test_pod_spanning_axis_routes_hierarchically(self):
        from repro.launch.sharding import MeshCollectivePlanner

        topo = multi_pod(2, 4, 8, unit_links=True)
        pl = MeshCollectivePlanner(
            topo, {"pod": 2, "data": 4, "model": 8},
            registry=AlgorithmRegistry())
        assert pl.spans_pods("pod")
        assert not pl.spans_pods("model")
        alg = pl.algorithm("all_gather", "pod", 3)
        assert alg.name == "pccl_hier_all_gather"
        alg.validate()
        flat = pl.algorithm("all_gather", "model", 0)
        assert flat.name == "pccl_all_gather"


class TestHierarchyAlwaysPolicy:
    def test_always_on_unpartitioned_raises(self):
        eng = SynthesisEngine(ring(8))
        for kind in ("all_gather", "all_to_all", "reduce_scatter",
                     "all_reduce"):
            with pytest.raises(HierarchyError, match="no partition"):
                eng.collective(CollectiveRequest(
                    kind, group=tuple(range(8)), hierarchy="always"))

    def test_always_not_served_cached_auto_fallback(self):
        """An auto call that fell back to flat must not satisfy a later
        hierarchy="always" call through the registry: "always" re-attempts
        the hierarchical route and raises on infeasibility."""
        topo = two_level_switch(3, npus_per_node=4)
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        group = list(range(12))
        auto = eng.reduce_scatter(group)  # in-forest guard -> flat fallback
        assert auto.name == "pccl_reduce_scatter"
        with pytest.raises(HierarchyError):
            eng.collective(CollectiveRequest(
                "reduce_scatter", group=tuple(group), hierarchy="always"))
