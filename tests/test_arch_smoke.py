"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run one forward/loss (+grad) step and one decode step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.models import LM

ARCHS = sorted(REGISTRY)
B, S = 2, 32


def make_batch(cfg, rng):
    kt, kp = jax.random.split(rng)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kp, (B, cfg.encoder_seq, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(kp, (B, cfg.num_patches, cfg.d_model),
                                             jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch, rng):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(rng)
    batch = make_batch(cfg, rng)

    (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(
        params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0.0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(rng)
    cache = lm.decode_init(B, max_seq=16)
    tokens = jax.random.randint(rng, (B,), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(lm.decode_step)(params, cache, tokens,
                                             jnp.asarray(3))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits))), f"{arch}: NaN logits"
    # cache structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_assigned_config_values(arch):
    """The full (non-reduced) configs carry the exact assigned shapes."""
    cfg = get_config(arch)
    expected = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch.startswith("granite-moe-3b"):
        assert (cfg.num_experts, cfg.experts_per_token) == (40, 8)
    if arch.startswith("granite-moe-1b"):
        assert (cfg.num_experts, cfg.experts_per_token) == (32, 8)
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64


def test_decode_matches_train_forward_dense():
    """Step-by-step decode reproduces the teacher-forced forward logits."""
    cfg = get_config("llama3.2-1b").reduced(num_layers=2, dtype="float32")
    lm = LM(cfg)
    rng = jax.random.PRNGKey(1)
    params = lm.init(rng)
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)

    # teacher-forced logits via the loss path
    from repro.models.layers import embed
    import repro.models.transformer as tfm

    h = embed(params["embed"], tokens, jnp.float32)
    h, _ = lm._body_dense(params, h)
    full_logits = lm._logits(params, h)  # [1, 8, V]

    cache = lm.decode_init(1, max_seq=8, dtype=jnp.float32)
    outs = []
    step = jax.jit(lm.decode_step)
    for t in range(8):
        logits, cache = step(params, cache, tokens[:, t], jnp.asarray(t))
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=1e-4, atol=1e-4)


def test_ssm_decode_matches_chunked_forward():
    """Mamba2 recurrent decode == chunked SSD on the same sequence."""
    cfg = get_config("mamba2-370m").reduced(num_layers=2, vocab_size=64,
                                            dtype="float32")
    lm = LM(cfg)
    rng = jax.random.PRNGKey(2)
    params = lm.init(rng)
    S = cfg.ssm_chunk * 2
    tokens = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)

    from repro.models.layers import embed

    h = embed(params["embed"], tokens, jnp.float32)
    h, _ = lm._body_ssm(params, h)
    full_logits = lm._logits(params, h)

    cache = lm.decode_init(1, max_seq=S, dtype=jnp.float32)
    step = jax.jit(lm.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t], jnp.asarray(t))
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=5e-2, atol=5e-2)
