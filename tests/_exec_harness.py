"""Shared helpers for executing PCCL collectives on a host-device mesh and
comparing against pure-numpy references.

Used by the mesh conformance suite (`test_exec_conformance.py`) and the
hypothesis property suite (`test_exec_property.py`). Everything jax-touching
is imported lazily so that merely collecting the test modules never
initializes a backend (the ``mesh`` marker's skip logic decides that).

Input/output conventions (leading axis = mesh device, ``n`` devices,
group of ``g`` members; ``S`` = payload shape):

====================  =====================  ==========================
kind                  stacked input          stacked output
====================  =====================  ==========================
all_gather            ``[n, *S]``            ``[n, g, *S]``
reduce_scatter        ``[n, g, *S]``         ``[n, *S]``
all_reduce            ``[n, D]`` (g | D)     ``[n, D]``
all_to_all            ``[n, g, *S]``         ``[n, g, *S]``
====================  =====================  ==========================

Non-participating devices must come back as exact zeros — their buffers are
untouched by the collective even when they forwarded traffic for the group.
"""

from __future__ import annotations

import numpy as np

KINDS = ("all_gather", "reduce_scatter", "all_reduce", "all_to_all")
REDUCTION_KINDS = ("reduce_scatter", "all_reduce")


def make_input(kind: str, group, n: int, *, payload: int = 3,
               seed: int = 0, dtype=np.float32) -> np.ndarray:
    """Random stacked input of the right shape for ``kind``."""
    rng = np.random.default_rng(seed)
    g = len(group)
    if kind == "all_gather":
        shape = (n, payload)
    elif kind in ("reduce_scatter", "all_to_all"):
        shape = (n, g, payload)
    elif kind == "all_reduce":
        shape = (n, g * payload)
    else:
        raise ValueError(kind)
    return rng.standard_normal(shape).astype(dtype)


def reference(kind: str, group, x: np.ndarray) -> np.ndarray:
    """Pure-numpy reference with zeros on non-participants."""
    n = x.shape[0]
    gl = list(group)
    g = len(gl)
    if kind == "all_gather":
        out = np.zeros((n, g) + x.shape[1:], x.dtype)
        for d in gl:
            out[d] = x[gl]
    elif kind == "reduce_scatter":
        out = np.zeros((n,) + x.shape[2:], x.dtype)
        for i, d in enumerate(gl):
            out[d] = x[gl, i].sum(axis=0)
    elif kind == "all_reduce":
        out = np.zeros_like(x)
        total = x[gl].sum(axis=0)
        for d in gl:
            out[d] = total
    elif kind == "all_to_all":
        out = np.zeros((n, g) + x.shape[2:], x.dtype)
        for i, d in enumerate(gl):
            out[d] = x[gl, i]
    else:
        raise ValueError(kind)
    return out


def run_on_mesh(kind: str, topo, spec, x: np.ndarray, *, n: int = 8,
                program=None, device_of_npu=None) -> np.ndarray:
    """Execute one pccl collective under jit+shard_map on an ``n``-device
    1-D mesh and return the stacked per-device outputs as numpy."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.comms import primitives
    from repro.jaxcompat import make_mesh, shard_map

    fn = getattr(primitives, f"pccl_{kind}")
    mesh = make_mesh((n,), ("x",))

    def f(xl):
        out = fn(xl[0], "x", topo, spec, program=program,
                 device_of_npu=device_of_npu)
        return out[None]

    run = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    return np.asarray(run(x))


def assert_conformant(kind: str, got: np.ndarray, want: np.ndarray,
                      label: str = "") -> None:
    """Bit-identical for data movement; fixed-order tolerance for
    reductions (the schedule fixes the accumulation order, but it differs
    from the reference's sum order)."""
    if kind in REDUCTION_KINDS:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=label)
    else:
        np.testing.assert_array_equal(got, want, err_msg=label)


def check_collective(kind: str, topo, spec, group, *, n: int = 8,
                     seed: int = 0, program=None) -> None:
    """End-to-end: build input, execute on the mesh, compare member outputs
    against the numpy reference and non-member outputs against zeros."""
    x = make_input(kind, group, n, seed=seed)
    got = run_on_mesh(kind, topo, spec, x, n=n, program=program)
    want = reference(kind, group, x)
    members = set(group)
    for d in range(n):
        if d in members:
            assert_conformant(kind, got[d], want[d],
                              f"{kind} member device {d}")
        else:
            np.testing.assert_array_equal(
                got[d], np.zeros_like(got[d]),
                err_msg=f"{kind}: non-participant device {d} buffer touched")
