"""Tests for the alpha-beta queuing simulator and baseline collectives,
anchored on analytically known completion times."""

import pytest

from repro.core import (
    Flow,
    collective_bandwidth,
    direct_all_gather,
    direct_all_to_all,
    replay_algorithm,
    ring_all_gather,
    shortest_path_links,
    simulate_flows,
    synthesize_all_gather,
    synthesize_all_to_all,
)
from repro.topology import line, mesh2d, ring, torus2d
from repro.topology.topology import Topology


class TestShortestPath:
    def test_line(self):
        topo = line(4)
        route = shortest_path_links(topo, 0, 3)
        assert len(route) == 3
        assert topo.links[route[0]].src == 0
        assert topo.links[route[-1]].dst == 3

    def test_weighted_prefers_fast_detour(self):
        topo = Topology("weighted")
        topo.add_npus(3)
        topo.add_link(0, 2, alpha=0.0, beta=10.0)  # slow direct
        topo.add_link(0, 1, alpha=0.0, beta=1.0)
        topo.add_link(1, 2, alpha=0.0, beta=1.0)
        route = shortest_path_links(topo, 0, 2, chunk_bytes=1.0)
        assert len(route) == 2  # detour via 1 wins (2 < 10)


class TestSimulator:
    def test_single_flow_timing(self):
        topo = line(3)
        route = shortest_path_links(topo, 0, 2)
        res = simulate_flows(topo, [Flow(0, 1.0, route)])
        assert res.makespan == pytest.approx(2.0)  # two unit hops

    def test_fifo_contention(self):
        # two chunks over the same single link serialize
        topo = Topology("one_link")
        topo.add_npus(2)
        topo.add_link(0, 1, alpha=0.0, beta=1.0)
        res = simulate_flows(topo, [Flow(0, 1.0, [0]), Flow(1, 1.0, [0])])
        assert res.makespan == pytest.approx(2.0)
        assert sorted(res.completion.values()) == [pytest.approx(1.0), pytest.approx(2.0)]

    def test_release_time(self):
        topo = line(2)
        res = simulate_flows(topo, [Flow(0, 1.0, [0], release=5.0)])
        assert res.completion[0] == pytest.approx(6.0)

    def test_store_and_forward(self):
        # same chunk cannot be on two hops at once
        topo = line(3)
        route = shortest_path_links(topo, 0, 2)
        res = simulate_flows(topo, [Flow(0, 2.0, route)])
        assert res.makespan == pytest.approx(4.0)

    def test_busy_timeline_shape(self):
        topo = ring(4)
        alg = synthesize_all_gather(topo, [0, 1, 2, 3])
        res = replay_algorithm(alg)
        timeline = res.busy_timeline(topo.num_links, bins=10)
        assert len(timeline) == 10
        assert all(0.0 <= x <= 1.0 + 1e-9 for x in timeline)
        # unidirectional ring AG keeps every link busy the whole time
        assert timeline[0] == pytest.approx(1.0)

    def test_busy_timeline_start_at_makespan(self):
        # regression: a transfer starting exactly at the makespan used to
        # index bin `bins` (IndexError); both bin indices must clamp.
        from repro.core import SimResult, Transfer

        res = SimResult(
            makespan=4.0,
            completion={0: 4.0, 1: 4.0},
            link_busy={0: 4.0},
            transfers=[
                Transfer(0, 0, 0, 1, 0.0, 4.0),
                Transfer(1, 1, 1, 2, 4.0, 4.0),  # starts at the makespan
            ],
        )
        timeline = res.busy_timeline(num_links=2, bins=8)
        assert len(timeline) == 8
        assert all(0.0 <= x <= 1.0 + 1e-9 for x in timeline)


class TestBaselines:
    def test_direct_a2a_mesh(self):
        topo = mesh2d(3, 3)
        res = direct_all_to_all(topo, list(range(9)))
        assert len(res.completion) == 72
        assert res.makespan > 0

    def test_pccl_beats_direct_on_mesh(self):
        # the paper's central claim (Fig 14/16)
        topo = mesh2d(4, 4)
        pccl = synthesize_all_to_all(topo, list(range(16)))
        direct = direct_all_to_all(topo, list(range(16)))
        assert pccl.makespan < direct.makespan

    def test_pccl_process_group_speedup(self):
        # process group = one mesh row; PCCL borrows other rows' links
        topo = mesh2d(4, 4)
        group = [0, 1, 2, 3]
        pccl = synthesize_all_to_all(topo, group)
        pccl.validate()
        direct = direct_all_to_all(topo, group)
        assert pccl.makespan <= direct.makespan

    def test_ring_ag_on_ring_matches_pccl(self):
        # on the actual ring topology the logical ring baseline is optimal,
        # PCCL must match it (both n-1 steps)
        topo = ring(6)
        base = ring_all_gather(topo, list(range(6)))
        pccl = synthesize_all_gather(topo, list(range(6)))
        assert base.makespan == pytest.approx(pccl.makespan) == 5.0

    def test_ring_ag_unaware_on_torus_loses(self):
        # paper Fig 3b: topology-unaware ring underutilizes richer networks
        topo = torus2d(3, 3)
        base = ring_all_gather(topo, list(range(9)))
        pccl = synthesize_all_gather(topo, list(range(9)))
        assert pccl.makespan < base.makespan

    def test_direct_ag(self):
        topo = mesh2d(3, 3)
        res = direct_all_gather(topo, list(range(9)))
        assert res.makespan > 0

    def test_bandwidth_metric(self):
        topo = ring(4)
        alg = synthesize_all_gather(topo, [0, 1, 2, 3])
        res = replay_algorithm(alg)
        bw = collective_bandwidth(res, payload_bytes=4.0)
        assert bw == pytest.approx(4.0 / 3.0)
