"""Per-kernel allclose sweeps: Pallas kernels (interpret mode on CPU) vs the
pure-jnp oracles in repro/kernels/ref.py, across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref

jax.config.update("jax_enable_x64", False)


def _qkv(rng, B, S, H, KV, hd, dtype):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,S,H,KV,hd", [
        (1, 128, 4, 4, 32),   # MHA
        (2, 128, 4, 2, 32),   # GQA 2:1
        (1, 256, 8, 1, 16),   # MQA
        (1, 192, 2, 2, 64),   # non-pow2 seq (block fallback)
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shapes_causal(self, B, S, H, KV, hd, causal):
        q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd, jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                  block_kv=64)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 96])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 4, 4, 32, jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=64, block_kv=64)
        want = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 128, 2, 2, 32, jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, softcap=20.0,
                                  block_q=64, block_kv=64)
        want = flash_attention_ref(q, k, v, causal=True, softcap=20.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 4, 2, 32, jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True, block_q=64,
                                  block_kv=64)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)

    def test_block_shape_independence(self):
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 256, 2, 2, 32, jnp.float32)
        a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=128)
        b = ops.flash_attention(q, k, v, causal=True, block_q=128, block_kv=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


class TestSSDScanKernel:
    def _inputs(self, rng, B, S, H, P, N, dtype=jnp.float32):
        ks = jax.random.split(rng, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
        dt = jax.nn.softplus(
            jax.random.normal(ks[1], (B, S, H), jnp.float32))
        A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[0], (B, S, N), jnp.float32) * 0.5
        return xh, dt, A, Bm, Cm

    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 4, 32, 16, 32),
        (1, 96, 2, 16, 8, 32),   # chunk fallback (96 % 32 == 0)
        (1, 64, 1, 64, 32, 64),  # single chunk
    ])
    def test_matches_recurrence(self, B, S, H, P, N, chunk):
        xh, dt, A, Bm, Cm = self._inputs(jax.random.PRNGKey(0), B, S, H, P, N)
        got = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk)
        want = ssd_scan_ref(xh, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_chunk_independence(self):
        xh, dt, A, Bm, Cm = self._inputs(jax.random.PRNGKey(1), 1, 128, 2, 16, 8)
        a = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=32)
        b = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_model_chunked_matches_kernel(self):
        """The model's jnp chunked SSD (_ssd_chunked) and the Pallas kernel
        agree — they implement the same algorithm with different tiling."""
        from repro.models.ssm import _ssd_chunked

        xh, dt, A, Bm, Cm = self._inputs(jax.random.PRNGKey(2), 1, 128, 2, 16, 8)
        a = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=32)
        b = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
