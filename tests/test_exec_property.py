"""Property test: random (fabric, process group, kind) triples synthesize,
execute on the 8-device host mesh, and match the pure-numpy reference.

Hypothesis shrinks a failure to a minimal (topology, group, kind) triple —
smallest group over the simplest fabric — which is exactly the reproduction
one wants when a schedule mis-executes. Inputs are seeded from the triple,
so every example (and every shrink step) is deterministic. When hypothesis
is absent, a deterministic seeded sweep over the same space still runs.
"""

import numpy as np
import pytest

from _exec_harness import KINDS, check_collective

pytestmark = pytest.mark.mesh

N = 8

FABRICS = ["ring8", "line8", "torus24", "grid23", "mp222"]

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _build(name: str):
    from repro.topology import line, ring, torus2d
    from repro.topology.generators import grid_hypercube, multi_pod

    return {
        "ring8": lambda: ring(8, bidirectional=True),
        "line8": lambda: line(8),
        "torus24": lambda: torus2d(2, 4),
        "grid23": lambda: grid_hypercube(2, 3),
        "mp222": lambda: multi_pod(2, 2, 2, unit_links=True,
                                   dci_ports_per_pod=2),
    }[name]()


_topos: dict[str, object] = {}


def _check_triple(fabric: str, kind: str, group: tuple[int, ...]) -> None:
    from repro.core import CollectiveRequest

    topo = _topos.setdefault(fabric, _build(fabric))
    req = CollectiveRequest(kind, group=group)
    seed = int(np.uint32(hash((fabric, kind, group)) & 0xFFFFFFFF))
    check_collective(kind, topo, req, group, n=N, seed=seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(
        fabric=st.sampled_from(FABRICS),
        kind=st.sampled_from(KINDS),
        group=st.lists(st.integers(0, N - 1), min_size=2, max_size=N,
                       unique=True).map(lambda g: tuple(sorted(g))),
    )
    def test_random_triple_executes_conformantly(fabric, kind, group):
        _check_triple(fabric, kind, group)


@pytest.mark.parametrize("case", range(8))
def test_seeded_triple_sweep(case):
    """Deterministic fallback sweep over the same (fabric, group, kind)
    space — runs with or without hypothesis installed."""
    rng = np.random.default_rng(1000 + case)
    fabric = FABRICS[int(rng.integers(len(FABRICS)))]
    kind = KINDS[int(rng.integers(len(KINDS)))]
    size = int(rng.integers(2, N + 1))
    group = tuple(sorted(rng.choice(N, size=size, replace=False).tolist()))
    _check_triple(fabric, kind, group)
