"""Randomized differential tests: the event-frontier ``bfs_int`` equals
``bfs_int_ref`` on random topologies — optionally with (serialized /
buffer-limited) switches — and random pre-committed TEN state.

Cases are generated from a ``random.Random`` seed, so the same generator
serves two harnesses: hypothesis drives the seed space (with its database
and shrinking) when installed, and a fixed seed sweep runs otherwise — the
differential gate never silently skips. Deterministic topology-class
coverage lives in test_pathfinding_diff.py.
"""

import random

import pytest

from repro.core.conditions import Condition
from repro.core.pathfinding import bfs_int, bfs_int_ref
from repro.core.ten import TEN
from repro.topology.topology import NodeType, Topology

from tests.test_pathfinding_diff import assert_same

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _gen_case(rng: random.Random, switches: bool, max_npus: int = 7):
    n = rng.randint(2, max_npus)
    topo = Topology("diff")
    topo.add_npus(n)
    perm = list(range(n))
    rng.shuffle(perm)
    for i in range(n):  # ring backbone: strong connectivity
        topo.add_link(perm[i], perm[(i + 1) % n])
    for _ in range(rng.randint(0, 2 * n)):
        u = rng.randrange(n)
        v = rng.randrange(n)
        if u != v and not any(l.dst == v for l in topo.out_links(u)):
            topo.add_link(u, v)
    if switches:
        sw = topo.add_node(
            NodeType.SWITCH,
            buffer_limit=rng.choice([None, 1, 2]),
            multicast=rng.random() < 0.5,
        )
        members = rng.sample(range(n), rng.randint(2, n))
        for m in members:
            topo.add_bidir_link(m, sw)

    # random pre-committed integer occupancy (as if prior conditions ran)
    ten = TEN(topo)
    seen = set()
    for _ in range(rng.randint(0, 4 * topo.num_links)):
        link = rng.randrange(topo.num_links)
        t = rng.randint(0, 12)
        if (link, t) not in seen:
            seen.add((link, t))
            ten.commit_int(link, t)
    # random switch residency intervals (buffer pressure)
    for s in topo.switches:
        for _ in range(rng.randint(0, 3)):
            a = rng.randint(0, 8)
            ten.commit_residency(s, float(a), float(a + rng.randint(1, 6)))

    npus = topo.npus
    src = rng.choice(npus)
    dests = rng.sample(npus, rng.randint(1, len(npus)))
    release = rng.choice([0, 0, 0, 2, 5])
    cond = Condition(0, src, frozenset(dests), release=float(release))
    return topo, ten, cond


def _clone_ten(topo, ten):
    clone = TEN(topo)
    for link, mask in enumerate(ten._masks):
        t = 0
        m = mask
        while m:
            if m & 1:
                clone.commit_int(link, t)
            m >>= 1
            t += 1
    for s, intervals in ten._residency.items():
        for a, b in intervals:
            clone.commit_residency(s, a, b)
    return clone


def check_seed(seed: int, switches: bool) -> None:
    topo, ten, cond = _gen_case(random.Random(seed), switches)
    ten2 = _clone_ten(topo, ten)
    try:
        ra = bfs_int_ref(ten, cond)
    except AssertionError as e:
        with pytest.raises(AssertionError) as eb:
            bfs_int(ten2, cond)
        assert str(e) == str(eb.value)
        return
    rb = bfs_int(ten2, cond)
    assert_same(ra, rb, ctx=f"seed={seed} switches={switches}")


if HAVE_HYPOTHESIS:

    @settings(max_examples=150, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_homogeneous_differential(seed):
        check_seed(seed, switches=False)

    @settings(max_examples=150, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_switched_differential(seed):
        check_seed(seed, switches=True)

else:  # seed-sweep fallback: same generator, fixed seeds

    @pytest.mark.parametrize("seed", range(0, 150))
    def test_random_homogeneous_differential(seed):
        check_seed(seed, switches=False)

    @pytest.mark.parametrize("seed", range(1000, 1150))
    def test_random_switched_differential(seed):
        check_seed(seed, switches=True)
