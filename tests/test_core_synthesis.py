"""Unit tests for the PCCL core synthesizer (paper §4, Algorithms 1-3)."""

import pytest

from repro.core import (
    ChunkIds,
    Condition,
    all_gather,
    all_to_all,
    all_to_allv,
    broadcast,
    direct_all_to_all,
    gather,
    multicast,
    point_to_point,
    scatter,
    synthesize,
    synthesize_all_gather,
    synthesize_all_reduce,
    synthesize_all_to_all,
    synthesize_joint,
    synthesize_reduce,
    synthesize_reduce_scatter,
    order_conditions,
)
from repro.core.pathfinding import bfs_cont, bfs_int
from repro.core.ten import TEN
from repro.topology import (
    hypercube,
    line,
    mesh2d,
    ring,
    star_switch,
    torus2d,
    two_level_switch,
)
from repro.topology.topology import Topology


class TestTENOps:
    """Algorithm 1: NextDevices / Available / NextAvailableTime analogues."""

    def test_earliest_free_empty(self):
        ten = TEN(ring(4))
        assert ten.earliest_free(0, 0.0, 1.0) == 0.0
        assert ten.earliest_free(0, 2.5, 1.0) == 2.5

    def test_earliest_free_after_commit(self):
        ten = TEN(ring(4))
        ten.commit(0, 0.0, 1.0)
        assert ten.earliest_free(0, 0.0, 1.0) == pytest.approx(1.0)
        # gap fitting: commit [2,3) -> a 1.0 transfer fits at [1,2)
        ten.commit(0, 2.0, 3.0)
        assert ten.earliest_free(0, 0.0, 1.0) == pytest.approx(1.0)
        assert ten.earliest_free(0, 0.0, 1.5) == pytest.approx(3.0)

    def test_commit_overlap_raises(self):
        ten = TEN(ring(4))
        ten.commit(0, 0.0, 2.0)
        with pytest.raises(AssertionError):
            ten.commit(0, 1.0, 1.5)

    def test_int_mode(self):
        ten = TEN(ring(4))
        assert ten.free_int(0, 0)
        ten.commit_int(0, 0)
        assert not ten.free_int(0, 0)
        assert ten.earliest_free_int(0, 0) == 1
        with pytest.raises(AssertionError):
            ten.commit_int(0, 0)


class TestBFS:
    """Algorithm 2 over unit-time TENs."""

    def test_single_hop(self):
        topo = ring(4)
        res = bfs_int(TEN(topo), Condition(0, 0, frozenset([1])))
        assert len(res.transfers) == 1
        t = res.transfers[0]
        assert (t.src, t.dst, t.start, t.end) == (0, 1, 0.0, 1.0)

    def test_multi_hop_unidirectional(self):
        topo = ring(4)  # 0->1->2->3->0
        res = bfs_int(TEN(topo), Condition(0, 0, frozenset([3])))
        assert res.reached[3] == 3.0
        assert len(res.transfers) == 3

    def test_multicast_tree_pruning(self):
        # paper Fig 6: BFS may visit extra nodes; pruning keeps only useful paths
        topo = mesh2d(3, 3)
        res = bfs_int(TEN(topo), Condition(0, 4, frozenset([0, 8])))
        # every retained transfer lies on a path to 0 or 8
        nodes = {t.dst for t in res.transfers} | {4}
        assert 0 in nodes and 8 in nodes
        # retained tree has exactly |path edges| <= visited edges
        assert len(res.transfers) <= 4

    def test_busy_links_route_around(self):
        topo = line(3)  # 0<->1<->2
        ten = TEN(topo)
        # occupy link 0->1 at t=0 (link id 0)
        ten.commit_int(0, 0)
        res = bfs_int(ten, Condition(0, 0, frozenset([2])))
        # must wait: 0->1 at t=1, 1->2 at t=2 => arrival 3
        assert res.reached[2] == 3.0

    def test_unreachable_raises(self):
        topo = Topology("disc")
        topo.add_npus(2)  # no links
        with pytest.raises(AssertionError):
            bfs_int(TEN(topo), Condition(0, 0, frozenset([1])))

    def test_continuous_matches_int_on_homogeneous(self):
        topo = mesh2d(3, 3)
        cond = Condition(0, 0, frozenset(range(9)))
        res_i = bfs_int(TEN(topo), cond)
        res_c = bfs_cont(TEN(topo), cond)
        assert res_i.reached == res_c.reached


class TestConditionBuilders:
    def test_counts(self):
        g = [0, 1, 2, 3]
        assert len(all_gather(g)) == 4
        assert len(all_to_all(g)) == 12
        assert len(scatter(g, 0)) == 3
        assert len(gather(g, 0)) == 3
        assert len(broadcast(g, 2)) == 1
        assert len(point_to_point(0, 3)) == 1
        assert len(multicast(0, [1, 2])) == 1

    def test_all_to_allv_counts(self):
        g = [0, 1]
        conds = all_to_allv(g, [[0, 3], [1, 0]])
        assert len(conds) == 4
        froms = sorted((c.src, next(iter(c.dests))) for c in conds)
        assert froms == [(0, 1), (0, 1), (0, 1), (1, 0)]

    def test_unique_chunk_ids_joint(self):
        ids = ChunkIds()
        a = all_gather([0, 1], ids=ids)
        b = all_to_all([2, 3], ids=ids)
        chunks = [c.chunk for c in a + b]
        assert len(set(chunks)) == len(chunks)

    def test_chunkids_split_shares_counter(self):
        parent = ChunkIds()
        c1, c2 = parent.split(2)
        ids = [c1.next(), c2.next(), parent.next(), c1.next()]
        assert ids == sorted(set(ids)), "split allocators must never collide"

    def test_chunkids_split_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            ChunkIds().split(0)

    def test_joint_with_split_allocators(self):
        topo = mesh2d(3, 3)
        v_ids, ag_ids = ChunkIds().split(2)
        v = all_to_all([0, 1, 2], ids=v_ids)
        ag = all_gather([6, 7, 8], ids=ag_ids)
        alg = synthesize_joint(topo, [("a2a", v), ("ag", ag)])
        alg.validate()

    def test_ordering_longest_first(self):
        topo = ring(8)
        conds = all_to_all(list(range(8)))
        ordered = order_conditions(topo, conds)
        dists = [
            (next(iter(c.dests)) - c.src) % 8 for c in ordered
        ]  # unidirectional hop distance
        assert dists == sorted(dists, reverse=True)


class TestSynthesis:
    def test_ring_all_gather_optimal(self):
        # paper Fig 3a: unidirectional ring AG in exactly n-1 steps
        for n in (3, 4, 7):
            alg = synthesize_all_gather(ring(n), list(range(n)))
            alg.validate()
            assert alg.makespan == n - 1

    def test_all_gather_every_topology(self):
        for topo in (line(5), mesh2d(3, 4), torus2d(3, 3), hypercube(3)):
            group = topo.npus
            alg = synthesize_all_gather(topo, group)
            alg.validate()

    def test_all_to_all_mesh(self):
        topo = mesh2d(4, 4)
        alg = synthesize_all_to_all(topo, list(range(16)))
        alg.validate()
        # beats Direct baseline on the same topology (paper Fig 14)
        direct = direct_all_to_all(topo, list(range(16)))
        assert alg.makespan < direct.makespan

    def test_scatter_gather_broadcast(self):
        topo = mesh2d(3, 3)
        for conds in (
            scatter(list(range(9)), 4),
            gather(list(range(9)), 0),
            broadcast(list(range(9)), 8),
        ):
            alg = synthesize(topo, conds)
            alg.validate()

    def test_process_group_uses_outside_links(self):
        # AG among 3 corner NPUs of a 3x3 mesh must route via others
        topo = mesh2d(3, 3)
        alg = synthesize_all_gather(topo, [0, 2, 8])
        alg.validate()
        touched = {t.src for t in alg.transfers} | {t.dst for t in alg.transfers}
        assert touched - {0, 2, 8}, "expected out-of-group forwarding"

    def test_release_times_respected(self):
        topo = ring(4)
        conds = [Condition(0, 0, frozenset([1]), release=5.0)]
        alg = synthesize(topo, conds)
        alg.validate()
        assert alg.transfers[0].start >= 5.0

    def test_joint_process_groups(self):
        # paper Fig 15: All-to-Allv (pg0) + All-Gather (pg1) on a 3x3 mesh
        topo = mesh2d(3, 3)
        ids = ChunkIds()
        v = all_to_allv([0, 1, 2], [[0, 2, 2], [1, 0, 1], [1, 1, 0]], ids=ids)
        ag = all_gather([6, 7, 8], ids=ids)
        alg = synthesize_joint(topo, [("pg0", v), ("pg1", ag)])
        alg.validate()

    def test_joint_duplicate_chunks_rejected(self):
        topo = mesh2d(2, 2)
        a = all_gather([0, 1])  # fresh ids starting at 0
        b = all_gather([2, 3])  # also starting at 0 -> collision
        with pytest.raises(ValueError):
            synthesize_joint(topo, [("a", a), ("b", b)])


class TestReductions:
    def test_reduce(self):
        topo = mesh2d(3, 3)
        alg = synthesize_reduce(topo, list(range(9)), root=4)
        alg.validate()

    def test_reduce_scatter(self):
        for topo in (ring(4, bidirectional=True), mesh2d(3, 3), hypercube(3)):
            alg = synthesize_reduce_scatter(topo, topo.npus)
            alg.validate()

    def test_all_reduce(self):
        topo = ring(8, bidirectional=True)
        alg = synthesize_all_reduce(topo, list(range(8)))
        alg.validate()

    def test_all_reduce_pipelined_not_slower(self):
        topo = mesh2d(4, 4)
        base = synthesize_all_reduce(topo, list(range(16)), pipelined=False)
        pipe = synthesize_all_reduce(topo, list(range(16)), pipelined=True)
        base.validate()
        pipe.validate()
        assert pipe.makespan <= base.makespan

    def test_reduce_process_group(self):
        topo = mesh2d(3, 3)
        alg = synthesize_reduce_scatter(topo, [0, 4, 8])
        alg.validate()


class TestSwitches:
    def test_star_switch_all_gather(self):
        topo = star_switch(4)
        alg = synthesize_all_gather(topo, [0, 1, 2, 3])
        alg.validate()

    def test_star_switch_no_multicast_serializes(self):
        topo = star_switch(4, multicast=False)
        alg = synthesize_all_gather(topo, [0, 1, 2, 3])
        alg.validate()
        mc = star_switch(4, multicast=True)
        alg_mc = synthesize_all_gather(mc, [0, 1, 2, 3])
        alg_mc.validate()
        assert alg.makespan >= alg_mc.makespan

    def test_buffer_limit_respected(self):
        topo = star_switch(6, buffer_limit=1)
        alg = synthesize_all_to_all(topo, list(range(6)))
        alg.validate()  # validator enforces the limit

    def test_two_level_switch_hetero(self):
        topo = two_level_switch(2, npus_per_node=4)
        alg = synthesize_all_to_all(topo, list(range(8)), bytes=512.0)
        alg.validate()
        # intra-node chunks finish before cross-node ones on average
        intra = [t for t in alg.transfers if t.start == 0.0]
        assert intra


class TestHeterogeneous:
    def test_alpha_beta_timing(self):
        # paper Fig 9: two links of different alpha/beta
        topo = Topology("hetero2")
        topo.add_npus(3)
        topo.add_link(0, 1, alpha=2.0, beta=0.5)
        topo.add_link(1, 2, alpha=1.0, beta=2.0)
        alg = synthesize(topo, [Condition(0, 0, frozenset([2]), bytes=4.0)])
        alg.validate()
        # 0->1: 2 + 4*0.5 = 4; 1->2: 1 + 4*2 = 9 => makespan 13
        assert alg.makespan == pytest.approx(13.0)

    def test_hetero_congestion_interval(self):
        # paper Fig 10: second chunk on the same link starts after the first's interval
        topo = Topology("one_link")
        topo.add_npus(2)
        topo.add_link(0, 1, alpha=1.0, beta=1.0)
        conds = [
            Condition(0, 0, frozenset([1]), bytes=2.0),
            Condition(1, 0, frozenset([1]), bytes=2.0),
        ]
        alg = synthesize(topo, conds)
        alg.validate()
        spans = sorted((t.start, t.end) for t in alg.transfers)
        assert spans[0][1] <= spans[1][0] + 1e-9
        assert alg.makespan == pytest.approx(6.0)

    def test_fast_path_equals_slow_path(self):
        topo = mesh2d(3, 3)
        conds = all_to_all(list(range(9)))
        fast = synthesize(topo, conds, mode="int")
        slow = synthesize(topo, conds, mode="cont")
        fast.validate()
        slow.validate()
        assert fast.makespan == pytest.approx(slow.makespan)
