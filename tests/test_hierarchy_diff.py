"""Differential harness for every synthesis routing path.

Four routes now produce schedules for the same named collectives — flat
per-chunk search, hierarchical phase composition (pipelined and
sequential), time-reversed reduction synthesis, and pipelined flat
All-Reduce — across every partitioned generator family. This suite pins
their equivalence systematically instead of by spot checks:

* every route's plan fulfils the *identical* per-chunk final conditions
  (same chunk -> same source/contributors -> same destination set);
* every plan passes validation under both the vectorized ``mode="bulk"``
  path and the reference ``mode="oracle"`` replay;
* on multi-level fabrics, the 2-level view (top partition only) and the
  3-level view (full partition tree) of the *same physical fabric* agree
  with each other and with flat synthesis;
* the registry never serves a plan cached for one partition-tree view to
  a request made under another (the partition-fingerprint regression).
"""

import pytest

from repro.core import (AlgorithmRegistry, CollectiveAlgorithm,
                        CollectiveRequest, SynthesisEngine, replay_algorithm)
from repro.core.conditions import Condition, ReduceCondition
from repro.core.hierarchy import HierarchicalSynthesizer, HierarchyError
from repro.topology import multi_pod, three_level, two_level_switch
from repro.topology.generators import grid_hypercube

KINDS = ("all_gather", "all_to_all", "reduce_scatter", "all_reduce")

# every partitioned generator family, small enough for oracle validation
FABRICS = {
    "multi_pod": lambda: multi_pod(2, 2, 4, unit_links=True,
                                   dci_ports_per_pod=4),
    "two_level_switch": lambda: two_level_switch(3, npus_per_node=4),
    "grid_hypercube": lambda: grid_hypercube(4, 2),
    "three_level": lambda: three_level(2, 2, 3, unit_links=True),
}


def _delivery(alg):
    """Per-chunk final conditions: (chunk, src-or-srcs, dests), sorted —
    the contract every routing path must agree on."""
    out = []
    for c in alg.conditions:
        if isinstance(c, ReduceCondition):
            out.append((c.chunk, tuple(sorted(c.srcs)),
                        tuple(sorted(c.dests))))
        else:
            out.append((c.chunk, c.src, tuple(sorted(c.dests))))
    return sorted(out)


def _routes(eng, kind, group):
    """Every routing path that can produce this collective on this engine's
    fabric: name -> algorithm. 'hier' may legitimately be a flat fallback
    (e.g. reductions on shared-device fabrics) — the equivalence claims
    hold either way."""
    routes = {
        "flat": eng.collective(
            CollectiveRequest(kind, group=tuple(group), hierarchy="never")),
        "hier": getattr(eng, kind)(group),  # auto: pipelined where safe
    }
    if kind == "all_reduce":
        routes["flat_pipelined"] = eng.collective(CollectiveRequest(
            "all_reduce", group=tuple(group), pipelined=True,
            hierarchy="never"))
    # the sequential (registry-shareable) hierarchical regime
    h = HierarchicalSynthesizer(SynthesisEngine(eng.topology,
                                                registry=eng.registry))
    try:
        routes["hier_sequential"] = getattr(h, kind)(group, pipeline=False)
    except HierarchyError:
        pass  # fabric family cannot take this path (e.g. in-forest guard)
    return routes


class TestRoutingPathEquivalence:
    """Flat vs hierarchical (pipelined and sequential) vs time-reversed vs
    pipelined plans: identical per-chunk final conditions, and every plan
    validates under both the bulk path and the oracle."""

    @pytest.mark.parametrize("fabric_name", sorted(FABRICS))
    @pytest.mark.parametrize("kind", KINDS)
    def test_differential(self, fabric_name, kind):
        topo = FABRICS[fabric_name]()
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        group = topo.npus
        routes = _routes(eng, kind, group)
        assert "hier" in routes and "flat" in routes
        ref = _delivery(routes["flat"])
        ref_completion = set(replay_algorithm(routes["flat"]).completion)
        for name, alg in routes.items():
            assert _delivery(alg) == ref, (
                f"{fabric_name}/{kind}: route {name} fulfils different "
                f"final conditions than flat synthesis")
            alg.validate(mode="oracle")
            alg.validate(mode="bulk")
            # replay agrees: the same chunk set completes on every route
            assert set(replay_algorithm(alg).completion) == ref_completion

    @pytest.mark.parametrize("fabric_name", ["multi_pod", "three_level"])
    @pytest.mark.parametrize("kind", KINDS)
    def test_hier_route_actually_taken(self, fabric_name, kind):
        """On switch-boundary-free fabrics the auto route must really be
        hierarchical — a silent flat fallback would turn the differential
        suite into flat-vs-flat."""
        topo = FABRICS[fabric_name]()
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        alg = getattr(eng, kind)(topo.npus)
        assert alg.name.startswith("pccl_hier")


class TestTwoVsThreeLevel:
    """The same physical fabric viewed with a depth-1 partition (pods only)
    and with the full depth-2 tree (pods of racks) must fulfil identical
    final conditions — recursion changes the decomposition, never the
    contract."""

    def _views(self):
        deep = three_level(2, 2, 3, unit_links=True)
        shallow = three_level(2, 2, 3, unit_links=True)
        shallow.set_partition([p[0] for p in deep.partition_paths])
        assert shallow.partition_depth == 1 and deep.partition_depth == 2
        return shallow, deep

    @pytest.mark.parametrize("kind", KINDS)
    def test_depth_views_agree(self, kind):
        shallow, deep = self._views()
        a2 = getattr(SynthesisEngine(shallow,
                                     registry=AlgorithmRegistry()),
                     kind)(shallow.npus)
        a3 = getattr(SynthesisEngine(deep, registry=AlgorithmRegistry()),
                     kind)(deep.npus)
        assert _delivery(a2) == _delivery(a3)
        for alg in (a2, a3):
            alg.validate(mode="oracle")
            alg.validate(mode="bulk")

    def test_three_level_view_recurses(self):
        _, deep = self._views()
        alg = SynthesisEngine(deep).all_gather(deep.npus)
        nested = [n for n, _, _ in alg.phase_spans if "/" in n]
        assert any(n.startswith("intra:") and "/inter" in n for n in nested), (
            "3-level view must decompose pod intra phases into nested "
            "rack/boundary phases")
        shallow_alg = SynthesisEngine(self._views()[0]).all_gather(
            deep.npus)
        assert not any("/" in n for n, _, _ in shallow_alg.phase_spans)


class TestPartitionTreeRegistryKeys:
    """Registry route keys must encode the full partition-tree fingerprint:
    the topology *structure* hash is partition-blind, so a cached 2-level
    plan would otherwise be served verbatim for a 3-level view of the same
    fabric (regression test for the route-param key fix)."""

    def test_fingerprint_differs_by_tree(self):
        deep = three_level(2, 2, 3, unit_links=True)
        shallow = three_level(2, 2, 3, unit_links=True)
        shallow.set_partition([p[0] for p in deep.partition_paths])
        from repro.core import topology_fingerprint

        assert topology_fingerprint(shallow) == topology_fingerprint(deep)
        assert (shallow.partition_fingerprint()
                != deep.partition_fingerprint())

    @pytest.mark.parametrize("kind", KINDS)
    def test_cached_two_level_plan_not_served_for_three_level(self, kind):
        deep = three_level(2, 2, 3, unit_links=True)
        shallow = three_level(2, 2, 3, unit_links=True)
        shallow.set_partition([p[0] for p in deep.partition_paths])
        reg = AlgorithmRegistry()
        getattr(SynthesisEngine(shallow, registry=reg), kind)(shallow.npus)
        misses = reg.stats.misses
        alg = getattr(SynthesisEngine(deep, registry=reg), kind)(deep.npus)
        assert reg.stats.misses > misses, (
            f"{kind}: the 3-level view was served the cached 2-level plan")
        alg.validate()

    def test_leaf_phase_key_carries_sub_partition(self):
        """The per-phase keys distinguish partitioned from unpartitioned
        views of the same sub-fabric too: a pod synthesized flat (as a
        2-level leaf) must not satisfy the recursive (3-level) request for
        the same structural pod."""
        deep = three_level(2, 2, 3, unit_links=True)
        pod = deep.pod_subtopology(0).topology
        flat_pod = three_level(2, 2, 3, unit_links=True).pod_subtopology(
            0).topology
        flat_pod.set_partition([-1] * flat_pod.num_nodes)
        from repro.core import topology_fingerprint

        assert topology_fingerprint(pod) == topology_fingerprint(flat_pod)
        assert (pod.partition_fingerprint()
                != flat_pod.partition_fingerprint())


class TestPlannerRoutesThreeLevel:
    def test_mesh_planner_recursive_route(self):
        from repro.launch.sharding import MeshCollectivePlanner

        topo = three_level(2, 2, 4, unit_links=True)
        pl = MeshCollectivePlanner(
            topo, {"pod": 2, "rack": 2, "model": 4},
            registry=AlgorithmRegistry())
        assert pl.hierarchy_levels() == 3
        assert pl.spans_pods("pod")
        assert not pl.spans_pods("model")
        alg = pl.algorithm("all_gather", "pod", 0)
        assert alg.name == "pccl_hier_all_gather"
        alg.validate()

    def test_spanning_generic_conditions(self):
        """spanning() is public: arbitrary condition sets decompose too."""
        topo = three_level(2, 2, 4, unit_links=True)
        eng = SynthesisEngine(topo)
        conds = [
            Condition(0, 0, frozenset([5, 9, 13])),   # multicast, 3 pods
            Condition(1, 4, frozenset([2])),          # cross-rack
            Condition(2, 8, frozenset([15, 3])),      # cross-pod pair
        ]
        alg = eng.hierarchical().spanning(conds)
        alg.validate(mode="oracle")
        assert _delivery(alg) == _delivery(
            eng.synthesize(conds, name="flat"))

    def test_spanning_honours_releases(self):
        """A condition's release must survive every phase — in particular a
        chunk whose source IS its egress gateway reaches the inter phase
        with no intra barrier before it (regression: the inter/scatter
        builders used to drop the release, scheduling boundary transfers
        before the chunk existed)."""
        topo = multi_pod(2, 2, 2, unit_links=True, dci_ports_per_pod=2)
        eng = SynthesisEngine(topo)
        gw = topo.gateways(0)[0]
        remote = topo.pod_npus(1)[1]
        conds = [Condition(0, gw, frozenset([remote]), release=5.0),
                 Condition(1, topo.pod_npus(0)[1],
                           frozenset([remote]), release=3.0)]
        alg = eng.hierarchical().spanning(conds)
        alg.validate(mode="oracle")
        assert min(t.start for t in alg.transfers if t.chunk == 0) >= 5.0
        assert min(t.start for t in alg.transfers if t.chunk == 1) >= 3.0


class TestPipelinedAllReduceJunction:
    """Barrier vs chunk-granular All-Reduce junction: the two routes fulfil
    identical per-chunk final conditions and both pass bulk + oracle
    validation; the per-chunk junction can only tighten the makespan."""

    @pytest.mark.parametrize("fabric_name",
                             ["multi_pod", "two_level_switch",
                              "three_level"])
    def test_barrier_vs_chunk_granular(self, fabric_name):
        topo = FABRICS[fabric_name]()
        eng = SynthesisEngine(topo, registry=AlgorithmRegistry())
        h = eng.hierarchical()
        try:
            barrier = h.all_reduce(topo.npus, pipeline=False)
        except HierarchyError:
            # shared-device boundaries fail the in-forest guard: the
            # engine route resolves the fallback; flat is the reference
            barrier = eng.collective(CollectiveRequest(
                "all_reduce", group=tuple(topo.npus), hierarchy="never"))
        try:
            pipe = h.all_reduce(topo.npus, pipeline=True)
        except HierarchyError:
            # switch-boundary fabrics refuse the forced pipeline; the auto
            # engine route resolves the regime itself and must agree
            pipe = eng.all_reduce(topo.npus)
        assert _delivery(pipe) == _delivery(barrier)
        for alg in (pipe, barrier):
            alg.validate(mode="bulk")
            alg.validate(mode="oracle")
        assert pipe.makespan <= barrier.makespan
        # barrier plans never carry the junction's release provenance
        assert not any("@release" in n for n, _, _ in barrier.phase_spans)

    def test_chunk_granular_release_provenance(self):
        """The pipelined junction records its per-chunk release envelope as
        a nested provenance span (invisible to top_phase_spans)."""
        topo = FABRICS["multi_pod"]()
        h = SynthesisEngine(topo, registry=AlgorithmRegistry()).hierarchical()
        alg = h.all_reduce(topo.npus, pipeline=True)
        spans = {n: (lo, hi) for n, lo, hi in alg.phase_spans}
        assert "all_gather/@release" in spans
        lo, hi = spans["all_gather/@release"]
        assert 0.0 < lo <= hi
        assert [n for n, _, _ in alg.top_phase_spans()] == [
            "reduce_scatter", "all_gather"]

    @pytest.mark.parametrize("fabric_name", ["multi_pod", "three_level"])
    def test_pre_release_corruption_flips_bulk(self, fabric_name):
        """Moving a single gather-half copy to before its chunk's reduce
        completion must flip bulk validation (and the oracle)."""
        import dataclasses

        topo = FABRICS[fabric_name]()
        h = SynthesisEngine(topo, registry=AlgorithmRegistry()).hierarchical()
        alg = h.all_reduce(topo.npus, pipeline=True)
        alg.validate(mode="bulk")
        ts = list(alg.transfers)
        # the last copy transfer starts strictly after its chunk's
        # assembly; yank it to t=0, before the chunk was even reduced
        idx = max((i for i, t in enumerate(ts) if not t.reduce),
                  key=lambda i: ts[i].start)
        assert ts[idx].start > 0.0
        dt = ts[idx].end - ts[idx].start
        ts[idx] = dataclasses.replace(ts[idx], start=0.0, end=dt)
        bad = CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                                  name=alg.name)
        with pytest.raises(AssertionError):
            bad.validate(mode="bulk")
        with pytest.raises(AssertionError):
            bad.validate(mode="oracle")

    def test_pre_release_spanning_corruption_flips_bulk(self):
        """A plain released condition: a single transfer moved before the
        condition's release floor must flip bulk validation."""
        import dataclasses

        topo = multi_pod(2, 2, 2, unit_links=True, dci_ports_per_pod=2)
        eng = SynthesisEngine(topo)
        remote = topo.pod_npus(1)[1]
        conds = [Condition(0, topo.pod_npus(0)[1], frozenset([remote]),
                           release=5.0)]
        alg = eng.hierarchical().spanning(conds)
        alg.validate(mode="bulk")
        ts = list(alg.transfers)
        idx = min(range(len(ts)), key=lambda i: ts[i].start)
        dt = ts[idx].end - ts[idx].start
        ts[idx] = dataclasses.replace(ts[idx], start=0.0, end=dt)
        bad = CollectiveAlgorithm(alg.topology, alg.conditions, ts,
                                  name=alg.name)
        with pytest.raises(AssertionError):
            bad.validate(mode="bulk")
