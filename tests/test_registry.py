"""Tests for the SynthesisEngine / AlgorithmRegistry stack: fingerprinting,
automorphism canonicalization, cache-hit relabeling, disk persistence, the
comms plan cache, and the launch-layer mesh planner."""

import numpy as np
import pytest

from repro.core import (
    AlgorithmRegistry,
    ChunkIds,
    CollectiveRequest,
    SynthesisEngine,
    all_gather,
    all_to_all,
    canonicalize_group,
    enumerate_automorphisms,
    from_msccl_json,
    is_automorphism,
    synthesize_all_gather,
    synthesize_joint,
    to_msccl_json,
    topology_fingerprint,
)
from repro.core import engine as engine_mod
from repro.core.registry import invert_permutation, relabel_algorithm
from repro.topology import hypercube, mesh2d, ring, torus2d


def torus_rows(rows, cols):
    return [[r * cols + c for c in range(cols)] for r in range(rows)]


def _rewrite_npz(path, mutate):
    """Load an npz entry, apply ``mutate(arrays)``, write it back."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    mutate(arrays)
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def _set(key, value):
    def mutate(arrays):
        arrays[key] = value
    return mutate


# name -> in-place corruption of an on-disk .npz plan entry
NPZ_CORRUPTIONS = {
    "garbage": lambda p: p.write_bytes(b"this is not a zip archive"),
    "empty": lambda p: p.write_bytes(b""),
    "truncated": lambda p: p.write_bytes(p.read_bytes()[:73]),
    "wrong-dtype": lambda p: _rewrite_npz(
        p, lambda a: a.update(t_start=a["t_start"].astype(np.float32))),
    "wrong-length": lambda p: _rewrite_npz(
        p, lambda a: a.update(t_link=a["t_link"][:-1])),
    "missing-column": lambda p: _rewrite_npz(
        p, lambda a: a.pop("t_chunk")),
    "bad-schema": lambda p: _rewrite_npz(
        p, _set("schema", np.array([999], np.int64))),
    "foreign-fingerprint": lambda p: _rewrite_npz(
        p, _set("fingerprint", np.array(["deadbeef"]))),
    "bad-indptr": lambda p: _rewrite_npz(
        p, lambda a: a.update(
            c_dests_indptr=a["c_dests_indptr"][::-1].copy())),
}


class TestAutomorphisms:
    def test_generators_verify(self):
        for topo in (ring(5), torus2d(3, 4), mesh2d(3, 3), hypercube(3)):
            assert topo.automorphism_generators
            for g in topo.automorphism_generators:
                assert is_automorphism(topo, g), topo.name

    def test_bogus_permutation_rejected(self):
        topo = torus2d(3, 3)
        assert not is_automorphism(topo, list(range(8)))  # wrong length
        perm = list(range(9))
        perm[0], perm[4] = perm[4], perm[0]  # not a torus symmetry? it is!
        # a single transposition of non-equivalent positions on a mesh2d:
        mesh = mesh2d(2, 3)
        p = list(range(6))
        p[0], p[1] = p[1], p[0]  # corner <-> edge-center: degree mismatch
        assert not is_automorphism(mesh, p)

    def test_closure_size_torus(self):
        topo = torus2d(4, 4)
        autos = enumerate_automorphisms(topo)
        assert len(autos) == 16  # 4 row-shifts x 4 col-shifts

    def test_rows_share_canonical_form(self):
        topo = torus2d(4, 4)
        canons = {canonicalize_group(topo, row)[0]
                  for row in torus_rows(4, 4)}
        assert len(canons) == 1
        canon, perm = canonicalize_group(topo, torus_rows(4, 4)[2])
        assert canon == (0, 1, 2, 3)
        assert is_automorphism(topo, perm)

    def test_fingerprint_name_independent(self):
        a, b = torus2d(3, 3), torus2d(3, 3)
        b.name = "renamed"
        assert topology_fingerprint(a) == topology_fingerprint(b)
        assert topology_fingerprint(a) != topology_fingerprint(torus2d(3, 4))


class TestRegistry:
    def test_isomorphic_rows_hit_without_bfs(self, monkeypatch):
        """Acceptance: the second (isomorphic) lookup performs no BFS and the
        relabeled algorithm validates with the cold makespan."""
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        rows = torus_rows(4, 4)

        cold = eng.all_gather(rows[0])
        cold.validate()
        assert reg.stats.misses == 1

        def boom(*a, **k):  # any BFS call on the hit path is a bug
            raise AssertionError("BFS ran on a registry hit")

        monkeypatch.setattr(engine_mod, "bfs_int", boom)
        monkeypatch.setattr(engine_mod, "bfs_cont", boom)
        for row in rows[1:]:
            alg = eng.all_gather(row)
            alg.validate()
            assert alg.makespan == cold.makespan
            # delivered to the requested group, not the canonical one
            for c in alg.conditions:
                assert c.dests == frozenset(row)
        assert reg.stats.hits == 3
        assert reg.stats.misses == 1

    def test_distinct_shapes_do_not_alias(self):
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        eng.all_gather(torus_rows(4, 4)[0])
        eng.collective(CollectiveRequest(
            "all_gather", group=tuple(torus_rows(4, 4)[0]),
            bytes=2.0))  # different params
        eng.all_to_all(torus_rows(4, 4)[0])  # different kind
        eng.all_gather([0, 5, 10, 15])  # diagonal: different canonical group
        assert reg.stats.misses == 4

    def test_reductions_and_allreduce_cached(self):
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        rows = torus_rows(4, 4)
        cold_rs = eng.reduce_scatter(rows[0])
        cold_ar = eng.collective(CollectiveRequest(
            "all_reduce", group=tuple(rows[0]), pipelined=True))
        hit_rs = eng.reduce_scatter(rows[3])
        hit_ar = eng.collective(CollectiveRequest(
            "all_reduce", group=tuple(rows[3]), pipelined=True))
        for alg in (cold_rs, cold_ar, hit_rs, hit_ar):
            alg.validate()
        assert hit_rs.makespan == cold_rs.makespan
        assert hit_ar.makespan == cold_ar.makespan
        assert reg.stats.misses == 2 and reg.stats.hits == 2

    def test_chunk_ids_follow_caller_allocator(self):
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        ids = ChunkIds(100)
        alg = eng.all_gather(torus_rows(4, 4)[1], ids=ids)
        assert sorted(c.chunk for c in alg.conditions) == list(range(100, 104))
        alg.validate()

    def test_lru_eviction(self):
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry(max_entries=1)
        eng = SynthesisEngine(topo, registry=reg)
        eng.all_gather(torus_rows(4, 4)[0])
        eng.all_to_all(torus_rows(4, 4)[0])  # evicts the all_gather
        eng.all_gather(torus_rows(4, 4)[0])  # re-synthesizes
        assert reg.stats.misses == 3
        assert reg.stats.evictions == 2

    def test_disk_persistence_roundtrip(self, tmp_path):
        topo = torus2d(4, 4)
        rows = torus_rows(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg1 = SynthesisEngine(topo, registry=reg1).all_gather(rows[0])
        assert list(tmp_path.glob("*.npz"))
        assert reg1.stats.bytes_stored > 0
        # fresh registry, same dir: served from disk, no synthesis
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg2 = SynthesisEngine(topo, registry=reg2).all_gather(rows[1])
        alg2.validate()
        assert reg2.stats.disk_hits == 1 and reg2.stats.misses == 0
        assert reg2.stats.bytes_loaded > 0
        assert alg2.makespan == alg1.makespan

    def test_disk_roundtrip_is_exact(self, tmp_path):
        """Disk-served plans are transfer-for-transfer identical to the
        plan that was stored (fields, order, phase spans)."""
        topo = torus2d(4, 4)
        rows = torus_rows(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg1 = SynthesisEngine(topo, registry=reg1).all_gather(rows[0])
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg2 = SynthesisEngine(topo, registry=reg2).all_gather(rows[0])
        assert list(alg2.transfers) == list(alg1.transfers)
        assert alg2.conditions == alg1.conditions
        assert alg2.phase_spans == alg1.phase_spans

    @pytest.mark.parametrize("corrupt", list(NPZ_CORRUPTIONS),
                             ids=list(NPZ_CORRUPTIONS))
    def test_corrupt_disk_entry_resynthesized(self, tmp_path, corrupt):
        """A corrupt/truncated/wrong-dtype/wrong-shape on-disk plan must be
        skipped (and replaced), never raise out of get_or_synthesize."""
        topo = torus2d(4, 4)
        rows = torus_rows(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        SynthesisEngine(topo, registry=reg1).all_gather(rows[0])
        (entry,) = tmp_path.glob("*.npz")
        NPZ_CORRUPTIONS[corrupt](entry)

        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg = SynthesisEngine(topo, registry=reg2).all_gather(rows[0])
        alg.validate()
        assert reg2.stats.disk_hits == 0 and reg2.stats.misses == 1
        # the bad entry was replaced by the fresh plan
        reg3 = AlgorithmRegistry(cache_dir=str(tmp_path))
        SynthesisEngine(topo, registry=reg3).all_gather(rows[0])
        assert reg3.stats.disk_hits == 1

    def test_truncated_disk_entry_resynthesized(self, tmp_path):
        """Half-written file from a killed process: same contract."""
        topo = torus2d(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        SynthesisEngine(topo, registry=reg1).all_gather(torus_rows(4, 4)[0])
        (entry,) = tmp_path.glob("*.npz")
        entry.write_bytes(entry.read_bytes()[: len(entry.read_bytes()) // 2])
        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg = SynthesisEngine(topo, registry=reg2).all_gather(
            torus_rows(4, 4)[1])
        alg.validate()
        assert reg2.stats.misses == 1

    def test_legacy_json_entry_migrated_to_npz(self, tmp_path):
        """Pre-npz .json entries still load, and are migrated in place."""
        topo = torus2d(4, 4)
        rows = torus_rows(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        # rows[0] is its own canonical form, so the returned algorithm is
        # exactly what a legacy registry would have serialized
        alg = SynthesisEngine(topo, registry=reg1).all_gather(rows[0])
        (npz,) = tmp_path.glob("*.npz")
        npz.with_suffix(".json").write_text(to_msccl_json(alg),
                                            encoding="utf-8")
        npz.unlink()

        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg2 = SynthesisEngine(topo, registry=reg2).all_gather(rows[1])
        alg2.validate()
        assert reg2.stats.disk_hits == 1 and reg2.stats.misses == 0
        assert alg2.makespan == alg.makespan
        # one-way migration: npz rewritten, json retired
        assert list(tmp_path.glob("*.npz"))
        assert not list(tmp_path.glob("*.json"))
        # and the migrated entry serves the next registry
        reg3 = AlgorithmRegistry(cache_dir=str(tmp_path))
        SynthesisEngine(topo, registry=reg3).all_gather(rows[0])
        assert reg3.stats.disk_hits == 1 and reg3.stats.misses == 0

    def test_corrupt_legacy_json_dropped(self, tmp_path):
        """A broken legacy .json entry is removed and resynthesized."""
        topo = torus2d(4, 4)
        rows = torus_rows(4, 4)
        reg1 = AlgorithmRegistry(cache_dir=str(tmp_path))
        SynthesisEngine(topo, registry=reg1).all_gather(rows[0])
        (npz,) = tmp_path.glob("*.npz")
        npz.with_suffix(".json").write_text("{ not json", encoding="utf-8")
        npz.unlink()

        reg2 = AlgorithmRegistry(cache_dir=str(tmp_path))
        alg = SynthesisEngine(topo, registry=reg2).all_gather(rows[0])
        alg.validate()
        assert reg2.stats.misses == 1
        assert not list(tmp_path.glob("*.json"))

    def test_relabel_preserves_validity_on_reduce(self):
        topo = torus2d(4, 4)
        eng = SynthesisEngine(topo)
        alg = eng.reduce_scatter(torus_rows(4, 4)[0])
        shift = topo.automorphism_generators[0]  # row translation
        relabeled = relabel_algorithm(alg, shift)
        relabeled.validate()
        assert relabeled.makespan == alg.makespan
        back = relabel_algorithm(relabeled, invert_permutation(shift))
        back.validate()
        assert [t.link for t in back.transfers] == [t.link for t in alg.transfers]


class TestTranslateRoundtrip:
    def test_msccl_json_roundtrip(self):
        topo = torus2d(3, 3)
        eng = SynthesisEngine(topo)
        for alg in (eng.all_gather(list(range(9))),
                    eng.all_reduce(list(range(9)))):
            rt = from_msccl_json(to_msccl_json(alg), topo)
            rt.validate()
            assert rt.makespan == alg.makespan
            assert rt.num_transfers == alg.num_transfers

    def test_roundtrip_rejects_missing_conditions(self):
        topo = ring(4)
        with pytest.raises(ValueError):
            from_msccl_json('{"gpus": []}', topo)


class TestJointSynthesis:
    def test_duplicate_chunk_rejection(self):
        topo = mesh2d(2, 2)
        with pytest.raises(ValueError, match="duplicate chunk"):
            synthesize_joint(
                topo, [("a", all_gather([0, 1])), ("b", all_gather([2, 3]))]
            )

    def test_multi_group_congestion_freedom(self):
        """Two process groups synthesized jointly never overlap on a link —
        checked explicitly here, beyond the validator."""
        topo = torus2d(4, 4)
        ids = ChunkIds()
        g1 = [0, 1, 2, 3]
        g2 = [12, 13, 14, 15]
        alg = synthesize_joint(
            topo,
            [("pg0", all_gather(g1, ids=ids)), ("pg1", all_to_all(g2, ids=ids))],
        )
        alg.validate()
        by_link: dict = {}
        for t in alg.transfers:
            for other in by_link.setdefault(t.link, []):
                assert not t.overlaps(other), f"congestion: {t} vs {other}"
            by_link[t.link].append(t)
        # both groups' postconditions satisfied
        tags = {c.tag for c in alg.conditions}
        assert tags == {"pg0", "pg1"}

    def test_registry_algorithms_compose_into_joint(self):
        """Registry-returned chunk numbering composes with a shared ChunkIds
        allocator (renumber_chunks path)."""
        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        eng = SynthesisEngine(topo, registry=reg)
        ids = ChunkIds()
        a = eng.all_gather([0, 1, 2, 3], ids=ids)
        b = eng.all_gather([8, 9, 10, 11], ids=ids)  # registry hit, remapped
        chunks = [c.chunk for c in a.conditions] + [c.chunk for c in b.conditions]
        assert len(set(chunks)) == 8
        assert reg.stats.hits == 1


class TestCommsPlanCache:
    def test_plan_cache_hit_on_repeat(self):
        from repro.comms.executor import (
            clear_plan_cache,
            plan_buffers_cached,
            plan_cache_stats,
        )
        from repro.core import to_ppermute_program

        clear_plan_cache()
        topo = ring(4, bidirectional=True)
        alg = synthesize_all_gather(topo, list(range(4)))
        prog = to_ppermute_program(alg)
        p1 = plan_buffers_cached(prog, "fp-1")
        p2 = plan_buffers_cached(prog, "fp-1")
        assert p1 is p2
        assert plan_cache_stats == {"hits": 1, "misses": 1}
        clear_plan_cache()

    def test_synthesize_program_reuses_plan(self):
        from repro.comms.executor import plan_cache_stats
        from repro.comms.primitives import (
            _PROGRAM_CACHE,
            CollectiveSpec,
            synthesize_program,
        )

        topo = ring(4, bidirectional=True)
        spec = CollectiveSpec("all_gather", (0, 1, 2, 3))
        reg = AlgorithmRegistry()
        prog1, plan1 = synthesize_program(topo, spec, registry=reg)
        before = dict(plan_cache_stats)
        # repeated identical collective: plan served from the executor cache
        prog2, plan2 = synthesize_program(topo, spec, registry=reg)
        assert plan2 is plan1 and prog2 is prog1
        assert plan_cache_stats["hits"] == before["hits"] + 1
        # even after the program cache is dropped, the plan survives
        _PROGRAM_CACHE.clear()
        _, plan3 = synthesize_program(topo, spec, registry=reg)
        assert plan3 is plan1
        # and the re-translation got its algorithm from the registry, no BFS
        assert reg.stats.hits >= 1


class TestCacheHygiene:
    def test_topology_mutation_invalidates_memoized_state(self):
        topo = ring(4)
        fp1 = topology_fingerprint(topo)
        autos1 = enumerate_automorphisms(topo)
        assert len(autos1) == 4
        topo.add_link(0, 2)  # chord: breaks the ring symmetry
        fp2 = topology_fingerprint(topo)
        assert fp2 != fp1
        # rotations are no longer automorphisms of the chorded graph
        assert len(enumerate_automorphisms(topo)) == 1

    def test_engines_are_collected_with_their_topology(self):
        import gc
        import weakref

        from repro.comms.primitives import CollectiveSpec, synthesize_program

        topo = ring(4, bidirectional=True)
        reg = AlgorithmRegistry()
        synthesize_program(topo, CollectiveSpec("all_gather", (0, 1, 2, 3)),
                           registry=reg)
        ref = weakref.ref(topo)
        del topo
        gc.collect()
        assert ref() is None, "engine cache kept the topology alive"


class TestMeshPlanner:
    def test_axis_groups_and_amortization(self):
        from repro.launch.sharding import MeshCollectivePlanner

        topo = torus2d(4, 4)
        reg = AlgorithmRegistry()
        pl = MeshCollectivePlanner(topo, {"data": 4, "model": 4}, registry=reg)
        assert pl.axis_groups("model")[0] == [0, 1, 2, 3]
        assert pl.axis_groups("data")[0] == [0, 4, 8, 12]
        stats = pl.warm(("all_gather",))
        # 2 axes x 4 groups = 8 lookups, 2 cold syntheses
        assert stats["misses"] == 2
        assert stats["hits"] == 6
        alg = pl.algorithm("all_gather", "data", 2)
        alg.validate()

    def test_size_mismatch_rejected(self):
        from repro.launch.sharding import MeshCollectivePlanner

        with pytest.raises(ValueError):
            MeshCollectivePlanner(torus2d(4, 4), {"data": 4, "model": 8})

    def test_joint_synthesis_split_allocators(self):
        from repro.launch.sharding import MeshCollectivePlanner

        pl = MeshCollectivePlanner(torus2d(4, 4), {"data": 4, "model": 4})
        # two model-axis rows run different collectives over one shared TEN;
        # chunk ids come from one ChunkIds.split() family (no collisions)
        alg = pl.joint([("all_gather", "model", 0),
                        ("all_to_all", "model", 2)])
        alg.validate()
        chunks = [c.chunk for c in alg.conditions]
        assert len(set(chunks)) == len(chunks)

    def test_joint_rejects_reductions(self):
        from repro.launch.sharding import MeshCollectivePlanner

        pl = MeshCollectivePlanner(torus2d(4, 4), {"data": 4, "model": 4})
        with pytest.raises(ValueError):
            pl.joint([("all_reduce", "model", 0)])
