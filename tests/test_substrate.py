"""Tests for data pipeline, optimizer, checkpointing, and fault-tolerance
policies — including a full kill-and-restore training round-trip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataPipeline
from repro.data.pipeline import _batch_for_step
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.runtime import ElasticMeshPlanner, FaultToleranceManager, StragglerMonitor


class TestData:
    def test_deterministic(self):
        a = _batch_for_step(7, 3, 4, 16, 100)
        b = _batch_for_step(7, 3, 4, 16, 100)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        a = _batch_for_step(7, 3, 4, 16, 100)
        b = _batch_for_step(7, 4, 4, 16, 100)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_shifted(self):
        a = _batch_for_step(0, 0, 2, 8, 50)
        np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
        assert (a["labels"][:, -1] == -1).all()

    def test_pipeline_restart_exactness(self):
        p1 = DataPipeline(seed=1, batch=2, seq=8, vocab=64)
        seen = [next(p1) for _ in range(5)]
        p1.close()
        # restart at step 3 reproduces batches 3, 4
        p2 = DataPipeline(seed=1, batch=2, seq=8, vocab=64, start_step=3)
        s3, b3 = next(p2)
        p2.close()
        assert s3 == 3
        np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                      np.asarray(seen[3][1]["tokens"]))


class TestOptim:
    def test_adamw_decreases_loss(self):
        w = {"w": jnp.asarray([2.0, -3.0])}
        opt = adamw_init(w)

        def loss(p):
            return jnp.sum(jnp.square(p["w"]))

        for _ in range(200):
            g = jax.grad(loss)(w)
            w, opt, m = adamw_update(w, g, opt, lr=0.05, weight_decay=0.0)
        assert float(loss(w)) < 1e-2

    def test_clip(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        assert total == pytest.approx(1.0, rel=1e-5)

    def test_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(lr(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-3)

    def test_weight_decay_exempt_norms(self):
        w = {"scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
        opt = adamw_init(w)
        g = jax.tree.map(jnp.zeros_like, w)
        w2, _, _ = adamw_update(w, g, opt, lr=0.1, weight_decay=0.5)
        np.testing.assert_array_equal(np.asarray(w2["scale"]),
                                      np.asarray(w["scale"]))  # exempt
        assert (np.asarray(w2["w"]) < 1.0).all()  # decayed


class TestCheckpointer:
    def test_atomic_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "step_meta": {"data_step": jnp.asarray(5)}}
        ck.save(5, state).result()
        assert ck.latest_step() == 5
        step, restored = ck.restore(state)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        ck.close()

    def test_prune_keeps_newest(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = {"params": {"w": jnp.zeros(2)}}
        for s in (1, 2, 3, 4):
            ck.save(s, state).result()
        ck.wait()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [3, 4]
        ck.close()

    def test_no_partial_checkpoint_visible(self, tmp_path):
        """A .tmp directory must never be considered a checkpoint."""
        ck = Checkpointer(str(tmp_path))
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert ck.latest_step() is None
        ck.close()

    def test_kill_and_restore_training(self, tmp_path):
        """Full loop: train 4 steps, checkpoint at 2, 'crash', restore, and
        verify steps 3-4 reproduce bit-exactly (deterministic data +
        restored state)."""
        from repro.configs import get_config
        from repro.models import LM

        cfg = get_config("llama3.2-1b").reduced(num_layers=1, vocab_size=128,
                                                dtype="float32")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)

        @jax.jit
        def train_step(params, opt, batch):
            (loss, _), grads = jax.value_and_grad(lm.loss, has_aux=True)(
                params, batch)
            p2, o2, _ = adamw_update(params, grads, opt, lr=1e-3)
            return p2, o2, loss

        def batches(step):
            b = _batch_for_step(11, step, 2, 16, cfg.vocab_size)
            return {k: jnp.asarray(v) for k, v in b.items()}

        ck = Checkpointer(str(tmp_path))
        losses = []
        for step in range(4):
            if step == 2:
                ck.save(2, {"params": params, "opt": opt}).result()
            params, opt, loss = train_step(params, opt, batches(step))
            losses.append(float(loss))

        # --- crash: restore from step 2 and replay ---
        step0, restored = ck.restore({"params": params, "opt": opt})
        assert step0 == 2
        p2, o2 = restored["params"], restored["opt"]
        replay = []
        for step in range(2, 4):
            p2, o2, loss = train_step(p2, o2, batches(step))
            replay.append(float(loss))
        np.testing.assert_allclose(replay, losses[2:], rtol=1e-6)
        ck.close()


class TestFaultTolerance:
    def test_elastic_plan(self):
        pl = ElasticMeshPlanner(model_degree=16)
        assert pl.plan(256) == (16, 16)
        assert pl.plan(255) == (15, 16)  # lose a node -> DP shrinks
        assert pl.plan(16) == (1, 16)
        with pytest.raises(RuntimeError):
            pl.plan(15)

    def test_elastic_plan_multi_pod(self):
        pl = ElasticMeshPlanner(model_degree=16)
        plans = pl.plan_multi_pod([256, 240])
        assert plans == [(15, 16), (15, 16)]  # symmetric at min survivor
        plans = pl.plan_multi_pod([256, 8])  # pod 2 dies entirely
        assert plans == [(16, 16)]

    def test_straggler_monitor(self):
        mon = StragglerMonitor(tolerance=2.0, evict_after=2)
        for _ in range(8):
            assert mon.record(1.0) == "ok"
        assert mon.record(5.0) == "straggler"
        assert mon.record(5.0) == "evict"
        assert mon.evictions == 1
        assert mon.record(1.0) == "ok"

    def test_recovery_flow(self, tmp_path):
        """End-to-end policy: save, 'fail' 16 chips, re-mesh, restore."""
        ck = Checkpointer(str(tmp_path))
        state = {"params": {"w": jnp.arange(4.0)}}
        ck.save(7, state).result()

        meshes = []

        def make_mesh(data, model):
            meshes.append((data, model))
            return (data, model)

        mgr = FaultToleranceManager(
            checkpointer=ck,
            planner=ElasticMeshPlanner(model_degree=16),
            make_mesh=make_mesh,
        )
        step, restored, mesh = mgr.recover(
            state, surviving_chips=240,
            shardings_for_mesh=lambda m: None or {})
        assert step == 7
        assert mesh == (15, 16)
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        assert mgr.restarts == 1
        ck.close()
