from repro.runtime.fault_tolerance import (
    ElasticMeshPlanner,
    FaultToleranceManager,
    StragglerMonitor,
)

__all__ = ["ElasticMeshPlanner", "FaultToleranceManager", "StragglerMonitor"]
