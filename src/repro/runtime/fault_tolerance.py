"""Fault tolerance for large-scale training: failure handling, elastic
re-meshing, and straggler mitigation.

At 1000+ node scale, node failure is a *when*, not an *if* (MTBF of a
10k-chip job is measured in hours). The policy layer here is hardware-
independent and fully unit-testable on CPU:

* :class:`FaultToleranceManager` — drives the checkpoint/restore/restart
  loop: on failure, pick the newest complete checkpoint, compute the
  surviving device set, re-mesh, restore (resharding onto the new mesh),
  and resume the data pipeline at the restored step (deterministic batches
  make this bit-exact).
* :class:`ElasticMeshPlanner` — given surviving chip count, choose the
  largest (data, model) mesh that preserves the model-parallel degree
  (TP degree is a property of the checkpoint's sharding; DP shrinks).
* :class:`StragglerMonitor` — per-step duration tracking with a robust
  deadline (median x tolerance); slow steps raise a straggler verdict that
  the training loop answers by skipping the straggler's microbatch
  contribution (gradient accumulation re-normalizes) or re-meshing the
  node away after `evict_after` consecutive verdicts.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class ElasticMeshPlanner:
    model_degree: int  # TP degree — fixed by the checkpoint's layout
    pod_size: int = 256

    def plan(self, surviving_chips: int) -> tuple[int, int]:
        """Largest (data, model) mesh with `model_degree` TP that fits the
        survivors. Data degree must keep at least 1."""
        if surviving_chips < self.model_degree:
            raise RuntimeError(
                f"cannot keep TP={self.model_degree} with only "
                f"{surviving_chips} chips")
        data = surviving_chips // self.model_degree
        return data, self.model_degree

    def plan_multi_pod(self, surviving_per_pod: list[int]):
        """Per-pod plan: each pod keeps its own (data, model); pods whose
        survivors can't host one TP group drop out of the job."""
        plans = []
        for chips in surviving_per_pod:
            if chips >= self.model_degree:
                plans.append(self.plan(chips))
        if not plans:
            raise RuntimeError("no pod can host a model-parallel group")
        # keep the common (minimum) data degree so pods stay symmetric
        data = min(d for d, _ in plans)
        return [(data, self.model_degree)] * len(plans)


@dataclass
class StragglerMonitor:
    tolerance: float = 2.0  # step slower than median x tolerance => straggler
    window: int = 32
    evict_after: int = 3
    _durations: list[float] = field(default_factory=list)
    _consecutive: int = 0
    evictions: int = 0

    def record(self, duration_s: float) -> str:
        """Returns 'ok' | 'straggler' | 'evict'."""
        history = self._durations[-self.window:]
        self._durations.append(duration_s)
        if len(history) < 5:
            return "ok"
        med = statistics.median(history)
        if duration_s <= med * self.tolerance:
            self._consecutive = 0
            return "ok"
        self._consecutive += 1
        if self._consecutive >= self.evict_after:
            self._consecutive = 0
            self.evictions += 1
            return "evict"
        return "straggler"

    @property
    def median(self) -> float:
        return statistics.median(self._durations) if self._durations else 0.0


@dataclass
class FaultToleranceManager:
    """Orchestrates recovery. All side effects are injected (checkpointer,
    mesh builder, pipeline factory) so the policy is testable without
    hardware.

    When a ``plan_service`` (:class:`repro.core.planservice.PlanService`)
    and ``topology`` are attached, the manager also re-plans the job's
    registered collectives for the surviving fabric on failure: register
    each :class:`repro.core.request.CollectiveRequest` the job runs via
    :meth:`register_collective`, and :meth:`recover` (given the
    ``degradation`` event) repairs them incrementally alongside the
    elastic re-mesh — phase-local where the damage allows, cold degraded
    resynthesis otherwise, and a loud
    :class:`repro.core.errors.FabricDegradedError` when the survivors
    cannot fulfil a collective at all."""

    checkpointer: object  # repro.checkpoint.Checkpointer
    planner: ElasticMeshPlanner
    make_mesh: Callable[[int, int], object]  # (data, model) -> mesh
    restarts: int = 0
    max_restarts: int = 100
    plan_service: object | None = None  # repro.core.planservice.PlanService
    topology: object | None = None  # the physical fabric the job runs on
    _collectives: list = field(default_factory=list)
    replanned: dict = field(default_factory=dict)

    def register_collective(self, request) -> None:
        """Track a collective this job depends on, for re-planning on
        failure. Planning happens lazily at the first repair (the service
        captures the healthy-fabric phase record then)."""
        if not any(r.fingerprint() == request.fingerprint()
                   for r in self._collectives):
            self._collectives.append(request)

    def replan_collectives(self, degradation, *,
                           validate: str | None = "auto") -> dict:
        """Repair every registered collective against ``degradation``
        (:class:`repro.core.repair.DegradationEvent`) on the surviving
        fabric; returns {request fingerprint: RepairResult} and keeps it
        on ``self.replanned``. A FabricDegradedError propagates — a job
        whose collective cannot be fulfilled must not resume on a silently
        broken schedule."""
        if self.plan_service is None or self.topology is None:
            raise RuntimeError(
                "collective re-planning needs plan_service= and topology=")
        out = {}
        for req in self._collectives:
            out[req.fingerprint()] = self.plan_service.repair(
                self.topology, req, degradation, validate=validate)
        self.replanned = out
        return out

    def recover(self, template: dict, surviving_chips: int,
                shardings_for_mesh: Callable[[object], dict],
                degradation=None):
        """Failure path: plan a new mesh from survivors, restore the newest
        checkpoint resharded onto it, and report the step to resume from.
        With a ``degradation`` event (and an attached plan service), the
        registered collectives are re-planned for the surviving fabric
        first — so an unfulfillable fabric fails loudly before any restore
        work happens.

        Returns (step, state, mesh)."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted")
        if degradation is not None and self._collectives:
            self.replan_collectives(degradation)
        data, model = self.planner.plan(surviving_chips)
        mesh = self.make_mesh(data, model)
        shardings = shardings_for_mesh(mesh)
        step, state = self.checkpointer.restore(template,
                                                shardings=shardings)
        return step, state, mesh


class StepTimer:
    """Context manager feeding the straggler monitor."""

    def __init__(self, monitor: StragglerMonitor):
        self.monitor = monitor
        self.verdict = "ok"

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.verdict = self.monitor.record(time.monotonic() - self._t0)
        return False
