"""Model configuration schema covering all assigned architecture families:
dense / MoE / SSM / hybrid / enc-dec / VLM-backbone / audio-backbone."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert FFN width (granite: 512)
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0  # N (state size per head)
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128  # SSD chunk length

    # --- attention flavor ---
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0  # chatglm3 uses 0.5 ("RoPE 2d" partial rotary)
    sliding_window: int = 0  # >0 enables SWA (h2o-danube)
    attn_logit_softcap: float = 0.0

    # --- hybrid (zamba2): shared attention block every K mamba blocks ---
    hybrid_attn_period: int = 0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper audio frames after conv frontend (stub)

    # --- modality frontends (stubs per assignment) ---
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_patches: int = 0  # vision stub: prepended patch embeddings (anyres)

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def padded_experts(self, ep: int) -> int:
        """Experts padded up to a multiple of the expert-parallel degree
        (granite-3b: 40 -> 48 on a 16-way axis); pad experts receive -inf
        router logits and are never selected."""
        if self.num_experts == 0:
            return 0
        return ((self.num_experts + ep - 1) // ep) * ep

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.is_moe:
            small.update(num_experts=4, experts_per_token=2, moe_d_ff=64)
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.family == "hybrid":
            small.update(num_layers=4, hybrid_attn_period=2)
        if self.is_encoder_decoder:
            small.update(encoder_layers=2, encoder_seq=8)
        if self.frontend == "vision_stub":
            small.update(num_patches=4)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    # ------------------------------------------------------------------
    # analytic parameter counts (for roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    qo = 2 * cfg.d_model * cfg.num_heads * cfg.head_dim
    kv = 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim
    return qo + kv


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d

    def dense_ffn():
        return 3 * d * cfg.d_ff  # SwiGLU

    def moe_ffn():
        e = cfg.experts_per_token if active_only else cfg.num_experts
        return e * 3 * d * cfg.moe_d_ff + d * cfg.num_experts  # + router

    def mamba_block():
        di, n = cfg.d_inner, cfg.ssm_state
        heads = cfg.ssm_heads
        in_proj = d * (2 * di + 2 * n * heads // cfg.ssm_heads * heads + heads)
        # simplified: in_proj ~ d*(2*di + 2*n_groups*n + heads); use n_groups=1
        in_proj = d * (2 * di + 2 * n + heads)
        return in_proj + di * cfg.ssm_conv_width + di * d + 2 * di

    per_layer_norms = 2 * d
    if cfg.family == "ssm":
        total += cfg.num_layers * (mamba_block() + per_layer_norms)
    elif cfg.family == "hybrid":
        total += cfg.num_layers * (mamba_block() + per_layer_norms)
        total += _attn_params(cfg) + dense_ffn() + per_layer_norms  # shared block
    elif cfg.is_encoder_decoder:
        enc = cfg.encoder_layers * (_attn_params(cfg) + dense_ffn() + per_layer_norms)
        dec = cfg.num_layers * (
            2 * _attn_params(cfg) + dense_ffn() + 3 * d  # self + cross attn
        )
        total += enc + dec
    elif cfg.is_moe:
        total += cfg.num_layers * (_attn_params(cfg) + moe_ffn() + per_layer_norms)
    else:
        total += cfg.num_layers * (_attn_params(cfg) + dense_ffn() + per_layer_norms)
    return int(total)
