"""whisper-medium [audio]: enc-dec, 24L decoder (+24L encoder) d_model=1024
16H (kv=16) d_ff=4096 vocab=51865. Conv/audio frontend is a STUB providing
precomputed frame embeddings [B, 1500, d] per the assignment.
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    frontend="audio_stub",
)
