"""Architecture registry: the 10 assigned architectures as selectable configs
(``--arch <id>``), plus shape specs (train/prefill/decode/long-context)."""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.mamba2_370m import CONFIG as mamba2_370m
from repro.configs.chatglm3_6b import CONFIG as chatglm3_6b
from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.h2o_danube_3_4b import CONFIG as h2o_danube_3_4b
from repro.configs.llama3_2_1b import CONFIG as llama3_2_1b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.zamba2_7b import CONFIG as zamba2_7b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        granite_moe_3b_a800m,
        granite_moe_1b_a400m,
        llava_next_34b,
        mamba2_370m,
        chatglm3_6b,
        internlm2_20b,
        h2o_danube_3_4b,
        llama3_2_1b,
        whisper_medium,
        zamba2_7b,
    ]
}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic attention."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 512k context needs sub-quadratic "
            "attention (see DESIGN.md §6)"
        )
    return True, ""


def all_cells():
    """Every (arch, shape) pair — 40 cells, with applicability flags."""
    for arch, cfg in sorted(REGISTRY.items()):
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape.name)
            yield arch, shape.name, ok, why
