"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling (vision frontend is a stub providing precomputed
patch embeddings per the assignment).
[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision_stub",
    num_patches=2880,  # anyres: base 576 + 4 tiles x 576
)
