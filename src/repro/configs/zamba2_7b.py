"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block (weights
reused at every invocation). [arXiv:2411.15242; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_period=6,  # shared block every 6 mamba blocks (13 invocations)
)
