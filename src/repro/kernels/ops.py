"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; elsewhere they run in interpret mode
(the kernel body executes as jax ops — bit-faithful to the TPU tiling but
slow), which is how the CPU test suite validates them against the ref.py
oracles. The model layer calls these through `use_flash`/`use_kernel` flags.
"""

from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=512, block_kv=512):
    """[B,S,H,hd] x [B,T,KV,hd]^2 -> [B,S,H,hd]."""
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=not _on_tpu())


def ssd_scan(xh, dt, A, Bm, Cm, *, chunk=128):
    """Chunked SSD: [B,S,H,P] inputs -> [B,S,H,P] outputs."""
    return _ssd.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk,
                         interpret=not _on_tpu())
