"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation of FlashAttention: the (q-block x kv-block) tile walk
maps onto a sequential TPU grid (batch*heads, q_blocks, kv_blocks) with the
online-softmax state (m, l, acc) living in VMEM scratch that persists across
the innermost (kv) grid dimension. Tiles are staged HBM->VMEM by BlockSpecs;
the two tile matmuls (q@k^T and p@v) hit the MXU. Causal/sliding-window
tiles that are fully masked are skipped with `pl.when` (a real branch on
TPU — the jnp reference path cannot skip, see DESIGN.md).

GQA is expressed in the index maps: query head h reads KV head
h // (H // KV) — no KV duplication in memory.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, softcap, bq, bkv, nkv):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * bq
    k_start = j * bkv
    # tile-level skipping: causal -> tiles strictly above the diagonal;
    # window -> tiles strictly left of the window
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + bq - 1
    if window > 0:
        run &= k_start + bkv - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0].astype(jnp.float32)  # [bkv, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bkv]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_start + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)  # fully-masked rows
        corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nkv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    bkv = min(block_kv, T)
    while T % bkv:
        bkv //= 2
    nq, nkv = S // bq, T // bkv
    scale = 1.0 / math.sqrt(hd)

    # layout: fold batch and head into the leading grid dim
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, hd)

    grid = (B * H, nq, nkv)

    def q_index(bh, i, j):
        return (bh, i, 0)

    def kv_index(bh, i, j):
        b = bh // H
        h = bh % H
        return (b * KV + h // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bkv=bkv, nkv=nkv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_index),
            pl.BlockSpec((1, bkv, hd), kv_index),
            pl.BlockSpec((1, bkv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, S, hd), 1, 2)
