"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU-native adaptation of the Mamba2 CUDA scan: the sequential recurrence is
restructured into its "state-space dual" chunked form — per chunk, two MXU
matmuls (the intra-chunk quadratic term C@B^T masked by the decay kernel L,
and the inter-chunk C@state term) — with the [head_dim, state] chunk-boundary
state carried in VMEM scratch across the innermost (chunk) grid dimension.
There is no warp-shuffle analogue on TPU; the carry IS the VMEM scratch and
the grid's guaranteed sequential order plays the role of the CUDA block scan.

Grid: (batch, heads, num_chunks), chunks innermost. B/C projections are
group-shared (one group), so their BlockSpecs ignore the head index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_scr, *,
                chunk):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [Q]
    A = a_ref[0].astype(jnp.float32)  # []
    Bm = b_ref[0].astype(jnp.float32)  # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)  # [Q, N]

    dA = dt * A  # [Q] (A < 0)
    csum = jnp.cumsum(dA)  # [Q]
    total = csum[-1]
    xdt = x * dt[:, None]  # [Q, P]

    # intra-chunk: (C B^T ∘ L) @ (x*dt), L[i,j] = exp(csum_i - csum_j), i>=j
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    ii = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(csum[:, None] - csum[None, :]), 0.0)
    intra = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Q,P]

    # inter-chunk: C_i decay_i @ state_in^T  (state: [P, N])
    state = state_scr[...]
    decayed_C = Cm * jnp.exp(csum)[:, None]  # [Q, N]
    inter = jax.lax.dot_general(decayed_C, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Q,P]

    o_ref[0, :, 0] = (intra + inter).astype(o_ref.dtype)

    # state update: exp(total) * state + sum_j exp(total - csum_j) x_j B_j^T
    decay_to_end = jnp.exp(total - csum)  # [Q]
    dstate = jax.lax.dot_general(
        xdt * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [P, N]
    state_scr[...] = state * jnp.exp(total) + dstate


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xh: jax.Array,  # [B, S, H, P] (pre-scaled inputs)
    dt: jax.Array,  # [B, S, H] post-softplus step sizes
    A: jax.Array,  # [H] negative decay rates
    Bm: jax.Array,  # [B, S, N] (group-shared)
    Cm: jax.Array,  # [B, S, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    grid = (B, H, nc)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A, Bm, Cm)
    return out
