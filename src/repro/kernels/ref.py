"""Pure-jnp oracles for the Pallas kernels.

Deliberately naive implementations (dense attention; step-by-step recurrent
SSD) — independent of both the kernels and the model code — used by the
per-kernel allclose sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Dense GQA attention. q: [B,S,H,hd]; k/v: [B,T,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    kk = jnp.repeat(k, group, axis=2)  # [B,T,H,hd]
    vv = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(xh, dt, A, Bm, Cm):
    """Token-by-token SSD recurrence (the definitional form).

    xh: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (<0);
    Bm/Cm: [B,S,N]. Returns y: [B,S,H,P].

    h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dt_t * A[None, :])  # [B,H]
        dBx = jnp.einsum("bn,bhp->bhpn", b_t, x_t * dt_t[..., None])
        h = h * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype)
