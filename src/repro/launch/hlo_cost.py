"""Loop-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by the trip
count (verified empirically: an 8-step scanned matmul reports 1 step's
flops). This module re-derives the totals hierarchically:

  cost(computation) = sum over instructions of
      dot           -> 2 * prod(result_shape) * contracted_size
      fusion        -> cost(called computation); HBM bytes = operands+result
                       of the fusion instruction itself
      while         -> (cost(body) + cost(cond)) * known_trip_count
      call/async    -> cost(callee)
      conditional   -> max over branch computations
      collectives   -> bytes tallied by kind (counted at -start, x trip count)
      elementwise   -> prod(result shape) flops (minor term)

Shapes in post-partitioning HLO are per-device, so all numbers are
per-device. The analyzer is deliberately approximate for non-dot flops —
dots dominate every cell here by >100x.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


@dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(type_str: str) -> list[Shape]:
    """'(s32[], f32[512]{0})' or 'bf16[4,8]{1,0}' -> [Shape, ...]."""
    return [Shape(dt, tuple(int(x) for x in dims.split(",")) if dims else ())
            for dt, dims in _SHAPE_RE.findall(type_str)]


@dataclass
class Instruction:
    name: str
    result_types: list[Shape]
    op: str
    operands: list[str]
    raw: str

    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.result_types)

    def result_elems(self) -> int:
        return sum(s.elems for s in self.result_types)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0  # HBM traffic proxy: fusion/top-level operand+result
    transcendentals: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    def add(self, other: "Cost", factor: float = 1.0):
        self.flops += other.flops * factor
        self.bytes += other.bytes * factor
        self.transcendentals += other.transcendentals * factor
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) + v * factor
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (
                self.collective_counts.get(k, 0) + v * factor)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)"
)
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE_TRANS = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                      "logistic", "sine", "cosine", "exponential-minus-one"}
_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "reshape", "broadcast", "iota",
    "after-all", "partition-id", "replica-id", "rng-get-and-update-state",
    "opt-barrier", "custom-call", "transpose", "slice", "concatenate",
    "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "gather",
    "scatter", "convert", "reduce", "select", "compare", "clamp", "map",
    "sort", "rng", "domain", "send", "recv", "send-done", "recv-done",
}


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instruction]] = {}
        self.instr_raw: dict[tuple[str, str], Instruction] = {}
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _parse(self, text: str):
        current = None
        for line in text.splitlines():
            stripped = line.strip()
            # computation header: `%name (args) -> type {` (args may nest
            # parens for tuple types); instruction lines contain " = ".
            if (stripped.endswith("{") and " = " not in stripped
                    and "->" in stripped):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                    continue
            if stripped.startswith("}"):
                current = None
                continue
            if current is None:
                continue
            im = _INSTR_RE.match(stripped)
            if not im:
                continue
            name, type_str, op = im.groups()
            instr = Instruction(name, parse_shapes(type_str), op, [], stripped)
            self.computations[current].append(instr)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m and m.group(1) in self.computations:
            return m.group(1)
        # fallback: computation not referenced by anyone
        called = set()
        for instrs in self.computations.values():
            for i in instrs:
                for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                    mm = pat.search(i.raw)
                    if mm:
                        called.add(mm.group(1))
        for name in self.computations:
            if name not in called:
                return name
        return next(iter(self.computations))

    # ------------------------------------------------------------------
    def _dot_flops(self, instr: Instruction, shapes_of: dict[str, Shape]) -> float:
        result = instr.result_types[0]
        cm = _CONTRACT_RE.search(instr.raw)
        ops = _OPERAND_RE.findall(instr.raw.split("(", 1)[1])
        lhs_shape = shapes_of.get(ops[0]) if ops else None
        if cm is None or lhs_shape is None:
            # assume square-ish: use result elems * sqrt heuristic — rare
            return 2.0 * result.elems
        contract = 1
        dims = [int(x) for x in cm.group(1).split(",") if x]
        for d in dims:
            if d < len(lhs_shape.dims):
                contract *= lhs_shape.dims[d]
        return 2.0 * result.elems * contract

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        total = Cost()
        shapes_of: dict[str, Shape] = {}
        for instr in self.computations.get(comp_name, []):
            if instr.result_types:
                shapes_of[instr.name] = instr.result_types[0]
            op = instr.op
            raw = instr.raw
            if op == "while":
                body = _BODY_RE.search(raw)
                cond = _COND_RE.search(raw)
                trips = 1
                tm = _TRIP_RE.search(raw)
                if tm:
                    trips = int(tm.group(1))
                sub = Cost()
                if body:
                    sub.add(self.cost_of(body.group(1)))
                if cond:
                    sub.add(self.cost_of(cond.group(1)))
                total.add(sub, factor=trips)
            elif op == "fusion":
                cm = _CALLS_RE.search(raw)
                if cm:
                    inner = self.cost_of(cm.group(1))
                    total.flops += inner.flops
                    total.transcendentals += inner.transcendentals
                    for k, v in inner.collective_bytes.items():
                        total.collective_bytes[k] = (
                            total.collective_bytes.get(k, 0) + v)
                # HBM traffic of the fusion = operands + results
                total.bytes += instr.result_bytes()
                ops = _OPERAND_RE.findall(raw.split("(", 1)[1])
                total.bytes += sum(
                    shapes_of[o].bytes for o in ops if o in shapes_of)
            elif op == "call":
                cm = _CALLS_RE.search(raw)
                if cm:
                    total.add(self.cost_of(cm.group(1)))
            elif op == "conditional":
                bm = _BRANCHES_RE.search(raw)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    costs = [self.cost_of(b) for b in branches
                             if b in self.computations]
                    if costs:
                        total.add(max(costs, key=lambda c: c.flops))
            elif op == "dot":
                total.flops += self._dot_flops(instr, shapes_of)
                total.bytes += instr.result_bytes()
                ops = _OPERAND_RE.findall(raw.split("(", 1)[1])
                total.bytes += sum(
                    shapes_of[o].bytes for o in ops if o in shapes_of)
            elif op == "convolution":
                # not used by these models (frontends are stubs); approximate
                total.flops += 2.0 * instr.result_elems()
            else:
                base = op.replace("-start", "")
                if base in COLLECTIVE_KINDS:
                    if op.endswith("-done"):
                        continue
                    nbytes = max(instr.result_bytes(), 1)
                    total.collective_bytes[base] = (
                        total.collective_bytes.get(base, 0) + nbytes)
                    total.collective_counts[base] = (
                        total.collective_counts.get(base, 0) + 1)
                elif op in _ELEMENTWISE_TRANS:
                    total.transcendentals += instr.result_elems()
                    total.flops += instr.result_elems()
                elif op not in _ZERO_COST_OPS:
                    # generic elementwise: add/multiply/subtract/...
                    total.flops += instr.result_elems()
        self._cost_cache[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
