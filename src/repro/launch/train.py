"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 20 --reduced            # CPU-runnable smoke
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --dry-run
        # lower+compile the full production cell instead of executing

The launcher wires together the production pieces: mesh + ShardingPolicy,
StepBundle (remat, grad accumulation, AdamW), deterministic DataPipeline,
async Checkpointer, straggler monitor, and (on restart) elastic recovery.
On this CPU container the full configs are exercised via --dry-run; real
execution uses --reduced configs. On a TPU slice the same code path runs the
full config directly.
"""

from __future__ import annotations

import argparse

import jax

from repro.checkpoint import Checkpointer
from repro.jaxcompat import make_mesh
from repro.configs import SHAPES, get_config
from repro.data.pipeline import DataPipeline
from repro.launch.sharding import ShardingPolicy, pad_heads
from repro.models import LM
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.runtime import StragglerMonitor
from repro.runtime.fault_tolerance import StepTimer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the full cell (no execution)")
    ap.add_argument("--reduced", action="store_true",
                    help="run a reduced config on the local devices")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        # delegate to the dry-run path (requires fresh process: 512 devices)
        import os
        import subprocess
        import sys

        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", args.shape, "--mesh", args.mesh]
        raise SystemExit(subprocess.call(cmd, env=dict(os.environ)))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = jax.device_count()
    mesh = make_mesh((1, n), ("data", "model"))
    policy = ShardingPolicy(mesh, cfg)
    cfg = pad_heads(cfg, policy.tp_size)
    policy.cfg = cfg
    lm = LM(cfg, ep_degree=policy.tp_size, policy=policy, remat=True)
    print(f"arch={cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    lr = cosine_schedule(3e-4, warmup=max(args.steps // 10, 1),
                         total=max(args.steps, 100))

    @jax.jit
    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(
            params, batch)
        params, opt, om = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss, om["grad_norm"]

    ck = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ck.latest_step() is not None:
        start, restored = ck.restore(
            {"params": params, "opt": opt},
            shardings={"params": policy.param_shardings(params)})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed at step {start}")

    batch_size, seq = (8, 256) if args.reduced else (
        SHAPES[args.shape].global_batch, SHAPES[args.shape].seq_len)
    pipe = DataPipeline(seed=0, batch=batch_size, seq=seq,
                        vocab=cfg.vocab_size, start_step=start)
    monitor = StragglerMonitor()
    for _ in range(start, args.steps):
        step, batch = next(pipe)
        with StepTimer(monitor) as t:
            params, opt, loss, gnorm = train_step(params, opt, batch)
            loss.block_until_ready()
        if t.verdict != "ok":
            print(f"  [straggler] step {step}: {t.verdict}")
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(loss):.4f} "
                  f"gnorm={float(gnorm):.2f}")
        if step and step % 10 == 0:
            ck.save(step, {"params": params, "opt": opt})
    ck.wait()
    pipe.close()
    print("done")


if __name__ == "__main__":
    main()
