"""Production serving launcher: batched decode against the KV-cache path.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-20b --dry-run
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.launch.sharding import ShardingPolicy, pad_heads
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["decode_32k", "long_500k"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    if args.dry_run:
        import os
        import subprocess
        import sys

        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", args.shape, "--mesh", args.mesh]
        raise SystemExit(subprocess.call(cmd, env=dict(os.environ)))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = jax.device_count()
    mesh = make_mesh((1, n), ("data", "model"))
    policy = ShardingPolicy(mesh, cfg)
    cfg = pad_heads(cfg, policy.tp_size)
    policy.cfg = cfg
    lm = LM(cfg, ep_degree=policy.tp_size, policy=policy)
    params = lm.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={args.batch}")

    max_seq = args.new_tokens + 8
    cache = lm.decode_init(args.batch, max_seq)
    step = jax.jit(lm.decode_step)
    tokens = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for t in range(args.new_tokens):
        logits, cache = step(params, cache, tokens, jnp.asarray(t))
        tokens = jnp.argmax(logits, axis=-1)
    tokens.block_until_ready()
    dt = time.time() - t0
    print(f"decoded {args.new_tokens} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.new_tokens * args.batch / dt:,.0f} tok/s)")


if __name__ == "__main__":
    main()
