"""Step builders: train_step / prefill_step / serve_step as AOT-lowerable
jitted functions with full input/output shardings, plus ``input_specs``
(ShapeDtypeStruct stand-ins — weak-type-correct, shardable, zero allocation).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.configs.base import ModelConfig
from repro.launch.sharding import ShardingPolicy, pad_heads
from repro.models import LM
from repro.optim import adamw_init, adamw_update, cosine_schedule


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def bf16(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


@dataclass
class StepBundle:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    arch: str
    shape: ShapeSpec
    cfg: ModelConfig  # padded config actually lowered
    lm: LM
    fn: Callable
    args: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


def _struct(tree):
    """eval_shape result -> plain ShapeDtypeStruct pytree."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a step."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": i32(B, S), "labels": i32(B, S)}
        if cfg.family == "encdec":
            batch["frames"] = bf16(B, cfg.encoder_seq, cfg.d_model)
        if cfg.family == "vlm":
            batch["patches"] = bf16(B, cfg.num_patches, cfg.d_model)
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": i32(B), "pos": i32()}


def batch_shardings(policy: ShardingPolicy, cfg: ModelConfig,
                    shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": policy.named(policy.batch_spec(B, S)),
           "labels": policy.named(policy.batch_spec(B, S))}
    if cfg.family == "encdec":
        s = policy.tp if cfg.encoder_seq % max(policy.tp_size, 1) == 0 else None
        out["frames"] = policy.named(
            P(policy.dp if B % policy.dp_size == 0 else None, s, None))
    if cfg.family == "vlm":
        s = policy.tp if cfg.num_patches % max(policy.tp_size, 1) == 0 else None
        out["patches"] = policy.named(
            P(policy.dp if B % policy.dp_size == 0 else None, s, None))
    if shape.kind == "prefill":
        out.pop("labels")
    return out


# Gradient-accumulation (microbatch) steps per arch for train_4k: divides
# per-device activation memory by the factor. Chosen so each cell's
# temp memory fits a 16 GiB v5e HBM (measured via dryrun memory_analysis).
ACCUM_STEPS: dict[str, int] = {
    "llava-next-34b": 8,  # micro-batch 32 == multi-pod DP degree (lower bound)
    "internlm2-20b": 4,
    "zamba2-7b": 2,
}


def build_bundle(arch: str, shape_name: str, mesh, *,
                 collective_backend: str = "xla",
                 accum_steps: int | None = None) -> StepBundle:
    """Construct the lowerable step for one dry-run cell."""
    base_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    policy = ShardingPolicy(mesh, base_cfg)
    cfg = pad_heads(base_cfg, policy.tp_size)
    policy.cfg = cfg
    lm = LM(cfg, ep_degree=policy.tp_size, policy=policy,
            remat=(shape.kind == "train"))

    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = _struct(jax.eval_shape(lm.init, rng))
    p_shard = policy.param_shardings(params_s)

    if shape.kind == "train":
        opt_s = _struct(jax.eval_shape(adamw_init, params_s))
        o_shard = _opt_shardings(policy, params_s, opt_s)
        batch_s = input_specs(cfg, shape)
        b_shard = batch_shardings(policy, cfg, shape)
        lr = cosine_schedule(3e-4, warmup=100, total=10000)
        accum = accum_steps if accum_steps is not None else ACCUM_STEPS.get(
            arch, 1)

        def compute_cast(params):
            """bf16 compute params (f32 masters stay in the optimizer): the
            FSDP weight all-gathers and per-microbatch gradient reductions
            then move half the bytes (§Perf iteration 2)."""
            return jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

        def grad_fn(params, batch):
            # differentiate at the bf16 compute params: the per-microbatch
            # cross-device grad reductions then move bf16, not f32
            # (§Perf iteration 4); f32 accumulation happens in the carry
            pc = compute_cast(params)
            return jax.value_and_grad(lm.loss, has_aux=True)(pc, batch)

        def train_step(params, opt_state, batch):
            if accum > 1:
                # microbatch over the batch dim; f32 grad accumulation keeps
                # the sum exact and divides activation memory by `accum`
                micro = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:]), batch)

                def mstep(carry, mb):
                    gacc, lacc, aacc = carry
                    (loss, metrics), grads = grad_fn(params, mb)
                    gacc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                    return (gacc, lacc + loss, aacc + metrics["moe_aux"]), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum, asum), _ = jax.lax.scan(
                    mstep, (g0, jnp.zeros((), jnp.float32),
                            jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / accum, gsum)
                loss = lsum / accum
                metrics = {"xent": loss, "moe_aux": asum / accum}
            else:
                (loss, metrics), grads = grad_fn(params, batch)
            new_params, new_opt, om = adamw_update(
                params, grads, opt_state, lr=lr)
            return new_params, new_opt, {"loss": loss, **metrics, **om}

        scalar = policy.named(P())
        out_shardings = (
            p_shard, o_shard,
            {"loss": scalar, "xent": scalar, "moe_aux": scalar,
             "grad_norm": scalar, "lr": scalar},
        )
        return StepBundle(arch, shape, cfg, lm, train_step,
                          (params_s, opt_s, batch_s),
                          (p_shard, o_shard, b_shard), out_shardings,
                          donate_argnums=(0, 1))

    if shape.kind == "prefill":
        batch_s = input_specs(cfg, shape)
        b_shard = batch_shardings(policy, cfg, shape)

        def prefill_step(params, batch):
            return lm.forward_logits(params, batch)

        out_shardings = policy.named(
            P(policy.dp if shape.global_batch % policy.dp_size == 0 else None,
              policy.tp if shape.seq_len % max(policy.tp_size, 1) == 0 else None,
              None))
        return StepBundle(arch, shape, cfg, lm, prefill_step,
                          (params_s, batch_s), (p_shard, b_shard),
                          out_shardings)

    # decode
    cache_s = _struct(
        jax.eval_shape(partial(lm.decode_init, shape.global_batch,
                               shape.seq_len)))
    c_shard = policy.cache_shardings(cache_s, shape.global_batch)
    tok_shard = policy.named(policy.token_spec(shape.global_batch))
    pos_shard = policy.named(P())

    def serve_step(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos)

    out_shardings = (policy.named(policy.logits_spec(shape.global_batch)),
                     c_shard)
    return StepBundle(
        arch, shape, cfg, lm, serve_step,
        (params_s, cache_s, i32(shape.global_batch), i32()),
        (p_shard, c_shard, tok_shard, pos_shard), out_shardings,
        donate_argnums=(1,))


def _opt_shardings(policy: ShardingPolicy, params_s, opt_s):
    """AdamW moments shard exactly like their parameters (ZeRO)."""
    p_shard = policy.param_shardings(params_s)
    return type(opt_s)(
        policy.named(P()),  # step counter
        p_shard,
        jax.tree.map(lambda s: s, p_shard),
    )
