import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape x
mesh) cell on the production meshes, and extract the roofline terms from the
compiled artifacts.

The two lines above MUST stay the first statements of this module — jax locks
the device count at first init, and the dry-run needs 512 placeholder host
devices to build the (2, 16, 16) production mesh. (Do not import this module
from tests/benches: they must see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json

Each cell records: compile ok, per-device memory stats, per-device HLO FLOPs
and bytes (cost_analysis), and per-collective byte counts parsed from the
compiled HLO (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) — the inputs to EXPERIMENTS.md §Dry-run and §Roofline.
Results are cached incrementally: re-runs skip completed cells.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


from repro.configs import REGISTRY, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_bundle  # noqa: E402

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> byte count (scalar '[]' -> element bytes)."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes_by_kind(hlo_text: str) -> dict[str, dict]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Works on post-SPMD-partitioning HLO, where shapes are per-device. Counts
    each op once (per-device traffic). `-start` variants are counted;
    matching `-done` ops are skipped to avoid double counting.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %ag = bf16[4,128]{1,0} all-gather(...), replica_groups=...
        m = re.search(
            r"=\s+([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?|\([^)]*\))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        shape_str, kind, phase = m.groups()
        if phase == "-done":
            continue
        if shape_str.startswith("("):  # tuple shape: sum elements
            nbytes = sum(_shape_bytes(p.strip())
                         for p in shape_str[1:-1].split(",") if "[" in p)
        else:
            nbytes = _shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str, mesh) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        bundle = build_bundle(arch, shape_name, mesh)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = collective_bytes_by_kind(hlo)
        # loop-aware totals (XLA's flat cost_analysis counts while bodies
        # once; scan-over-layers programs need the hierarchical model)
        deep = hlo_analyze(hlo)

        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "code_bytes": ma.generated_code_size_in_bytes,
            },
            flops=deep.flops,
            bytes_accessed=deep.bytes,
            collective_bytes=deep.collective_bytes,
            collective_counts=deep.collective_counts,
            xla_flat_flops=cost.get("flops", 0.0),
            xla_flat_bytes=cost.get("bytes accessed", 0.0),
            flat_collectives=colls,
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            padded_heads=bundle.cfg.num_heads,
            orig_heads=cfg.num_heads,
        )
    except Exception as e:  # noqa: BLE001 — record, don't abort the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"],
                    help="default: both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: dict[str, dict] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    meshes = {}
    if args.mesh in (None, "pod"):
        meshes["pod"] = make_production_mesh(multi_pod=False)
    if args.mesh in (None, "multipod"):
        meshes["multipod"] = make_production_mesh(multi_pod=True)

    archs = [args.arch] if args.arch else sorted(REGISTRY)
    shapes = [args.shape] if args.shape else list(SHAPES)

    failures = 0
    for mesh_name, mesh in meshes.items():
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}|{shape_name}|{mesh_name}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                rec = run_cell(arch, shape_name, mesh_name, mesh)
                results[key] = rec
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile={rec['compile_s']}s "
                             f"flops/dev={rec['flops']:.3g} "
                             f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
                elif status == "error":
                    extra = rec["error"][:160]
                    failures += 1
                print(f"[dryrun] {key}: {status} {extra}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"[dryrun] done; {failures} failures; results in {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
