"""Production device meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

from repro.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one TPU-v5e-like pod,
    2D torus). Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod
    axis is pure data parallelism across the DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, *, pods: int = 0):
    """Small mesh for CPU integration tests (requires forced host devices)."""
    if pods:
        return make_mesh((pods, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
