"""Sharding rules: param/activation PartitionSpecs for the production mesh.

Strategy (see DESIGN.md §4): Megatron-style tensor parallelism on the
"model" axis + ZeRO/FSDP sharding of the complementary weight dim on the
"data" axis + pure data parallelism on the "pod" axis, with sequence
parallelism (residual activations sharded on seq over "model") bounding
activation memory for the 4k/32k shapes.

Head counts that don't divide the TP degree are padded (llava 56->64,
granite-3b 24->32; zero-initialized wo rows keep the function exact); KV
projections replicate on the model axis when kv_heads doesn't divide it.
Every rule degrades to replication when a dim isn't divisible, so the same
rules serve reduced smoke configs and small test meshes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def pad_heads(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad num_heads up to a multiple of tp (keeping GQA grouping legal)."""
    h = cfg.num_heads
    if h % tp == 0 or cfg.family == "ssm":
        return cfg
    hp = ((h + tp - 1) // tp) * tp
    # keep grouping divisible: hp must be a multiple of kv heads
    while hp % cfg.num_kv_heads:
        hp += tp
    return dataclasses.replace(cfg, num_heads=hp)


@dataclass(eq=False)  # identity hash: used as a custom_vjp nondiff arg
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig

    def __post_init__(self):
        names = self.mesh.axis_names
        sizes = dict(zip(names, self.mesh.devices.shape))
        self.tp = "model" if "model" in names else None
        self.tp_size = sizes.get("model", 1)
        dp = tuple(a for a in ("pod", "data") if a in names)
        self.dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        self.dp_size = int(np.prod([sizes[a] for a in ("pod", "data")
                                    if a in names]))
        self.fsdp = "data" if "data" in names else None
        self.fsdp_size = sizes.get("data", 1)
        self.all_axes = tuple(names)
        self.total = int(np.prod(self.mesh.devices.shape))

    # -- helpers -----------------------------------------------------------
    def _div(self, dim: int, axis, size: int):
        """axis if dim divides evenly, else None (replicate)."""
        return axis if axis is not None and dim % size == 0 and size > 1 else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        return lax.with_sharding_constraint(x, self.named(spec))

    # -- parameter specs ----------------------------------------------------
    def param_spec(self, path: tuple, leaf) -> P:
        cfg = self.cfg
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        last = names[-1]
        shape = leaf.shape
        stacked = ("layers" in names or "enc_layers" in names
                   or "tail_layers" in names)
        pre = (None,) if stacked else ()
        tp, fsdp = self.tp, self.fsdp

        def spec(*dims):
            return P(*pre, *dims)

        if last == "table":  # embedding [V, d]
            # vocab on TP when divisible (best measured temp), else FSDP on d;
            # the gather is done in bf16 (see layers.embed) so the reshard of
            # its output never spills f32 copies.
            v_ax = self._div(shape[0], tp, self.tp_size)
            if v_ax:
                return P(v_ax, self._div(shape[1], fsdp, self.fsdp_size))
            return P(None, self._div(shape[1], fsdp, self.fsdp_size))
        if names[-2] == "unembed":  # [d, V]
            return P(self._div(shape[0], fsdp, self.fsdp_size),
                     self._div(shape[1], tp, self.tp_size))
        if last in ("wq",):
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last in ("wk", "wv"):
            kv_ok = cfg.num_kv_heads % self.tp_size == 0
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        tp if kv_ok and self.tp_size > 1 else None)
        if last == "wo":
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last in ("gate", "up"):
            if len(shape) == len(pre) + 3:  # MoE experts [*, E, d, ffe]
                return spec(self._div(shape[-3], tp, self.tp_size),
                            self._div(shape[-2], fsdp, self.fsdp_size), None)
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last == "down":
            if len(shape) == len(pre) + 3:  # MoE [*, E, ffe, d]
                return spec(self._div(shape[-3], tp, self.tp_size), None,
                            self._div(shape[-1], fsdp, self.fsdp_size))
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last == "router":
            return spec(self._div(shape[-2], fsdp, self.fsdp_size), None)
        if last in ("w_z", "w_x"):  # [*, d, d_inner] head-parallel
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last in ("w_B", "w_C"):  # group-shared: replicate state dim
            return spec(self._div(shape[-2], fsdp, self.fsdp_size), None)
        if last == "w_dt":
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last == "conv_x":
            return spec(None, self._div(shape[-1], tp, self.tp_size))
        if last in ("conv_B", "conv_C"):
            return spec(None, None)
        if last in ("dt_bias", "A_log", "D"):
            return spec(self._div(shape[-1], tp, self.tp_size))
        if last == "out_proj":  # [*, d_inner, d]
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last == "norm_scale":
            return spec(self._div(shape[-1], tp, self.tp_size))
        if last == "scale":  # RMSNorm
            return spec(None)
        # default: replicate
        return P(*((None,) * len(shape)))

    def param_shardings(self, params):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.named(self.param_spec(path, leaf)), params
        )

    def param_specs(self, params):
        return jax.tree_util.tree_map_with_path(self.param_spec, params)

    # -- activation specs ---------------------------------------------------
    @property
    def seq_spec(self) -> P:
        """Residual stream [B, S, d]: batch on DP, seq on TP (Megatron SP)."""
        return P(self.dp, self.tp, None)

    def batch_spec(self, batch_size: int, seq_len: int) -> P:
        """Token batches [B, S]."""
        dp = self.dp if batch_size % self.dp_size == 0 else None
        s = self.tp if seq_len % max(self.tp_size, 1) == 0 else None
        return P(dp, s)

    def token_spec(self, batch_size: int) -> P:
        return P(self.dp if batch_size % self.dp_size == 0 else None)

    def kv_cache_spec(self, batch_size: int, seq_len: int) -> P:
        """[L, B, S, KV, hd]: batch on DP, seq on TP; batch-1 long-context
        shards seq over every axis (256/512-way context parallelism)."""
        if batch_size == 1:
            all_sz = self.total
            s = self.all_axes if seq_len % all_sz == 0 else (
                self.tp if seq_len % self.tp_size == 0 else None)
            return P(None, None, s, None, None)
        dp = self.dp if batch_size % self.dp_size == 0 else None
        s = self.tp if seq_len % max(self.tp_size, 1) == 0 else None
        return P(None, dp, s, None, None)

    def ssm_cache_spec(self, field: str, batch_size: int, leaf) -> P:
        dp = self.dp if batch_size % self.dp_size == 0 else None
        if field == "state":  # [L, B, H, P, N]
            h = self.tp if leaf.shape[2] % max(self.tp_size, 1) == 0 else None
            return P(None, dp, h, None, None)
        if field == "conv_x":  # [L, B, K-1, d_inner]
            c = self.tp if leaf.shape[3] % max(self.tp_size, 1) == 0 else None
            return P(None, dp, None, c)
        return P(None, dp, None, None)  # conv_B / conv_C

    def cache_shardings(self, cache, batch_size: int):
        """Map a decode cache pytree to NamedShardings (shape-aware)."""

        def spec_for(path, leaf):
            names = [getattr(k, "key", str(k)) for k in path]
            if "kv" in names or "cross" in names:
                return self.named(
                    self.kv_cache_spec(batch_size, leaf.shape[2]))
            return self.named(self.ssm_cache_spec(names[-1], batch_size, leaf))

        return jax.tree_util.tree_map_with_path(spec_for, cache)

    def logits_spec(self, batch_size: int) -> P:
        dp = self.dp if batch_size % self.dp_size == 0 else None
        v = self.tp if self.cfg.vocab_size % max(self.tp_size, 1) == 0 else None
        return P(dp, v)

    def collective_planner(self, topo, registry=None) -> "MeshCollectivePlanner":
        """A planner for this policy's mesh over the physical fabric."""
        return MeshCollectivePlanner(
            topo,
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape)),
            registry=registry,
        )


# ---------------------------------------------------------------------------
# Mesh-axis collectives through the algorithm registry
# ---------------------------------------------------------------------------

class MeshCollectivePlanner:
    """Routes per-mesh-axis process-group collectives through the shared
    :class:`repro.core.registry.AlgorithmRegistry`.

    A (data, model) mesh laid row-major on the physical torus induces one
    process group per row of every axis: ``model``-axis groups vary the last
    axis, ``data``-axis groups the first, etc. All groups of one axis are
    isomorphic under the torus translations, so the registry synthesizes each
    (axis, collective, bytes) combination exactly once and serves every other
    row by relabeling — instead of the old per-row ad-hoc ``synthesize_*``
    calls.

    ``axis_sizes`` is an ordered {axis name: size} whose product must equal
    the NPU count; device index = row-major rank, assumed to coincide with
    the topology's NPU ids (true for ``tpu_v5e_pod``/``torus2d`` meshes, and
    for ``multi_pod`` meshes whose leading axis is the pod axis).

    On partitioned fabrics (``multi_pod`` et al), groups that span pods —
    e.g. the data-parallel axis of a ("pod", "data", "model") mesh — are
    routed through the hierarchical synthesis pipeline automatically (the
    engine's ``hierarchy="auto"``): per-pod phases are synthesized once per
    canonical pod and stitched with an inter-pod phase, instead of paying a
    flat whole-fabric TEN search per group. This covers the reduction
    collectives too — a pod-spanning ``reduce_scatter`` synthesizes as the
    time-reversal of a hierarchical All-Gather on the reversed fabric, and
    ``all_reduce`` composes that with the forward hierarchical All-Gather —
    so the data-parallel gradient path, the dominant collective of
    multi-pod training, takes the scalable route by default. Pass
    ``hierarchy="never"`` to force flat synthesis.

    Fabrics carrying a nested partition tree (``three_level`` et al —
    rack -> pod -> plane) recurse: a plane-spanning group decomposes into a
    plane phase over pod gateways, per-pod phases that themselves decompose
    into rack phases, and canonical per-rack plans registry-shared across
    every isomorphic rack of every pod. ``hierarchy_levels()`` reports how
    deep the routing goes.
    """

    def __init__(self, topo, axis_sizes: dict[str, int], *, registry=None,
                 gateway_strategy: str = "auto", sketch=None):
        from repro.core.engine import SynthesisEngine
        from repro.core.registry import default_registry

        self.topo = topo
        self.axis_sizes = dict(axis_sizes)
        shape = tuple(self.axis_sizes.values())
        if int(np.prod(shape)) != len(topo.npus):
            raise ValueError(
                f"mesh {self.axis_sizes} has {int(np.prod(shape))} devices "
                f"but topology has {len(topo.npus)} NPUs"
            )
        self.registry = registry if registry is not None else default_registry()
        # gateway_strategy/sketch steer the hierarchical inter-pod phase
        # (see repro.core.traffic) — e.g. a CommSketch keeping the
        # data-parallel axis' traffic off a storage plane's uplinks
        self.engine = SynthesisEngine(topo, registry=self.registry,
                                      gateway_strategy=gateway_strategy,
                                      sketch=sketch)
        self._ranks = np.arange(int(np.prod(shape))).reshape(shape)

    def axis_groups(self, axis: str) -> list[list[int]]:
        """Every process group of ``axis``: vary that axis, fix the others."""
        names = list(self.axis_sizes)
        k = names.index(axis)
        moved = np.moveaxis(self._ranks, k, -1)
        return [list(map(int, row)) for row in
                moved.reshape(-1, self.axis_sizes[axis])]

    def spans_pods(self, axis: str) -> bool:
        """True iff this axis' process groups cross a pod boundary (and will
        therefore take the hierarchical synthesis path by default)."""
        if self.topo.partition is None:
            return False
        return self.engine.hierarchical().spans_pods(self.axis_groups(axis)[0])

    def hierarchy_levels(self) -> int:
        """Routing depth of the fabric: 1 = flat, 2 = pods, 3 = pods-of-pods
        (rack -> pod -> plane), i.e. ``partition_depth + 1``. Pod-spanning
        groups synthesize through that many phase levels."""
        return self.topo.partition_depth + 1

    def algorithm(self, kind, axis: str, group_index: int = 0, *,
                  nbytes: float = 1.0, ids=None, **kw):
        """The synthesized (or registry-served) algorithm for one group.

        ``kind`` is either a collective name or a
        :class:`repro.core.request.CollectiveRequest` (its ``group`` is
        filled in from the axis; other fields pass through). The legacy
        string form builds the same request internally from ``nbytes`` and
        the remaining keywords (``chunks_per_npu``/``chunks_per_pair``,
        ``hierarchy``, ``pipelined``, ``root``).

        ``all_gather``/``all_to_all``/``reduce_scatter``/``all_reduce``
        groups that span pods route through the hierarchical pipeline
        automatically; override with ``hierarchy="never"`` (or
        "always")."""
        from repro.core.request import CollectiveRequest

        group = self.axis_groups(axis)[group_index]
        if isinstance(kind, CollectiveRequest):
            if kw:
                raise TypeError(
                    f"pass request fields on the CollectiveRequest, not as "
                    f"keywords: {sorted(kw)}")
            return self.engine.collective(kind.with_group(group), ids=ids)
        if kind not in ("all_gather", "all_to_all", "all_reduce",
                        "reduce_scatter", "reduce"):
            raise ValueError(f"unknown collective kind {kind!r}")
        chunks = kw.pop("chunks_per_npu", None)
        if chunks is None:
            chunks = kw.pop("chunks_per_pair", None)
        req_kw = {"bytes": nbytes}
        if chunks is not None:
            req_kw["chunks"] = chunks
        for f in ("hierarchy", "pipelined", "root"):
            if f in kw:
                req_kw[f] = kw.pop(f)
        if kw:
            raise TypeError(f"unknown keyword(s) {sorted(kw)} for {kind}")
        req = CollectiveRequest(kind, group=tuple(group), **req_kw)
        return self.engine.collective(req, ids=ids)

    def joint(self, parts, *, name: str = "pccl_joint"):
        """Jointly synthesize several mesh-axis collectives over one shared
        TEN (paper §6.4): ``parts`` is a list of ``(kind, axis, group_index)``
        or ``(kind, axis, group_index, nbytes)``. Chunk ids are drawn from
        one ``ChunkIds.split()`` family, so the condition builders cannot
        collide — previously every caller had to hand-thread one allocator.

        Only non-reduction kinds are supported (reductions synthesize via a
        reversed topology and cannot share this TEN).
        """
        from repro.core import conditions as cnd
        from repro.core.conditions import ChunkIds

        builders = {"all_gather": cnd.all_gather, "all_to_all": cnd.all_to_all}
        norm = [(p if len(p) == 4 else (*p, 1.0)) for p in parts]
        ids = ChunkIds()
        groups = []
        for child, (kind, axis, group_index, nbytes) in zip(
                ids.split(len(norm)), norm):
            builder = builders.get(kind)
            if builder is None:
                raise ValueError(
                    f"joint synthesis supports {sorted(builders)}, "
                    f"got {kind!r}"
                )
            group = self.axis_groups(axis)[group_index]
            conds = builder(group, ids=child, bytes=nbytes)
            groups.append((f"{kind}_{axis}{group_index}", conds))
        return self.engine.synthesize_joint(groups, name=name)

    def warm(self, kinds=("all_gather", "reduce_scatter"), *,
             nbytes: float = 1.0) -> dict:
        """Pre-populate the registry for every axis/kind; returns stats.

        Thanks to canonicalization this costs one cold synthesis per
        (axis, kind) — the remaining rows are cache hits."""
        for axis in self.axis_sizes:
            for kind in kinds:
                for i in range(len(self.axis_groups(axis))):
                    self.algorithm(kind, axis, i, nbytes=nbytes)
        return self.registry.stats.as_dict()

    def program(self, kind, axis: str, group_index: int = 0, *,
                nbytes: float = 1.0,
                device_of_npu: dict[int, int] | None = None):
        """(PpermuteProgram, BufferPlan) for executing one group's collective
        inside shard_map — synthesis, translation, and buffer planning all
        cached by fingerprint (see repro.comms).

        ``kind`` is a collective name or a
        :class:`repro.core.request.CollectiveRequest` (group filled in from
        the axis), mirroring :meth:`algorithm` — requests execute any engine
        route (hierarchy, TE gateways, sketches, pipelining)."""
        from repro.comms.primitives import CollectiveSpec, synthesize_program
        from repro.core.request import CollectiveRequest

        group = tuple(self.axis_groups(axis)[group_index])
        if isinstance(kind, CollectiveRequest):
            spec = kind.with_group(group)
        else:
            spec = CollectiveSpec(kind, group)
        return synthesize_program(
            self.topo, spec, nbytes=nbytes, registry=self.registry,
            device_of_npu=device_of_npu,
        )
