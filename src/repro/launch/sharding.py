"""Sharding rules: param/activation PartitionSpecs for the production mesh.

Strategy (see DESIGN.md §4): Megatron-style tensor parallelism on the
"model" axis + ZeRO/FSDP sharding of the complementary weight dim on the
"data" axis + pure data parallelism on the "pod" axis, with sequence
parallelism (residual activations sharded on seq over "model") bounding
activation memory for the 4k/32k shapes.

Head counts that don't divide the TP degree are padded (llava 56->64,
granite-3b 24->32; zero-initialized wo rows keep the function exact); KV
projections replicate on the model axis when kv_heads doesn't divide it.
Every rule degrades to replication when a dim isn't divisible, so the same
rules serve reduced smoke configs and small test meshes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def pad_heads(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad num_heads up to a multiple of tp (keeping GQA grouping legal)."""
    h = cfg.num_heads
    if h % tp == 0 or cfg.family == "ssm":
        return cfg
    hp = ((h + tp - 1) // tp) * tp
    # keep grouping divisible: hp must be a multiple of kv heads
    while hp % cfg.num_kv_heads:
        hp += tp
    return dataclasses.replace(cfg, num_heads=hp)


@dataclass(eq=False)  # identity hash: used as a custom_vjp nondiff arg
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig

    def __post_init__(self):
        names = self.mesh.axis_names
        sizes = dict(zip(names, self.mesh.devices.shape))
        self.tp = "model" if "model" in names else None
        self.tp_size = sizes.get("model", 1)
        dp = tuple(a for a in ("pod", "data") if a in names)
        self.dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        self.dp_size = int(np.prod([sizes[a] for a in ("pod", "data")
                                    if a in names]))
        self.fsdp = "data" if "data" in names else None
        self.fsdp_size = sizes.get("data", 1)
        self.all_axes = tuple(names)
        self.total = int(np.prod(self.mesh.devices.shape))

    # -- helpers -----------------------------------------------------------
    def _div(self, dim: int, axis, size: int):
        """axis if dim divides evenly, else None (replicate)."""
        return axis if axis is not None and dim % size == 0 and size > 1 else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        return lax.with_sharding_constraint(x, self.named(spec))

    # -- parameter specs ----------------------------------------------------
    def param_spec(self, path: tuple, leaf) -> P:
        cfg = self.cfg
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        last = names[-1]
        shape = leaf.shape
        stacked = ("layers" in names or "enc_layers" in names
                   or "tail_layers" in names)
        pre = (None,) if stacked else ()
        tp, fsdp = self.tp, self.fsdp

        def spec(*dims):
            return P(*pre, *dims)

        if last == "table":  # embedding [V, d]
            # vocab on TP when divisible (best measured temp), else FSDP on d;
            # the gather is done in bf16 (see layers.embed) so the reshard of
            # its output never spills f32 copies.
            v_ax = self._div(shape[0], tp, self.tp_size)
            if v_ax:
                return P(v_ax, self._div(shape[1], fsdp, self.fsdp_size))
            return P(None, self._div(shape[1], fsdp, self.fsdp_size))
        if names[-2] == "unembed":  # [d, V]
            return P(self._div(shape[0], fsdp, self.fsdp_size),
                     self._div(shape[1], tp, self.tp_size))
        if last in ("wq",):
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last in ("wk", "wv"):
            kv_ok = cfg.num_kv_heads % self.tp_size == 0
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        tp if kv_ok and self.tp_size > 1 else None)
        if last == "wo":
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last in ("gate", "up"):
            if len(shape) == len(pre) + 3:  # MoE experts [*, E, d, ffe]
                return spec(self._div(shape[-3], tp, self.tp_size),
                            self._div(shape[-2], fsdp, self.fsdp_size), None)
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last == "down":
            if len(shape) == len(pre) + 3:  # MoE [*, E, ffe, d]
                return spec(self._div(shape[-3], tp, self.tp_size), None,
                            self._div(shape[-1], fsdp, self.fsdp_size))
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last == "router":
            return spec(self._div(shape[-2], fsdp, self.fsdp_size), None)
        if last in ("w_z", "w_x"):  # [*, d, d_inner] head-parallel
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last in ("w_B", "w_C"):  # group-shared: replicate state dim
            return spec(self._div(shape[-2], fsdp, self.fsdp_size), None)
        if last == "w_dt":
            return spec(self._div(shape[-2], fsdp, self.fsdp_size),
                        self._div(shape[-1], tp, self.tp_size))
        if last == "conv_x":
            return spec(None, self._div(shape[-1], tp, self.tp_size))
        if last in ("conv_B", "conv_C"):
            return spec(None, None)
        if last in ("dt_bias", "A_log", "D"):
            return spec(self._div(shape[-1], tp, self.tp_size))
        if last == "out_proj":  # [*, d_inner, d]
            return spec(self._div(shape[-2], tp, self.tp_size),
                        self._div(shape[-1], fsdp, self.fsdp_size))
        if last == "norm_scale":
            return spec(self._div(shape[-1], tp, self.tp_size))
        if last == "scale":  # RMSNorm
            return spec(None)
        # default: replicate
        return P(*((None,) * len(shape)))

    def param_shardings(self, params):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.named(self.param_spec(path, leaf)), params
        )

    def param_specs(self, params):
        return jax.tree_util.tree_map_with_path(self.param_spec, params)

    # -- activation specs ---------------------------------------------------
    @property
    def seq_spec(self) -> P:
        """Residual stream [B, S, d]: batch on DP, seq on TP (Megatron SP)."""
        return P(self.dp, self.tp, None)

    def batch_spec(self, batch_size: int, seq_len: int) -> P:
        """Token batches [B, S]."""
        dp = self.dp if batch_size % self.dp_size == 0 else None
        s = self.tp if seq_len % max(self.tp_size, 1) == 0 else None
        return P(dp, s)

    def token_spec(self, batch_size: int) -> P:
        return P(self.dp if batch_size % self.dp_size == 0 else None)

    def kv_cache_spec(self, batch_size: int, seq_len: int) -> P:
        """[L, B, S, KV, hd]: batch on DP, seq on TP; batch-1 long-context
        shards seq over every axis (256/512-way context parallelism)."""
        if batch_size == 1:
            all_sz = self.total
            s = self.all_axes if seq_len % all_sz == 0 else (
                self.tp if seq_len % self.tp_size == 0 else None)
            return P(None, None, s, None, None)
        dp = self.dp if batch_size % self.dp_size == 0 else None
        s = self.tp if seq_len % max(self.tp_size, 1) == 0 else None
        return P(None, dp, s, None, None)

    def ssm_cache_spec(self, field: str, batch_size: int, leaf) -> P:
        dp = self.dp if batch_size % self.dp_size == 0 else None
        if field == "state":  # [L, B, H, P, N]
            h = self.tp if leaf.shape[2] % max(self.tp_size, 1) == 0 else None
            return P(None, dp, h, None, None)
        if field == "conv_x":  # [L, B, K-1, d_inner]
            c = self.tp if leaf.shape[3] % max(self.tp_size, 1) == 0 else None
            return P(None, dp, None, c)
        return P(None, dp, None, None)  # conv_B / conv_C

    def cache_shardings(self, cache, batch_size: int):
        """Map a decode cache pytree to NamedShardings (shape-aware)."""

        def spec_for(path, leaf):
            names = [getattr(k, "key", str(k)) for k in path]
            if "kv" in names or "cross" in names:
                return self.named(
                    self.kv_cache_spec(batch_size, leaf.shape[2]))
            return self.named(self.ssm_cache_spec(names[-1], batch_size, leaf))

        return jax.tree_util.tree_map_with_path(spec_for, cache)

    def logits_spec(self, batch_size: int) -> P:
        dp = self.dp if batch_size % self.dp_size == 0 else None
        v = self.tp if self.cfg.vocab_size % max(self.tp_size, 1) == 0 else None
        return P(dp, v)
