"""Fault-tolerant checkpointing for sharded training state.

Design (what a 1000-node deployment needs, scaled to this substrate):

* **Atomic**: state is written to ``step_<n>.tmp/`` and os.rename'd to
  ``step_<n>/`` only after an fsync'd manifest — a crash mid-write can never
  produce a half-checkpoint that restore() would pick up.
* **Async**: ``save()`` snapshots device arrays to host (jax.device_get —
  cheap, the step's arrays are immutable) and hands serialization to a
  background thread; training continues. ``wait()`` joins outstanding saves.
* **Sharded-aware**: leaves are saved as full (addressable) arrays here; on
  restore they are re-placed with the *target* sharding, so a checkpoint
  taken on one mesh restores onto another (elastic re-mesh after failures).
* **Self-pruning**: keeps the newest ``keep`` checkpoints.

The on-disk format is one .npz per pytree (flattened paths) plus a JSON
manifest carrying step and tree structure.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: list[Future] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, state: dict) -> Future:
        """Async atomic save. `state` is a dict of pytrees (e.g. {"params":
        ..., "opt": ..., "data_step": ...})."""
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        fut = self._pool.submit(self._write, step, host_state)
        with self._lock:
            self._pending = [f for f in self._pending if not f.done()]
            self._pending.append(fut)
        return fut

    def _write(self, step: int, host_state: dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "trees": {}}
        for name, tree in host_state.items():
            flat = _flatten(tree)
            np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
            manifest["trees"][name] = sorted(flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._prune()
        return final

    def _prune(self):
        done = sorted(d for d in os.listdir(self.directory)
                      if d.startswith("step_") and not d.endswith(".tmp"))
        for old in done[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, old))

    def wait(self):
        with self._lock:
            pending = list(self._pending)
        for f in pending:
            f.result()

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                    steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template: dict, *, step: int | None = None,
                shardings: dict | None = None) -> tuple[int, dict]:
        """Restore into the structure of `template`. `shardings` (same outer
        keys) re-places leaves onto devices — pass the CURRENT mesh's
        shardings to re-shard onto a different topology than the one that
        saved (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for name, tree in template.items():
            data = np.load(os.path.join(path, f"{name}.npz"))
            leaves_with_path = jax.tree_util.tree_leaves_with_path(tree)
            sharding_leaves = None
            if shardings is not None and name in shardings:
                # shardings[name] mirrors the state tree's structure
                sharding_leaves = jax.tree.leaves(
                    shardings[name],
                    is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            new_leaves = []
            for i, (p, leaf) in enumerate(leaves_with_path):
                key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                               for k in p)
                arr = data[key]
                if sharding_leaves is not None:
                    arr = jax.device_put(arr, sharding_leaves[i])
                new_leaves.append(arr)
            out[name] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tree), new_leaves)
        return manifest["step"], out

    def close(self):
        self.wait()
        self._pool.shutdown()
