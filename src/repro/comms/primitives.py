"""PCCL-backed collective primitives for JAX programs.

Drop-in collectives that run a PCCL-synthesized, topology-aware schedule via
ppermute instead of XLA's built-in all-gather/all-reduce/all-to-all. They are
meant to be called INSIDE shard_map over the axis (or flattened axes) whose
devices form the process group.

The schedule is synthesized once per (topology, group, collective, nbytes)
and cached; synthesis happens at trace time on the host, so the compiled
program embeds the static permute rounds.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms.executor import (
    BufferPlan,
    execute_program,
    gather_slots,
    plan_buffers_cached,
)
from repro.core.engine import SynthesisEngine
from repro.core.registry import default_registry, topology_fingerprint
from repro.core.request import CollectiveRequest
from repro.core.translate import PpermuteProgram, to_ppermute_program
from repro.topology.topology import Topology


@dataclass(frozen=True)
class CollectiveSpec:
    """What to synthesize: collective kind over a device group embedded in a
    physical topology. `device_of_npu` maps topology NPU ids to mesh axis
    indices; it must cover every NPU that may forward traffic (the whole
    topology for process-group-aware routing).

    Everywhere a ``CollectiveSpec`` is accepted, a fully-specified
    :class:`~repro.core.request.CollectiveRequest` works too — that is the
    way to execute hierarchy/TE/pipelining-routed plans, since the request
    carries ``hierarchy``/``gateway_strategy``/``sketch``/``pipelined``."""

    kind: str  # all_gather | reduce_scatter | all_reduce | all_to_all
    group: tuple[int, ...]  # NPU ids of the process group, in axis order


_EXEC_KINDS = ("all_gather", "all_to_all", "reduce_scatter", "all_reduce")


def _as_request(spec, nbytes: float, pipelined_ar: bool) -> CollectiveRequest:
    """Normalize CollectiveSpec | CollectiveRequest into a CollectiveRequest."""
    if isinstance(spec, CollectiveRequest):
        req = spec
    elif isinstance(spec, CollectiveSpec):
        req = CollectiveRequest(
            spec.kind, group=tuple(spec.group), bytes=nbytes,
            pipelined=pipelined_ar if spec.kind == "all_reduce" else False)
    else:
        raise TypeError(
            f"spec must be CollectiveSpec or CollectiveRequest, "
            f"got {type(spec).__name__}")
    if req.kind not in _EXEC_KINDS:
        raise ValueError(
            f"collective kind {req.kind!r} is not executable "
            f"(expected one of {_EXEC_KINDS})")
    if not req.group:
        raise ValueError("executable collectives need an explicit group")
    return req


# translated programs, keyed by fingerprint (bounded LRU; BufferPlans are
# owned by the executor's plan cache, not pinned here)
_PROGRAM_CACHE: OrderedDict = OrderedDict()
_PROGRAM_CACHE_MAX = 128


def _engine_for(topo: Topology, registry) -> SynthesisEngine:
    """One engine per (topology, registry), attached to the topology object
    so distance caches persist across collectives, the whole bundle is
    garbage-collected with the topology (a topo<->engine cycle, not an
    immortal module-level dict), and graph mutation invalidates it."""
    engines = getattr(topo, "_pccl_engines", None)
    if engines is None:
        engines = topo._pccl_engines = OrderedDict()
    eng = engines.get(id(registry))
    if eng is None:
        # NB: id(registry) stays valid while the entry exists because the
        # engine references the registry strongly.
        eng = SynthesisEngine(topo, registry=registry)
        engines[id(registry)] = eng
        while len(engines) > 8:
            engines.popitem(last=False)
    return eng


def synthesize_program(
    topo: Topology,
    spec,
    *,
    nbytes: float = 1.0,
    device_of_npu: dict[int, int] | None = None,
    pipelined_ar: bool = True,
    registry=None,
) -> tuple[PpermuteProgram, BufferPlan]:
    """Synthesis -> translation -> buffer planning, cached at every layer:
    the algorithm through the (shared) AlgorithmRegistry — so isomorphic
    process groups reuse one synthesized plan — the translated program here,
    and the BufferPlan through the executor's plan cache (the single owner
    of plans; every call goes through it, so its stats reflect real reuse).

    ``spec`` is a :class:`CollectiveSpec` (legacy default route) or a
    :class:`~repro.core.request.CollectiveRequest` — the latter executes any
    engine route: ``hierarchy="always"``, TE gateway strategies, comm
    sketches, pipelined all-reduce. ``nbytes``/``pipelined_ar`` only apply
    to the CollectiveSpec form; a request carries its own."""
    registry = registry if registry is not None else default_registry()
    req = _as_request(spec, nbytes, pipelined_ar)
    dev_key = (None if device_of_npu is None
               else tuple(sorted(device_of_npu.items())))
    key = (topology_fingerprint(topo), req.fingerprint(), dev_key)
    prog = _PROGRAM_CACHE.get(key)
    if prog is not None:
        _PROGRAM_CACHE.move_to_end(key)
    else:
        engine = _engine_for(topo, registry)
        alg = engine.collective(req)
        alg.validate()
        prog = to_ppermute_program(alg, device_of_npu)
        _PROGRAM_CACHE[key] = prog
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.popitem(last=False)
    return prog, plan_buffers_cached(prog, key)


def lower_algorithm(
    alg,
    *,
    key: object = "lowered",
    device_of_npu: dict[int, int] | None = None,
    validate: bool = False,
) -> tuple[PpermuteProgram, BufferPlan]:
    """Lower a pre-synthesized :class:`CollectiveAlgorithm` — e.g. a
    ``PlanRepairer`` repair result or a hand-stitched ``PhasePlan`` — to an
    executable (program, plan) pair that the ``pccl_*`` primitives accept
    via their ``program=`` argument. ``key`` namespaces the buffer-plan
    cache entry; the program's structural digest keeps distinct schedules
    apart even under one key."""
    if validate:
        alg.validate()
    prog = to_ppermute_program(alg, device_of_npu)
    return prog, plan_buffers_cached(prog, key)


def _group_devices(prog: PpermuteProgram, spec,
                   device_of_npu: dict[int, int] | None) -> list[int]:
    if device_of_npu is None:
        return list(spec.group)
    return [device_of_npu[n] for n in spec.group]


def _member_mask(prog: PpermuteProgram, devices: list[int]) -> np.ndarray:
    mask = np.zeros(prog.num_devices, dtype=bool)
    mask[devices] = True
    return mask


def _resolve(topo, spec, device_of_npu, program, kind):
    """Shared head of the pccl_* primitives: check the kind, fetch or accept
    a (program, plan) pair, map the group onto mesh devices, and build the
    non-participant mask — devices outside the process group may forward
    traffic (that is PG-awareness executing) but must hand back exact
    zeros, never forwarded or partially-reduced payloads."""
    req_kind = spec.kind
    if req_kind != kind:
        raise ValueError(f"pccl_{kind} got a spec of kind {req_kind!r}")
    if program is not None:
        prog, plan = program
    else:
        prog, plan = synthesize_program(topo, spec, device_of_npu=device_of_npu)
    devices = _group_devices(prog, spec, device_of_npu)
    return prog, plan, devices, _member_mask(prog, devices)


def _chunks_by_src(prog: PpermuteProgram, devices: list[int]) -> dict[int, list[int]]:
    out: dict[int, list[int]] = {d: [] for d in devices}
    for chunk, src in sorted(prog.chunk_srcs.items()):
        if src in out:
            out[src].append(chunk)
    return out


# ---------------------------------------------------------------------------
# collectives (call inside shard_map)
# ---------------------------------------------------------------------------

def pccl_all_gather(
    x: jax.Array,
    axis_name,
    topo: Topology | None,
    spec,
    *,
    device_of_npu: dict[int, int] | None = None,
    program: tuple[PpermuteProgram, BufferPlan] | None = None,
    tiled: bool = False,
) -> jax.Array:
    """All-gather x (local shard, shape S) over the group -> [g, *S] stacked
    in group order (or concatenated on axis 0 when tiled=True). Devices
    outside the group return zeros."""
    prog, plan, devices, member = _resolve(
        topo, spec, device_of_npu, program, "all_gather")
    by_src = _chunks_by_src(prog, devices)
    # one chunk per group member
    my_chunk_slot = np.zeros(prog.num_devices, dtype=np.int32)
    for dev in devices:
        (chunk,) = by_src[dev]
        my_chunk_slot[dev] = plan.slot_of[(dev, chunk)]
    idx = lax.axis_index(axis_name)
    buf = jnp.zeros((plan.buffer_slots, *x.shape), x.dtype)
    buf = lax.dynamic_update_index_in_dim(
        buf, x, jnp.asarray(my_chunk_slot)[idx], axis=0
    )
    buf = execute_program(plan, buf, axis_name)
    ordered_chunks = [by_src[d][0] for d in devices]
    out = gather_slots(plan, buf, axis_name, ordered_chunks)
    # non-participants may have forwarded chunks sitting in their slots —
    # mask so their output is untouched-by-the-collective zeros
    out = jnp.where(jnp.asarray(member)[idx], out, jnp.zeros_like(out))
    return jnp.concatenate(list(out), axis=0) if tiled else out


def pccl_reduce_scatter(
    x: jax.Array,
    axis_name,
    topo: Topology | None,
    spec,
    *,
    device_of_npu: dict[int, int] | None = None,
    program: tuple[PpermuteProgram, BufferPlan] | None = None,
) -> jax.Array:
    """x: [g, *S] (addend g for each group member); returns this device's
    reduced shard [*S] (devices outside the group return zeros)."""
    prog, plan, devices, member = _resolve(
        topo, spec, device_of_npu, program, "reduce_scatter")
    # chunk k is owned by group member k (condition order = group order)
    chunks = sorted(prog.chunk_holders)  # ReduceCondition: dests are owners
    owner_of_chunk = {c: prog.chunk_dests[c][0] for c in chunks}
    # initial buffer: device d's contribution to chunk k sits at d's slot for k
    # — but the reversed-AG plan only allocates slots along reduction paths.
    # Every group member is a leaf (or interior) of every chunk's tree, so the
    # slot exists for group devices.
    init_slot = np.full((prog.num_devices, len(chunks)), plan.num_slots, np.int32)
    for ci, c in enumerate(chunks):
        for dev in devices:
            got = plan.slot_of.get((dev, c))
            if got is not None:
                init_slot[dev, ci] = got
    idx = lax.axis_index(axis_name)
    buf = jnp.zeros((plan.buffer_slots, *x.shape[1:]), x.dtype)
    for ci in range(len(chunks)):
        buf = lax.dynamic_update_index_in_dim(
            buf, x[ci], jnp.asarray(init_slot[:, ci])[idx], axis=0
        )
    buf = execute_program(plan, buf, axis_name)
    # each group device extracts its own chunk
    my_chunk_table = np.zeros(prog.num_devices, dtype=np.int64)
    for ci, c in enumerate(chunks):
        my_chunk_table[owner_of_chunk[c]] = c
    out_slot = np.full(prog.num_devices, plan.num_slots, np.int32)
    for dev in devices:
        out_slot[dev] = plan.slot_of[(dev, int(my_chunk_table[dev]))]
    out = lax.dynamic_index_in_dim(
        buf, jnp.asarray(out_slot)[idx], axis=0, keepdims=False
    )
    return jnp.where(jnp.asarray(member)[idx], out, jnp.zeros_like(out))


def pccl_all_reduce(
    x: jax.Array,
    axis_name,
    topo: Topology | None,
    spec,
    *,
    device_of_npu: dict[int, int] | None = None,
    program: tuple[PpermuteProgram, BufferPlan] | None = None,
) -> jax.Array:
    """All-reduce x (same shape everywhere) over the group. x is split into
    g shard-chunks along axis 0 (must divide); composition RS∘AG per §4.5.
    Devices outside the group return zeros."""
    prog, plan, devices, member = _resolve(
        topo, spec, device_of_npu, program, "all_reduce")
    g = len(devices)
    chunks = sorted(prog.chunk_holders)
    assert len(chunks) == g, "all_reduce uses one shard-chunk per member"
    # chunk order follows group order by construction (see
    # synthesizer.synthesize_all_reduce: reduce_scatter iterates the group)
    xs = jnp.reshape(x, (g, x.shape[0] // g, *x.shape[1:]))
    init_slot = np.full((prog.num_devices, g), plan.num_slots, np.int32)
    for ci, c in enumerate(chunks):
        for dev in devices:
            got = plan.slot_of.get((dev, c))
            if got is not None:
                init_slot[dev, ci] = got
    idx = lax.axis_index(axis_name)
    buf = jnp.zeros((plan.buffer_slots, *xs.shape[1:]), x.dtype)
    for ci in range(g):
        buf = lax.dynamic_update_index_in_dim(
            buf, xs[ci], jnp.asarray(init_slot[:, ci])[idx], axis=0
        )
    buf = execute_program(plan, buf, axis_name)
    out = gather_slots(plan, buf, axis_name, chunks)
    out = jnp.where(jnp.asarray(member)[idx], out, jnp.zeros_like(out))
    return jnp.reshape(out, x.shape)


def pccl_all_to_all(
    x: jax.Array,
    axis_name,
    topo: Topology | None,
    spec,
    *,
    device_of_npu: dict[int, int] | None = None,
    program: tuple[PpermuteProgram, BufferPlan] | None = None,
) -> jax.Array:
    """x: [g, *S] where row j is this device's payload for group member j.
    Returns [g, *S] where row i is the payload received from member i
    (row for self = own self-payload, which never leaves the device).
    Devices outside the group return zeros."""
    prog, plan, devices, member = _resolve(
        topo, spec, device_of_npu, program, "all_to_all")
    g = len(devices)
    rank_of_device = {d: r for r, d in enumerate(devices)}
    # chunk (i -> j): src devices[i], dest devices[j]; build per-device tables
    send_chunk_slot = np.full((prog.num_devices, g), plan.num_slots, np.int32)
    recv_chunk_slot = np.full((prog.num_devices, g), plan.num_slots, np.int32)
    self_row = np.zeros(prog.num_devices, dtype=np.int32)
    for chunk, src in prog.chunk_srcs.items():
        dst = prog.chunk_dests[chunk][0]
        i, j = rank_of_device[src], rank_of_device[dst]
        send_chunk_slot[src, j] = plan.slot_of[(src, chunk)]
        recv_chunk_slot[dst, i] = plan.slot_of[(dst, chunk)]
    for dev in devices:
        self_row[dev] = rank_of_device[dev]
    idx = lax.axis_index(axis_name)
    buf = jnp.zeros((plan.buffer_slots, *x.shape[1:]), x.dtype)
    for j in range(g):
        buf = lax.dynamic_update_index_in_dim(
            buf, x[j], jnp.asarray(send_chunk_slot[:, j])[idx], axis=0
        )
    buf = execute_program(plan, buf, axis_name)
    rows = []
    for i in range(g):
        rows.append(
            lax.dynamic_index_in_dim(
                buf, jnp.asarray(recv_chunk_slot[:, i])[idx], axis=0, keepdims=False
            )
        )
    out = jnp.stack(rows)
    # self row: take from input (never transferred)
    me = jnp.asarray(self_row)[idx]
    self_payload = lax.dynamic_index_in_dim(x, me, axis=0, keepdims=False)
    out = lax.dynamic_update_index_in_dim(out, self_payload, me, axis=0)
    # the self-row write above lands row 0 <- x[0] on non-participants
    # (self_row defaults to 0); mask them back to zeros
    return jnp.where(jnp.asarray(member)[idx], out, jnp.zeros_like(out))
