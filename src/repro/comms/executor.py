"""Execute PCCL-synthesized schedules as shard_map ppermute programs.

This is the TPU adaptation of the paper's §4.8 (MSCCL translation): each
synthesis wave becomes one `jax.lax.ppermute` over the device mesh. Because
the synthesizer emits congestion-free neighbor-link transfers, the resulting
permutes are ICI-neighbor permutes on the physical torus.

Buffers are functional: every device holds a [num_slots, chunk_elems] array.
A static *buffer plan* assigns, per device, a slot to every chunk the device
ever holds (source, in-transit forwarder — possibly outside the process
group, which is how PG-awareness executes — or destination). Slot lookups
inside the traced program use per-device constant tables indexed by
`lax.axis_index`, so one SPMD program serves every device.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.translate import PpermuteProgram, Send


@dataclass
class RoundTables:
    perm: list[tuple[int, int]]
    send_slot: np.ndarray  # [num_devices] slot each device sends (0 if none)
    recv_slot: np.ndarray  # [num_devices] slot each device writes (trash if none)
    is_recv: np.ndarray  # [num_devices] bool
    is_reduce: np.ndarray  # [num_devices] bool (receive-reduce vs receive-copy)


@dataclass
class BufferPlan:
    num_devices: int
    num_slots: int  # data slots; slot num_slots is the trash slot
    slot_of: dict[tuple[int, int], int]  # (device, chunk) -> slot
    rounds: list[RoundTables] = field(default_factory=list)

    @property
    def buffer_slots(self) -> int:
        return self.num_slots + 1  # + trash


def plan_buffers(prog: PpermuteProgram) -> BufferPlan:
    n = prog.num_devices
    slot_of: dict[tuple[int, int], int] = {}
    next_slot = [0] * n

    def ensure_slot(device: int, chunk: int) -> int:
        key = (device, chunk)
        if key not in slot_of:
            slot_of[key] = next_slot[device]
            next_slot[device] += 1
        return slot_of[key]

    # initial holders (sources; every contributor for reduced chunks)
    for chunk, holders in prog.chunk_holders.items():
        for h in holders:
            ensure_slot(h, chunk)

    rounds: list[RoundTables] = []
    for sends in prog.rounds:
        perm = []
        send_slot = np.zeros(n, dtype=np.int32)
        recv_slot = np.zeros(n, dtype=np.int32)
        is_recv = np.zeros(n, dtype=bool)
        is_reduce = np.zeros(n, dtype=bool)
        for s in sends:
            if (s.src, s.chunk) not in slot_of:
                raise AssertionError(
                    f"send of chunk {s.chunk} from device {s.src} before arrival"
                )
            perm.append((s.src, s.dst))
            send_slot[s.src] = slot_of[(s.src, s.chunk)]
            recv_slot[s.dst] = ensure_slot(s.dst, s.chunk)
            is_recv[s.dst] = True
            is_reduce[s.dst] = s.reduce
        rounds.append(RoundTables(perm, send_slot, recv_slot, is_recv, is_reduce))

    num_slots = max(next_slot) if n else 0
    plan = BufferPlan(n, num_slots, slot_of, rounds)
    # route non-receivers' ppermute zeros into the trash slot
    for rt in plan.rounds:
        rt.recv_slot = np.where(rt.is_recv, rt.recv_slot, num_slots).astype(np.int32)
    return plan


def execute_program(
    plan: BufferPlan,
    buf: jax.Array,
    axis_name,
) -> jax.Array:
    """Run inside shard_map. `buf`: [plan.buffer_slots, *chunk_shape] local
    buffer with source chunks pre-placed at their planned slots. Returns the
    final buffer; callers extract destination slots via `plan.slot_of`."""
    idx = lax.axis_index(axis_name)
    for rt in plan.rounds:
        send_slot = jnp.asarray(rt.send_slot)[idx]
        recv_slot = jnp.asarray(rt.recv_slot)[idx]
        reduce_here = jnp.asarray(rt.is_reduce)[idx]
        val = lax.dynamic_index_in_dim(buf, send_slot, axis=0, keepdims=False)
        got = lax.ppermute(val, axis_name, rt.perm)
        old = lax.dynamic_index_in_dim(buf, recv_slot, axis=0, keepdims=False)
        new = jnp.where(reduce_here, old + got, got)
        buf = lax.dynamic_update_index_in_dim(buf, new, recv_slot, axis=0)
    return buf


def gather_slots(
    plan: BufferPlan, buf: jax.Array, axis_name, chunks: list[int]
) -> jax.Array:
    """Extract `chunks` (in order) from the local buffer; per-device slot
    tables again via axis_index. Missing chunks map to the trash slot."""
    idx = lax.axis_index(axis_name)
    tables = []
    for chunk in chunks:
        t = np.full(plan.num_devices, plan.num_slots, dtype=np.int32)
        for dev in range(plan.num_devices):
            got = plan.slot_of.get((dev, chunk))
            if got is not None:
                t[dev] = got
        tables.append(jnp.asarray(t)[idx])
    slots = jnp.stack(tables)
    return jnp.take(buf, slots, axis=0)
