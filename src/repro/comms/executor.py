"""Execute PCCL-synthesized schedules as shard_map ppermute programs.

This is the TPU adaptation of the paper's §4.8 (MSCCL translation): each
synthesis wave becomes one `jax.lax.ppermute` over the device mesh. Because
the synthesizer emits congestion-free neighbor-link transfers, the resulting
permutes are ICI-neighbor permutes on the physical torus.

Buffers are functional: every device holds a [num_slots, chunk_elems] array.
A static *buffer plan* assigns, per device, a slot to every chunk the device
ever holds (source, in-transit forwarder — possibly outside the process
group, which is how PG-awareness executes — or destination). Slot lookups
inside the traced program use per-device constant tables indexed by
`lax.axis_index`, so one SPMD program serves every device.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.translate import PpermuteProgram


@dataclass
class RoundTables:
    perm: list[tuple[int, int]]
    send_slot: np.ndarray  # [num_devices] slot each device sends (0 if none)
    recv_slot: np.ndarray  # [num_devices] slot each device writes (trash if none)
    is_recv: np.ndarray  # [num_devices] bool
    is_reduce: np.ndarray  # [num_devices] bool (receive-reduce vs receive-copy)


@dataclass
class BufferPlan:
    num_devices: int
    num_slots: int  # data slots; slot num_slots is the trash slot
    slot_of: dict[tuple[int, int], int]  # (device, chunk) -> slot
    rounds: list[RoundTables] = field(default_factory=list)
    # lazily-built stacked [num_rounds, num_devices] device arrays, shared by
    # every trace of this plan (see round_tables)
    _tables: tuple | None = field(default=None, repr=False, compare=False)

    @property
    def buffer_slots(self) -> int:
        return self.num_slots + 1  # + trash

    def round_tables(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """(send_slot, recv_slot, is_reduce) stacked over rounds. The numpy
        stacks are built once per plan; the jnp conversion happens per call
        — memoizing the device arrays would capture the enclosing trace's
        tracers when first materialized inside shard_map, and a cached plan
        is shared across traces (tests, retraces, threads)."""
        if self._tables is None:
            n = self.num_devices
            if self.rounds:
                send = np.stack([rt.send_slot for rt in self.rounds])
                recv = np.stack([rt.recv_slot for rt in self.rounds])
                red = np.stack([rt.is_reduce for rt in self.rounds])
            else:
                send = np.zeros((0, n), np.int32)
                recv = np.zeros((0, n), np.int32)
                red = np.zeros((0, n), bool)
            self._tables = (send, recv, red)
        send, recv, red = self._tables
        return jnp.asarray(send), jnp.asarray(recv), jnp.asarray(red)


def plan_buffers(prog: PpermuteProgram) -> BufferPlan:
    """Assign per-device buffer slots and build per-round permute tables.

    Array-backed: slots live in a dense ``[num_devices, num_chunks]`` int32
    matrix (-1 = unassigned) and every round's tables are filled with numpy
    scatters over the round's send arrays, instead of per-send dict probes.
    Slot numbering is identical to the historical per-transfer scan: initial
    holders first (condition order), then receivers in round order — each
    device appears at most once as a destination per round, so the
    vectorized assignment order cannot collide. ``slot_of`` is materialized
    once at the end for the primitives' lookup API.
    """
    n = prog.num_devices
    chunks = sorted(prog.chunk_holders)
    cidx = {c: k for k, c in enumerate(chunks)}
    slot = np.full((n, len(chunks)), -1, dtype=np.int32)
    next_slot = np.zeros(n, dtype=np.int32)

    # initial holders (sources; every contributor for reduced chunks)
    for chunk, holders in prog.chunk_holders.items():
        k = cidx[chunk]
        for h in holders:
            if slot[h, k] < 0:
                slot[h, k] = next_slot[h]
                next_slot[h] += 1

    rounds: list[RoundTables] = []
    for sends in prog.rounds:
        send_slot = np.zeros(n, dtype=np.int32)
        recv_slot = np.zeros(n, dtype=np.int32)
        is_recv = np.zeros(n, dtype=bool)
        is_reduce = np.zeros(n, dtype=bool)
        if not sends:
            rounds.append(RoundTables([], send_slot, recv_slot, is_recv,
                                      is_reduce))
            continue
        m = len(sends)
        src = np.fromiter((s.src for s in sends), np.int64, m)
        dst = np.fromiter((s.dst for s in sends), np.int64, m)
        red = np.fromiter((s.reduce for s in sends), bool, m)
        try:
            ck = np.fromiter((cidx[s.chunk] for s in sends), np.int64, m)
        except KeyError:
            bad = next(s for s in sends if s.chunk not in cidx)
            raise AssertionError(
                f"send of chunk {bad.chunk} from device {bad.src} "
                f"before arrival"
            ) from None
        ssl = slot[src, ck]
        if (ssl < 0).any():
            bad = sends[int(np.argmax(ssl < 0))]
            raise AssertionError(
                f"send of chunk {bad.chunk} from device {bad.src} "
                f"before arrival"
            )
        need = slot[dst, ck] < 0
        # destinations are unique within a ppermute round, so the scattered
        # slot grants cannot collide
        slot[dst[need], ck[need]] = next_slot[dst[need]]
        next_slot[dst[need]] += 1
        send_slot[src] = ssl
        recv_slot[dst] = slot[dst, ck]
        is_recv[dst] = True
        is_reduce[dst] = red
        perm = list(zip(src.tolist(), dst.tolist()))
        rounds.append(RoundTables(perm, send_slot, recv_slot, is_recv,
                                  is_reduce))

    num_slots = int(next_slot.max()) if n else 0
    devs, ks = np.nonzero(slot >= 0)
    slot_of = {
        (int(d), chunks[k]): int(slot[d, k]) for d, k in zip(devs, ks)
    }
    plan = BufferPlan(n, num_slots, slot_of, rounds)
    # route non-receivers' ppermute zeros into the trash slot
    for rt in plan.rounds:
        rt.recv_slot = np.where(rt.is_recv, rt.recv_slot, num_slots).astype(np.int32)
    return plan


# ---------------------------------------------------------------------------
# Plan cache: fingerprint -> BufferPlan. Repeated identical collectives (same
# synthesized program, e.g. the all-reduce issued every training step, or the
# same registry-canonical collective re-requested after a retrace) skip
# plan_buffers entirely and share the plan's jitted round tables.
# ---------------------------------------------------------------------------

_PLAN_CACHE: OrderedDict[object, BufferPlan] = OrderedDict()
_PLAN_CACHE_MAX = 128
_PLAN_LOCK = threading.Lock()
plan_cache_stats = {"hits": 0, "misses": 0}


def plan_buffers_cached(prog: PpermuteProgram, fingerprint: object) -> BufferPlan:
    """``plan_buffers`` behind a thread-safe LRU.

    The key pairs the caller's fingerprint (registry fingerprint plus device
    mapping is the natural choice) with the program's own structural digest,
    so two distinct programs whose callers happen to hand in the same
    fingerprint can never cross-serve one buffer plan — the digest disambiguates
    while the caller fingerprint keeps lookups stable across re-translations
    of the same schedule.
    """
    key = (fingerprint, prog.digest())
    with _PLAN_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _PLAN_CACHE.move_to_end(key)
            plan_cache_stats["hits"] += 1
            return plan
    # plan outside the lock: duplicated work under a race is cheaper than
    # serializing every cold plan behind one mutex
    plan = plan_buffers(prog)
    with _PLAN_LOCK:
        existing = _PLAN_CACHE.get(key)
        if existing is not None:
            _PLAN_CACHE.move_to_end(key)
            plan_cache_stats["hits"] += 1
            return existing
        plan_cache_stats["misses"] += 1
        _PLAN_CACHE[key] = plan
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)
    return plan


def clear_plan_cache() -> None:
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()
        plan_cache_stats.update(hits=0, misses=0)


def execute_program(
    plan: BufferPlan,
    buf: jax.Array,
    axis_name,
) -> jax.Array:
    """Run inside shard_map. `buf`: [plan.buffer_slots, *chunk_shape] local
    buffer with source chunks pre-placed at their planned slots. Returns the
    final buffer; callers extract destination slots via `plan.slot_of`."""
    idx = lax.axis_index(axis_name)
    send_t, recv_t, reduce_t = plan.round_tables()
    for r, rt in enumerate(plan.rounds):
        send_slot = send_t[r, idx]
        recv_slot = recv_t[r, idx]
        reduce_here = reduce_t[r, idx]
        val = lax.dynamic_index_in_dim(buf, send_slot, axis=0, keepdims=False)
        got = lax.ppermute(val, axis_name, rt.perm)
        old = lax.dynamic_index_in_dim(buf, recv_slot, axis=0, keepdims=False)
        new = jnp.where(reduce_here, old + got, got)
        buf = lax.dynamic_update_index_in_dim(buf, new, recv_slot, axis=0)
    return buf


def gather_slots(
    plan: BufferPlan, buf: jax.Array, axis_name, chunks: list[int]
) -> jax.Array:
    """Extract `chunks` (in order) from the local buffer; per-device slot
    tables again via axis_index. Missing chunks map to the trash slot."""
    idx = lax.axis_index(axis_name)
    tables = []
    for chunk in chunks:
        t = np.full(plan.num_devices, plan.num_slots, dtype=np.int32)
        for dev in range(plan.num_devices):
            got = plan.slot_of.get((dev, chunk))
            if got is not None:
                t[dev] = got
        tables.append(jnp.asarray(t)[idx])
    slots = jnp.stack(tables)
    return jnp.take(buf, slots, axis=0)
