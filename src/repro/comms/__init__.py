from repro.comms.executor import (
    BufferPlan,
    clear_plan_cache,
    execute_program,
    plan_buffers,
    plan_buffers_cached,
    plan_cache_stats,
)
from repro.comms.primitives import (
    CollectiveSpec,
    lower_algorithm,
    pccl_all_gather,
    pccl_all_reduce,
    pccl_all_to_all,
    pccl_reduce_scatter,
    synthesize_program,
)
from repro.comms.compression import (
    ef_int8_compress,
    ef_int8_decompress,
    error_feedback_all_reduce,
    topk_compress,
    topk_decompress,
)

__all__ = [
    "BufferPlan",
    "clear_plan_cache",
    "execute_program",
    "plan_buffers",
    "plan_buffers_cached",
    "plan_cache_stats",
    "CollectiveSpec",
    "lower_algorithm",
    "pccl_all_gather",
    "pccl_all_reduce",
    "pccl_all_to_all",
    "pccl_reduce_scatter",
    "synthesize_program",
    "ef_int8_compress",
    "ef_int8_decompress",
    "error_feedback_all_reduce",
    "topk_compress",
    "topk_decompress",
]
