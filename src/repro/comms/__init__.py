from repro.comms.executor import BufferPlan, execute_program, plan_buffers
from repro.comms.primitives import (
    CollectiveSpec,
    pccl_all_gather,
    pccl_all_reduce,
    pccl_all_to_all,
    pccl_reduce_scatter,
    synthesize_program,
)
from repro.comms.compression import (
    ef_int8_compress,
    ef_int8_decompress,
    error_feedback_all_reduce,
    topk_compress,
    topk_decompress,
)

__all__ = [
    "BufferPlan",
    "execute_program",
    "plan_buffers",
    "CollectiveSpec",
    "pccl_all_gather",
    "pccl_all_reduce",
    "pccl_all_to_all",
    "pccl_reduce_scatter",
    "synthesize_program",
    "ef_int8_compress",
    "ef_int8_decompress",
    "error_feedback_all_reduce",
    "topk_compress",
    "topk_decompress",
]
