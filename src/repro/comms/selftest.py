"""Multi-device numerical selftest of the PCCL ppermute executor.

Run as a subprocess (it forces 8 host devices, which must happen before jax
initializes): ``python -m repro.comms.selftest``. Exit code 0 = all
collectives bit-match their jax.lax references.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comms.primitives import (  # noqa: E402
    CollectiveSpec,
    pccl_all_gather,
    pccl_all_reduce,
    pccl_all_to_all,
    pccl_reduce_scatter,
)
from repro.jaxcompat import make_mesh, shard_map  # noqa: E402
from repro.topology import line, ring, torus2d  # noqa: E402


def _mesh1d(n=8):
    return make_mesh((n,), ("x",))


def check(name, got, want, atol=1e-6):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol,
                               err_msg=name)
    print(f"  ok: {name}")


def test_all_gather_ring():
    mesh = _mesh1d()
    topo = ring(8, bidirectional=True)
    spec = CollectiveSpec("all_gather", tuple(range(8)))
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

    @jax.jit
    def run(x):
        def f(xl):
            return pccl_all_gather(xl[0], "x", topo, spec)

        return shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)

    got = run(x)  # [8 devices, 8 chunks, 4] -> every device row == full x
    want = jnp.broadcast_to(x, (8, 8, 4)).reshape(8 * 8, 4)
    check("all_gather ring8", got.reshape(-1, 4), want)


def test_all_gather_subgroup_with_forwarding():
    """Process group {0, 3, 7} on a line: chunks MUST forward through
    out-of-group devices 1, 2, 4, 5, 6 — the paper's §4.3 scenario."""
    mesh = _mesh1d()
    topo = line(8)
    group = (0, 3, 7)
    spec = CollectiveSpec("all_gather", group)
    x = jnp.arange(8 * 2, dtype=jnp.float32).reshape(8, 2)

    @jax.jit
    def run(x):
        def f(xl):
            return pccl_all_gather(xl[0], "x", topo, spec)

        return shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)

    got = np.asarray(run(x)).reshape(8, 3, 2)
    want = np.asarray(x)[list(group)]
    for dev in group:
        check(f"subgroup AG at dev {dev}", got[dev], want)


def test_all_reduce():
    mesh = _mesh1d()
    topo = ring(8, bidirectional=True)
    spec = CollectiveSpec("all_reduce", tuple(range(8)))
    x = jnp.arange(8 * 8, dtype=jnp.float32).reshape(8, 8) * 0.25

    @jax.jit
    def run(x):
        def f(xl):
            mine = pccl_all_reduce(xl[0], "x", topo, spec)
            ref = lax.psum(xl[0], "x")
            return mine[None], ref[None]

        return shard_map(f, mesh=mesh, in_specs=P("x"),
                             out_specs=(P("x"), P("x")))(x)

    mine, ref = run(x)
    check("all_reduce ring8 vs psum", mine, ref)


def test_reduce_scatter():
    mesh = _mesh1d()
    topo = ring(8, bidirectional=True)
    spec = CollectiveSpec("reduce_scatter", tuple(range(8)))
    x = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(8, 8, 3)

    @jax.jit
    def run(x):
        def f(xl):
            mine = pccl_reduce_scatter(xl[0], "x", topo, spec)
            ref = lax.psum_scatter(xl[0], "x", scatter_dimension=0, tiled=False)
            return mine[None], ref[None]

        return shard_map(f, mesh=mesh, in_specs=P("x"),
                             out_specs=(P("x"), P("x")))(x)

    mine, ref = run(x)
    check("reduce_scatter ring8 vs psum_scatter", mine, ref)


def test_all_to_all_torus_rows():
    """A2A over the full 8-device group on a 2x4 torus."""
    mesh = _mesh1d()
    topo = torus2d(2, 4)
    spec = CollectiveSpec("all_to_all", tuple(range(8)))
    x = jnp.arange(8 * 8 * 2, dtype=jnp.float32).reshape(8, 8, 2)

    @jax.jit
    def run(x):
        def f(xl):
            mine = pccl_all_to_all(xl[0], "x", topo, spec)
            ref = lax.all_to_all(xl[0][:, None], "x", split_axis=0,
                                 concat_axis=0)[:, 0]
            return mine[None], ref[None]

        return shard_map(f, mesh=mesh, in_specs=P("x"),
                             out_specs=(P("x"), P("x")))(x)

    mine, ref = run(x)
    check("all_to_all torus2x4 vs lax.all_to_all", mine, ref)


def test_all_to_all_subgroup():
    """A2A among process group {0,2,5} of a line-8: PG-aware forwarding."""
    mesh = _mesh1d()
    topo = line(8)
    group = (0, 2, 5)
    spec = CollectiveSpec("all_to_all", group)
    x = jnp.arange(8 * 3 * 2, dtype=jnp.float32).reshape(8, 3, 2)

    @jax.jit
    def run(x):
        def f(xl):
            return pccl_all_to_all(xl[0], "x", topo, spec)[None]

        return shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)

    got = np.asarray(run(x))
    xs = np.asarray(x)
    for i, dev in enumerate(group):
        want = np.stack([xs[src, i] for src in group])
        want[i] = xs[dev, i]
        check(f"subgroup A2A at dev {dev}", got[dev], want)


def test_two_axis_flattened():
    """Executor over a flattened ('r','c') mesh — the full-pod execution mode."""
    mesh = make_mesh((2, 4), ("r", "c"))
    topo = torus2d(2, 4)
    spec = CollectiveSpec("all_gather", tuple(range(8)))
    x = jnp.arange(8 * 2, dtype=jnp.float32).reshape(8, 2)

    @jax.jit
    def run(x):
        def f(xl):
            return pccl_all_gather(xl[0], ("r", "c"), topo, spec)[None]

        return shard_map(f, mesh=mesh, in_specs=P(("r", "c")),
                             out_specs=P(("r", "c")))(x)

    got = np.asarray(run(x)).reshape(8, 8, 2)
    for dev in range(8):
        check(f"flattened-axes AG dev {dev}", got[dev], np.asarray(x))


def main():
    tests = [
        test_all_gather_ring,
        test_all_gather_subgroup_with_forwarding,
        test_all_reduce,
        test_reduce_scatter,
        test_all_to_all_torus_rows,
        test_all_to_all_subgroup,
        test_two_axis_flattened,
    ]
    for t in tests:
        print(f"[selftest] {t.__name__}")
        t()
    print("[selftest] ALL PASS")


if __name__ == "__main__":
    main()
