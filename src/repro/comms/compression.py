"""Gradient compression for the cross-pod data-parallel axis.

The pod axis crosses the DCI (slow, high-latency) fabric, so the framework
offers error-feedback compressed all-reduce there:

* ``ef_int8``: per-tensor symmetric int8 quantization with an error-feedback
  residual (the quantization error is carried into the next step), which
  keeps SGD/Adam convergence unbiased in the long run.
* ``topk``: magnitude top-k sparsification with error feedback.

Both are pure functions over pytrees so they compose with any optimizer and
are trivially jit/pjit-able.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ef_int8_compress(g: jax.Array, residual: jax.Array):
    """Returns (int8 payload, scale, new_residual). residual has g's shape."""
    acc = g + residual
    scale = jnp.maximum(jnp.max(jnp.abs(acc)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
    new_residual = acc - q.astype(acc.dtype) * scale
    return q, scale, new_residual


def ef_int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale


def topk_compress(g: jax.Array, residual: jax.Array, k: int):
    """Keep the k largest-|.| entries (flattened); rest go to the residual.
    Returns (values[k], indices[k], new_residual)."""
    acc = (g + residual).reshape(-1)
    _, idx = lax.top_k(jnp.abs(acc), k)
    vals = acc[idx]
    kept = jnp.zeros_like(acc).at[idx].set(vals)
    new_residual = (acc - kept).reshape(g.shape)
    return vals, idx, new_residual


def topk_decompress(vals: jax.Array, idx: jax.Array, shape, dtype=jnp.float32):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), dtype).at[idx].set(vals)
    return flat.reshape(shape)


def error_feedback_all_reduce(
    grads, residuals, axis_name, *, method: str = "int8"
):
    """Compressed psum over `axis_name` (call inside shard_map/pjit with the
    pod axis): quantize locally, mean-reduce the dequantized payloads, return
    (reduced_grads, new_residuals)."""
    if method != "int8":
        raise NotImplementedError(method)

    def one(g, r):
        q, scale, new_r = ef_int8_compress(g, r)
        # the int8 payload crosses the wire; the reduce happens on the
        # dequantized values (bit-exact across devices since scale rides along)
        deq = ef_int8_decompress(q, scale, g.dtype)
        summed = lax.psum(deq, axis_name)
        n = lax.psum(jnp.ones((), g.dtype), axis_name)
        return summed / n, new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out, res = zip(*[one(g, r) for g, r in zip(flat_g, flat_r)])
    return jax.tree.unflatten(tree, out), jax.tree.unflatten(tree, res)
