"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Implements the chunked SSD algorithm: the sequence is split into chunks of
length Q; within a chunk the recurrence is evaluated in its "dual" quadratic
attention-like form (MXU-friendly), and chunk-boundary states are carried by
an O(T/Q) scan. This is the TPU-native adaptation of the CUDA scan kernels:
the quadratic intra-chunk part maps onto the MXU, the inter-chunk scan is a
cheap `lax.scan` (or the Pallas kernel in repro/kernels for the fused path).

Projections are kept as separate weight matrices (z/x/B/C/dt) rather than one
fused in_proj so tensor parallelism can shard the head-parallel pieces
(z, x, dt, A, D — all per-head) on the "model" mesh axis while the
group-shared B/C projections stay replicated. SSD is embarrassingly parallel
across heads, so TP needs no collectives inside the scan itself.

Decode maintains the recurrent state [H, P, N] directly: O(1) per token,
which is why the SSM archs run the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import Params, _init


def ssd_init(key, d_model: int, *, expand: int = 2, head_dim: int = 64,
             state: int = 128, conv_width: int = 4) -> Params:
    d_inner = expand * d_model
    heads = d_inner // head_dim
    kz, kx, kB, kC, kdt, kconvx, kconvB, kconvC, kout = jax.random.split(key, 9)
    return {
        "w_z": _init(kz, (d_model, d_inner)),
        "w_x": _init(kx, (d_model, d_inner)),
        "w_B": _init(kB, (d_model, state)),
        "w_C": _init(kC, (d_model, state)),
        "w_dt": _init(kdt, (d_model, heads)),
        "conv_x": _init(kconvx, (conv_width, d_inner), scale=0.5),
        "conv_B": _init(kconvB, (conv_width, state), scale=0.5),
        "conv_C": _init(kconvC, (conv_width, state), scale=0.5),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "A_log": jnp.zeros((heads,), jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "out_proj": _init(kout, (d_inner, d_model)),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return out


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, policy=None):
    """Chunked SSD scan.

    xh: [B, S, H, P] inputs; dt: [B, S, H] step sizes (post softplus);
    A: [H] negative decay rates; Bm/Cm: [B, S, N] (single group, broadcast
    over heads). Returns [B, S, H, P].
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by ssd chunk {Q}"
    nc = S // Q

    # per-step log decay: dA = dt * A  (A < 0)
    dA = dt * A[None, None, :]  # [B, S, H]
    x_ = (xh * dt[..., None]).reshape(Bsz, nc, Q, H, P)
    dA = dA.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    csum = jnp.cumsum(dA, axis=2)  # [B, nc, Q, H]
    total = csum[:, :, -1, :]  # [B, nc, H] chunk total decay

    # ---- intra-chunk (dual quadratic form) ----
    # L[i, j] = exp(csum_i - csum_j) for i >= j
    diff = csum[:, :, :, None, :] - csum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [B,nc,Q,Q]
    intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, x_)

    # ---- chunk-boundary states ----
    # state contribution of chunk c: sum_j exp(total - csum_j) * B_j x_j
    decay_to_end = jnp.exp(total[:, :, None, :] - csum)  # [B,nc,Q,H]
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, x_)

    def _pin(t):
        """Keep the inter-chunk state carry batch/head-sharded inside the
        while body (same GSPMD-replication hazard as blockwise attention);
        non-batch dims stay UNCONSTRAINED so TP head sharding survives."""
        if policy is None or policy.dp is None:
            return t
        from jax.sharding import PartitionSpec as Pspec

        u = Pspec.UNCONSTRAINED
        h_ax = policy.tp if t.shape[1] % max(policy.tp_size, 1) == 0 else u
        return policy.constrain(
            t, Pspec(policy.dp, h_ax, *([u] * (t.ndim - 2))))

    def step(carry, inp):
        state_prev = carry  # [B, H, P, N]
        tot, st = inp  # [B,H], [B,H,P,N]
        new = state_prev * jnp.exp(tot)[..., None, None] + st
        return _pin(new), state_prev  # emit the state *entering* the chunk

    init = _pin(jnp.zeros((Bsz, H, P, N), xh.dtype))
    _, states_in = lax.scan(
        step,
        init,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)),
    )
    states_in = jnp.moveaxis(states_in, 0, 1)  # [B, nc, H, P, N]

    # ---- inter-chunk contribution: C_i · (decay_i * state_in) ----
    decay_from_start = jnp.exp(csum)  # [B,nc,Q,H]
    inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", Cc, decay_from_start, states_in
    )
    y = (intra + inter).reshape(Bsz, S, H, P)
    return y


def ssd_block(
    p: Params,
    x: jax.Array,  # [B, S, d_model]
    *,
    head_dim: int,
    state: int,
    chunk: int,
    conv_width: int = 4,
    use_kernel: bool = False,
    policy=None,
) -> jax.Array:
    B, S, d_model = x.shape
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim

    z = x @ p["w_z"].astype(x.dtype)
    xin = jax.nn.silu(_causal_conv(x @ p["w_x"].astype(x.dtype), p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(x @ p["w_B"].astype(x.dtype), p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(x @ p["w_C"].astype(x.dtype), p["conv_C"]))
    dt_raw = x @ p["w_dt"].astype(x.dtype)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H] negative
    xh = xin.reshape(B, S, H, head_dim)
    if use_kernel:
        from repro.kernels import ops as kops

        y = kops.ssd_scan(xh.astype(jnp.float32), dt, A,
                          Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                          chunk=chunk)
    else:
        y = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk,
                         policy=policy)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Recurrent decode: O(1) per token
# ---------------------------------------------------------------------------

def init_ssm_cache(batch: int, d_inner: int, head_dim: int, state: int,
                   conv_width: int, dtype=jnp.float32):
    H = d_inner // head_dim
    return {
        "state": jnp.zeros((batch, H, head_dim, state), dtype),
        "conv_x": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, conv_width - 1, state), dtype),
        "conv_C": jnp.zeros((batch, conv_width - 1, state), dtype),
    }


def _conv_step(cache_win: jax.Array, new: jax.Array, w: jax.Array):
    """cache_win: [B, K-1, C]; new: [B, C]; w: [K, C] -> (out [B,C], new win)."""
    win = jnp.concatenate([cache_win, new[:, None, :].astype(cache_win.dtype)],
                          axis=1)
    out = (win * w[None].astype(win.dtype)).sum(1)
    return out, win[:, 1:, :]


def ssd_decode_step(
    p: Params,
    x: jax.Array,  # [B, 1, d_model]
    cache: Params,
    *,
    head_dim: int,
    state: int,
):
    B = x.shape[0]
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim

    xt = x[:, 0]
    z = xt @ p["w_z"].astype(x.dtype)
    cx, new_conv_x = _conv_step(cache["conv_x"], xt @ p["w_x"].astype(x.dtype),
                                p["conv_x"])
    cB, new_conv_B = _conv_step(cache["conv_B"], xt @ p["w_B"].astype(x.dtype),
                                p["conv_B"])
    cC, new_conv_C = _conv_step(cache["conv_C"], xt @ p["w_C"].astype(x.dtype),
                                p["conv_C"])
    xin = jax.nn.silu(cx)
    Bm = jax.nn.silu(cB)
    Cm = jax.nn.silu(cC)
    dt_raw = xt @ p["w_dt"].astype(x.dtype)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # [B,H]
    xh = xin.reshape(B, H, head_dim).astype(jnp.float32)
    dBx = jnp.einsum("bn,bhp->bhpn", Bm.astype(jnp.float32),
                     xh * dt[..., None])
    new_state = cache["state"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    new_cache = {"state": new_state, "conv_x": new_conv_x,
                 "conv_B": new_conv_B, "conv_C": new_conv_C}
    return out, new_cache
