from repro.models.transformer import LM

__all__ = ["LM"]
