"""Mixture-of-Experts layer with top-k routing and capacity-based dispatch.

Expert parallelism shards the expert dimension over the "model" mesh axis;
the dense dispatch/combine einsums then lower to all-to-all collectives under
GSPMD — the exact pattern the paper targets (§3.3: All-to-All dominates
MoE workloads). The framework can execute that all-to-all either with XLA's
stock algorithm or with a PCCL-synthesized schedule (see repro/comms).

Experts whose count does not divide the EP degree are padded (granite-3b:
40 -> 48); padded experts get -inf router logits so no token ever routes to
them, and their weights stay zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _init


def moe_init(key, d: int, d_ff: int, num_experts: int,
             num_experts_padded: int | None = None) -> Params:
    e_pad = num_experts_padded or num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    p = {
        "router": _init(kr, (d, num_experts)),
        "gate": _init(kg, (e_pad, d, d_ff)),
        "up": _init(ku, (e_pad, d, d_ff)),
        "down": _init(kd, (e_pad, d_ff, d)),
    }
    if e_pad > num_experts:
        # zero the padded experts' weights (never routed to, but keep clean)
        for name in ("gate", "up", "down"):
            p[name] = p[name].at[num_experts:].set(0.0)
    return p


def moe_ffn(
    p: Params,
    x: jax.Array,  # [B, S, d]
    *,
    num_experts: int,
    experts_per_token: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
    policy=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux load-balancing loss).

    GShard-style grouped capacity dispatch: tokens are partitioned into
    groups of `group_size`; each group routes its tokens independently with
    per-group expert capacity C = cf * k * group_size / num_experts. The
    dispatch tensor is [G, S_g, E, C] — linear in total tokens (a global
    capacity would make it quadratic: measured 896 GiB/device on
    granite-3b prefill_32k before grouping, ~3 GiB after). Overflow tokens
    fall through to the residual connection.
    """
    B, S, d = x.shape
    E_pad = p["gate"].shape[0]
    T = B * S
    k = experts_per_token
    sg = min(group_size, T)
    if T % sg:
        sg = S if T % S == 0 else T
    G = T // sg
    xt = x.reshape(G, sg, d)

    logits = (xt.astype(jnp.float32)) @ p["router"].astype(jnp.float32)
    if E_pad > num_experts:
        pad = jnp.full((G, sg, E_pad - num_experts), -jnp.inf, logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)  # [G, S_g, E_pad]

    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [G, S_g, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )  # renormalize top-k

    C = max(1, int(capacity_factor * sg * k / max(num_experts, 1)))
    C = min(C, sg)

    # position of each (token, k) within its (group, expert) queue
    onehot = jax.nn.one_hot(expert_idx, E_pad, dtype=jnp.int32)  # [G,S,k,E]
    flat = onehot.reshape(G, sg * k, E_pad)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, sg, k, E_pad)
    pos = (pos_in_expert * onehot).sum(-1)  # [G, S_g, k]
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine [G, S_g, E, C]
    cap_onehot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                dtype=x.dtype)[..., :C]  # overflow -> dropped
    dispatch = jnp.einsum("gske,gskc->gsec", onehot.astype(x.dtype), cap_onehot)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals.astype(x.dtype),
                         onehot.astype(x.dtype), cap_onehot)

    # expert inputs [E, G, C, d] — sharded on E, these einsums lower to the
    # all-to-all pattern the paper targets (§3.3)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xt)
    g_ = jnp.einsum("egcd,edf->egcf", expert_in, p["gate"].astype(x.dtype))
    u = jnp.einsum("egcd,edf->egcf", expert_in, p["up"].astype(x.dtype))
    expert_out = jnp.einsum(
        "egcf,efd->egcd", jax.nn.silu(g_) * u, p["down"].astype(x.dtype)
    )
    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)

    # Switch-style aux loss: fraction routed vs mean router prob, real experts
    me = probs[..., :num_experts].mean((0, 1))
    ce = (onehot[..., :num_experts].sum(2).astype(jnp.float32)).mean((0, 1))
    aux = num_experts * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux
