"""GQA attention: training (full/causal/sliding-window) and decode (KV cache).

The jnp paths below are the reference implementations; on TPU the training
path dispatches to the Pallas flash-attention kernel
(`repro.kernels.ops.flash_attention`) when enabled. Decode attention is
written so that sharding the KV cache's *sequence* dimension across the
"model" mesh axis yields flash-decoding-style parallelism under GSPMD (the
softmax statistics and the PV products reduce over the sharded axis with
XLA-inserted collectives) — this sidesteps KV-head divisibility limits of
head-sharded decode entirely.
"""

from __future__ import annotations

import math
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.jaxcompat import shard_map
from repro.models.layers import Params, _init, apply_rope, rope_tables


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _init(kq, (d_model, num_heads * head_dim)),
        "wk": _init(kk, (d_model, num_kv_heads * head_dim)),
        "wv": _init(kv, (d_model, num_kv_heads * head_dim)),
        "wo": _init(ko, (num_heads * head_dim, d_model)),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def gqa_scores_mask(seq_q: int, seq_k: int, *, causal: bool,
                    window: int = 0, offset: int = 0):
    """[seq_q, seq_k] additive mask. `offset` = absolute position of query 0
    (so decode can reuse it). window > 0 = sliding-window attention."""
    qpos = jnp.arange(seq_q) + offset
    kpos = jnp.arange(seq_k)
    ok = jnp.ones((seq_q, seq_k), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attend(q, k, v, mask, *, softcap: float = 0.0):
    """q: [B,S,H,hd], k/v: [B,T,KV,hd]; returns [B,S,H,hd]. GQA via head
    grouping; softmax in f32."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    qg = q.reshape(B, S, KV, group, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + mask  # mask broadcasts [S,T]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, hd)


def _largest_divisor(n: int, cap: int) -> int:
    """Largest d <= cap with n % d == 0 (>= 1)."""
    d = min(cap, n)
    while n % d:
        d -= 1
    return max(d, 1)


def _block_geometry(S, T, window, block_q, block_kv):
    # the block must divide the sequence; prefer the largest divisor <= the
    # requested block so odd lengths degrade to smaller tiles, NEVER to one
    # full-sequence tile (which would materialize dense S x T scores —
    # measured 117 GiB/device on llava prefill before this guard)
    bq = _largest_divisor(S, block_q)
    nq = S // bq
    ctx = min(T, window + bq) if window > 0 else T
    bkv = _largest_divisor(ctx, block_kv)
    nkv = ctx // bkv
    return bq, nq, ctx, bkv, nkv


def _mask_block(qpos, kpos, causal, window):
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > qpos[:, None] - window
    return ok


def _pin_batch(t, policy):
    """Pin scan-carry batch sharding: without the constraint GSPMD may
    replicate accumulators inside while bodies, inflating per-device temp
    memory by the DP degree. Non-batch dims stay UNCONSTRAINED — pinning
    them to None would *replicate* them and strip the TP head sharding
    (measured 104 GiB/device on a 1-layer llava train step with None)."""
    if policy is None or policy.dp is None:
        return t
    from jax.sharding import PartitionSpec as P

    u = P.UNCONSTRAINED
    return policy.constrain(t, P(policy.dp, *([u] * (t.ndim - 1))))


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 512,
                        block_kv: int = 1024, policy=None, offset=None):
    """Keyword-friendly wrapper over the custom-VJP flash core. `offset` is
    the global position of q's first row (sequence-parallel attention passes
    the device's seq-shard origin)."""
    if offset is None:
        offset = jnp.zeros((), jnp.int32)
    return _flash_core(q, k, v, offset, causal, window, softcap, block_q,
                       block_kv, policy)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash_core(q, k, v, offset, causal: bool = True, window: int = 0,
                softcap: float = 0.0, block_q: int = 512,
                block_kv: int = 1024, policy=None):
    """Flash attention in pure jnp with a flash-style custom VJP.

    Forward: online-softmax over (block_q x block_kv) tiles — memory is one
    tile per head group instead of the full S x T matrix. Backward: probs are
    RECOMPUTED per tile (never stored), carrying O(T) dk/dv accumulators —
    naive autodiff through the tiled scan would otherwise stash every tile's
    probs and rebuild the full quadratic matrix (measured 69 GiB/device on
    llama3.2-1b train_4k; this path: ~4 GiB).

    This is the portable reference twin of the Pallas kernel
    (repro/kernels/flash_attention.py). Sliding-window attention slices
    exactly the window's KV (traced start, static size) so SWA costs
    O(S*window); the causal path masks at tile granularity (true tile
    skipping happens in the Pallas kernel — roofline accounting corrects
    analytically).
    """
    out, _ = _flash_fwd(q, k, v, offset, causal, window, softcap, block_q,
                        block_kv, policy)
    return out


def _flash_fwd(q, k, v, offset, causal, window, softcap, block_q, block_kv,
               policy):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    bq, nq, ctx, bkv, nkv = _block_geometry(S, T, window, block_q, block_kv)
    scale = 1.0 / math.sqrt(hd)
    group = H // KV
    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)
    # pe-poison (see _flash_bwd_vjp): under remat-in-scan the forward is
    # recomputed inside the backward, and its primal-independent tile masks
    # would be hoisted + stacked; tie positions to the primal to prevent it
    zero = (q.ravel()[0] * 0).astype(jnp.int32) + offset

    def q_block(args):
        i, qi = args
        qpos = i * bq + jnp.arange(bq) + zero
        start = jnp.maximum(offset + i * bq + bq - ctx, 0) if window > 0 else 0
        ks = lax.dynamic_slice(k, (0, start, 0, 0), (B, ctx, KV, hd))
        vs = lax.dynamic_slice(v, (0, start, 0, 0), (B, ctx, KV, hd))
        qg = qi.reshape(B, bq, KV, group, hd)

        def kv_block(carry, j):
            m, l, acc = carry
            kj = lax.dynamic_slice(ks, (0, j * bkv, 0, 0), (B, bkv, KV, hd))
            vj = lax.dynamic_slice(vs, (0, j * bkv, 0, 0), (B, bkv, KV, hd))
            s = jnp.einsum("bqkgh,btkh->bkgqt", qg, kj).astype(jnp.float32)
            s = s * scale
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            kpos = start + j * bkv + jnp.arange(bkv)
            ok = _mask_block(qpos, kpos, causal, window)
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return tuple(_pin_batch(t, policy)
                         for t in (m_new, l_new, acc_new)), None

        # zf: primal-derived zero — keeps the carries pe-"unknown" AND, under
        # shard_map, marks them varying on the manual axes (vma typing)
        zf = zero.astype(jnp.float32) * 0.0
        m0 = _pin_batch(
            jnp.full((B, KV, group, bq), -jnp.inf, jnp.float32) + zf, policy)
        l0 = _pin_batch(jnp.zeros((B, KV, group, bq), jnp.float32) + zf,
                        policy)
        a0 = _pin_batch(jnp.zeros((B, KV, group, bq, hd), jnp.float32) + zf,
                        policy)
        (m, l, acc), _ = lax.scan(kv_block, (m0, l0, a0), jnp.arange(nkv))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]
        lse_i = jnp.where(jnp.isinf(m), -jnp.inf,
                          m + jnp.log(jnp.maximum(l, 1e-30)))
        out_i = jnp.moveaxis(out_i, 3, 1).reshape(B, bq, H, hd).astype(q.dtype)
        return _pin_batch(out_i, policy), _pin_batch(lse_i, policy)

    outs, lses = lax.map(q_block, (jnp.arange(nq), qb))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    return out, lses  # lses: [nq, B, KV, G, bq]


def _flash_fwd_vjp(q, k, v, offset, causal, window, softcap, block_q,
                   block_kv, policy):
    out, lse = _flash_fwd(q, k, v, offset, causal, window, softcap, block_q,
                          block_kv, policy)
    return out, (q, k, v, offset, out, lse)


def _flash_bwd_vjp(causal, window, softcap, block_q, block_kv, policy,
                   res, dout):
    q, k, v, offset, out, lse = res
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    bq, nq, ctx, bkv, nkv = _block_geometry(S, T, window, block_q, block_kv)
    scale = 1.0 / math.sqrt(hd)
    group = H // KV

    # Partial-eval poison: scan AD hoists primal-independent intermediates
    # (the iota-derived tile masks below) out of the backward pass and STACKS
    # them as per-tile residuals — a [nq, nkv, B, KV, G, bq, bkv] bool array
    # (64 GiB/device on llava train_4k). Tying the position bases to a
    # primal value keeps the masks "unknown", so they are recomputed tile-by-
    # tile inside the backward loops instead of being saved.
    zero = (jnp.min(lse) * 0.0).astype(jnp.int32) + offset

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)
    dob = jnp.moveaxis(dout.reshape(B, nq, bq, H, hd), 1, 0)
    ob = jnp.moveaxis(out.reshape(B, nq, bq, H, hd), 1, 0)

    zf = zero.astype(jnp.float32) * 0.0
    dk0 = _pin_batch(jnp.zeros((B, T, KV, hd), jnp.float32) + zf, policy)
    dv0 = _pin_batch(jnp.zeros((B, T, KV, hd), jnp.float32) + zf, policy)

    def q_block(carry, args):
        dk_acc, dv_acc = carry
        i, qi, doi, oi, lse_i = args
        qpos = i * bq + jnp.arange(bq) + zero
        start = (jnp.maximum(zero + i * bq + bq - ctx, 0)
                 if window > 0 else 0)
        qg = qi.reshape(B, bq, KV, group, hd)
        dog = doi.reshape(B, bq, KV, group, hd)
        og = oi.reshape(B, bq, KV, group, hd)
        # D_i = rowsum(dout * out)  [B,KV,G,bq]
        Di = jnp.einsum("bqkgh,bqkgh->bkgq", dog.astype(jnp.float32),
                        og.astype(jnp.float32))
        lse_safe = jnp.where(jnp.isinf(lse_i), 0.0, lse_i)

        def kv_block(carry2, j):
            dq_i, dk_acc, dv_acc = carry2
            kj = lax.dynamic_slice(k, (0, start + j * bkv, 0, 0),
                                   (B, bkv, KV, hd))
            vj = lax.dynamic_slice(v, (0, start + j * bkv, 0, 0),
                                   (B, bkv, KV, hd))
            s_pre = jnp.einsum("bqkgh,btkh->bkgqt", qg, kj).astype(jnp.float32)
            s_pre = s_pre * scale
            if softcap > 0.0:
                tanh_s = jnp.tanh(s_pre / softcap)
                s = softcap * tanh_s
            else:
                s = s_pre
            kpos = start + j * bkv + jnp.arange(bkv)
            ok = _mask_block(qpos, kpos, causal, window)
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            p = jnp.exp(s - lse_safe[..., None])  # [B,KV,G,bq,t]
            p = jnp.where(jnp.isinf(lse_i)[..., None], 0.0, p)
            # dv_j += p^T dout_i (sum over q and group)
            dv_j = jnp.einsum("bkgqt,bqkgh->btkh", p,
                              dog.astype(jnp.float32))
            dp = jnp.einsum("bqkgh,btkh->bkgqt", dog,
                            vj).astype(jnp.float32)
            ds = p * (dp - Di[..., None])
            if softcap > 0.0:
                ds = ds * (1.0 - tanh_s * tanh_s)
            ds = ds * scale
            dq_i = dq_i + jnp.einsum("bkgqt,btkh->bqkgh", ds, kj)
            dk_j = jnp.einsum("bkgqt,bqkgh->btkh", ds, qg)
            dk_acc = lax.dynamic_update_slice(
                dk_acc,
                lax.dynamic_slice(dk_acc, (0, start + j * bkv, 0, 0),
                                  (B, bkv, KV, hd)) + dk_j,
                (0, start + j * bkv, 0, 0))
            dv_acc = lax.dynamic_update_slice(
                dv_acc,
                lax.dynamic_slice(dv_acc, (0, start + j * bkv, 0, 0),
                                  (B, bkv, KV, hd)) + dv_j,
                (0, start + j * bkv, 0, 0))
            return (_pin_batch(dq_i, policy), _pin_batch(dk_acc, policy),
                    _pin_batch(dv_acc, policy)), None

        dq0 = _pin_batch(jnp.zeros((B, bq, KV, group, hd), jnp.float32) + zf,
                         policy)
        (dq_i, dk_acc, dv_acc), _ = lax.scan(
            kv_block, (dq0, dk_acc, dv_acc), jnp.arange(nkv))
        return (dk_acc, dv_acc), _pin_batch(dq_i, policy)

    (dk, dv), dqs = lax.scan(
        q_block, (dk0, dv0), (jnp.arange(nq), qb, dob, ob, lse))
    # dqs: [nq, B, bq, KV, G, hd] -> [B, S, H, hd]
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, H, hd).astype(q.dtype)
    d_offset = np.zeros((), jax.dtypes.float0)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), d_offset


_flash_core.defvjp(_flash_fwd_vjp, _flash_bwd_vjp)


def _seq_parallel_attention(q, k, v, policy, *, causal, window, softcap):
    """Sequence-parallel flash attention via shard_map: queries stay
    seq-sharded on the TP axis; K/V are all-gathered ONCE per layer inside
    the shard (GQA keeps them small). Replaces GSPMD's per-tile resharding
    of the scan-tiled attention, which re-gathered K/V for EVERY
    (q-tile x kv-tile) pair — 11.7 TB/device/step of all-gather on
    llava-next-34b train_4k (§Perf iteration 1)."""
    from jax.sharding import PartitionSpec as P

    tp, dp = policy.tp, policy.dp
    S_loc = q.shape[1] // policy.tp_size

    def local(q_l, k_l, v_l):
        k_f = lax.all_gather(k_l, tp, axis=1, tiled=True)
        v_f = lax.all_gather(v_l, tp, axis=1, tiled=True)
        off = (lax.axis_index(tp) * S_loc).astype(jnp.int32)
        return blockwise_attention(q_l, k_f, v_f, causal=causal,
                                   window=window, softcap=softcap,
                                   policy=None, offset=off)

    spec = P(dp, tp, None, None)
    return shard_map(local, mesh=policy.mesh, in_specs=(spec,) * 3,
                     out_specs=spec)(q, k, v)


def attention_train(
    p: Params,
    x: jax.Array,  # [B, S, d]
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    rotary_pct: float = 1.0,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    positions: jax.Array | None = None,
    use_flash: bool = False,
    policy=None,
) -> jax.Array:
    B, S, _ = x.shape
    q = _split_heads(x @ p["wq"].astype(x.dtype), num_heads, head_dim)
    k = _split_heads(x @ p["wk"].astype(x.dtype), num_kv_heads, head_dim)
    v = _split_heads(x @ p["wv"].astype(x.dtype), num_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(S)
    cos, sin, rot = rope_tables(positions, head_dim, rope_theta, rotary_pct)
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)
    seq_parallel_ok = (
        policy is not None and policy.tp is not None and policy.tp_size > 1
        and S % policy.tp_size == 0
        and (S // policy.tp_size) % 8 == 0
        and B % policy.dp_size == 0
    )
    if use_flash:
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    elif seq_parallel_ok:
        out = _seq_parallel_attention(q, k, v, policy, causal=causal,
                                      window=window, softcap=softcap)
    elif S > 2048:
        # memory-bounded path for long contexts (32k prefill shapes)
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap, policy=policy)
    else:
        mask = gqa_scores_mask(S, S, causal=causal, window=window)
        out = attend(q, k, v, mask, softcap=softcap)
    return out.reshape(B, S, num_heads * head_dim) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_seq: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    shape = (batch, max_seq, num_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(
    p: Params,
    x: jax.Array,  # [B, 1, d] current-token activations
    cache: Params,  # {"k","v"}: [B, T, KV, hd]
    pos: jax.Array,  # [] current absolute position (same for the batch)
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    rotary_pct: float = 1.0,
    window: int = 0,
    softcap: float = 0.0,
):
    """One decode step. Returns (out [B,1,d], new cache). With window > 0 the
    cache is a ring buffer of size `window` (positions wrap)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    q = _split_heads(x @ p["wq"].astype(x.dtype), num_heads, head_dim)
    k = _split_heads(x @ p["wk"].astype(x.dtype), num_kv_heads, head_dim)
    v = _split_heads(x @ p["wv"].astype(x.dtype), num_kv_heads, head_dim)
    posv = jnp.full((1,), pos)
    cos, sin, rot = rope_tables(posv, head_dim, rope_theta, rotary_pct)
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)
    slot = (pos % T) if window > 0 else pos  # ring buffer under SWA
    ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                  (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                  (0, slot, 0, 0))
    # validity of cache positions: either absolute (no window) or ring-buffer
    kpos = jnp.arange(T)
    if window > 0:
        valid = (kpos <= pos % T) | (pos >= T)  # ring full -> everything valid
    else:
        valid = kpos <= pos
    mask = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)[None, :]
    out = attend(q, ck, cv, mask, softcap=softcap).astype(x.dtype)
    out = out.reshape(B, 1, num_heads * head_dim) @ p["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder) — no cache mutation, encoder KV is static
# ---------------------------------------------------------------------------

def cross_attention(
    p: Params,
    x: jax.Array,  # [B, S, d] decoder activations
    enc_kv: tuple[jax.Array, jax.Array],  # ([B,T,KV,hd], [B,T,KV,hd])
    *,
    num_heads: int,
    head_dim: int,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, _ = x.shape
    q = _split_heads(x @ p["wq"].astype(x.dtype), num_heads, head_dim)
    k, v = enc_kv
    T = k.shape[1]
    mask = jnp.zeros((S, T), jnp.float32)
    out = attend(q, k, v, mask, softcap=softcap)
    return out.reshape(B, S, num_heads * head_dim) @ p["wo"].astype(x.dtype)


def encode_cross_kv(p: Params, enc_out: jax.Array, *, num_kv_heads: int,
                    head_dim: int):
    k = _split_heads(enc_out @ p["wk"].astype(enc_out.dtype), num_kv_heads, head_dim)
    v = _split_heads(enc_out @ p["wv"].astype(enc_out.dtype), num_kv_heads, head_dim)
    return k, v
