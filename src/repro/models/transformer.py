"""Transformer assembly for every assigned architecture family.

One functional `LM` facade per config:

  * init(rng)                      -> params (stacked-layer pytree)
  * loss(params, batch)            -> (scalar loss, metrics)
  * decode_init(batch, max_seq)    -> KV/SSM caches
  * decode_step(params, cache, tokens, pos) -> (logits, cache)

Layer stacks keep a leading [L, ...] axis and the body is one lax.scan, so
HLO size (and 512-device compile time) is depth-independent.

Families:
  dense    — GQA + SwiGLU (llama3.2, chatglm3, internlm2, h2o-danube w/ SWA)
  moe      — GQA + top-k MoE FFN (granite couple)
  ssm      — Mamba2/SSD stack (mamba2-370m), attention-free
  hybrid   — Mamba2 stack + one shared attention block every K layers (zamba2)
  encdec   — whisper-medium: bidirectional encoder (audio-stub) + causal
             decoder with cross attention
  vlm      — llava-next: decoder LM consuming [patch-stub ++ token] embeddings
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    embed,
    embedding_init,
    linear_init,
    rms_norm,
    rms_norm_init,
    softmax_xent,
    swiglu,
    swiglu_init,
    unembed,
    unembed_separate,
)


def _stack_layers(key, n: int, init_one):
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


class LM:
    def __init__(self, cfg: ModelConfig, *, ep_degree: int = 1,
                 use_flash: bool = False, policy=None, remat: bool = False):
        """`policy` (launch.sharding.ShardingPolicy) adds Megatron-style
        sequence-parallel constraints on the residual stream; `remat`
        rematerializes each block in the backward pass."""
        self.cfg = cfg
        self.ep_degree = ep_degree
        self.use_flash = use_flash
        self.policy = policy
        self.remat = remat
        self.e_pad = cfg.padded_experts(ep_degree) if cfg.is_moe else 0

    def _constrain_seq(self, h):
        pol = self.policy
        if pol is None or pol.tp is None or pol.tp_size <= 1:
            return h
        if h.ndim != 3 or h.shape[1] % pol.tp_size:
            return h
        return pol.constrain(h, pol.seq_spec)

    def _maybe_remat(self, fn):
        # prevent_cse=False: we only remat inside lax.scan, which already
        # isolates iterations — the default CSE-prevention barriers force an
        # extra f32 copy of the residual stream to be stacked per layer
        # (13 GiB/device on llava train_4k)
        return jax.checkpoint(fn, prevent_cse=False) if self.remat else fn

    def _seq_pad(self) -> int:
        """Pad unit for concatenated (patch ++ token) sequences: a multiple
        of the attention block and the TP degree keeps blockwise attention
        tiled and sequence parallelism divisible."""
        tp = self.policy.tp_size if self.policy is not None else 1
        return 512 * max(tp, 1)

    def _pad_seq(self, h):
        """Right-pad the sequence dim; tail positions only attend causally
        among themselves and are sliced off before the loss."""
        pad_to = self._seq_pad()
        S = h.shape[1]
        rem = (-S) % pad_to
        if rem == 0:
            return h, S
        return jnp.pad(h, ((0, 0), (0, rem), (0, 0))), S

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _attn_init(self, key):
        c = self.cfg
        return attn.attention_init(key, c.d_model, c.num_heads, c.num_kv_heads,
                                   c.head_dim)

    def _block_init(self, key):
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "ln1": rms_norm_init(c.d_model),
            "attn": self._attn_init(k1),
            "ln2": rms_norm_init(c.d_model),
        }
        if c.is_moe:
            p["moe"] = moe_mod.moe_init(k2, c.d_model, c.moe_d_ff,
                                        c.num_experts, self.e_pad)
        else:
            p["mlp"] = swiglu_init(k2, c.d_model, c.d_ff)
        return p

    def _mamba_block_init(self, key):
        c = self.cfg
        k1, _ = jax.random.split(key)
        return {
            "ln": rms_norm_init(c.d_model),
            "ssd": ssm_mod.ssd_init(k1, c.d_model, expand=c.ssm_expand,
                                    head_dim=c.ssm_head_dim, state=c.ssm_state,
                                    conv_width=c.ssm_conv_width),
        }

    def init(self, rng) -> Params:
        c = self.cfg
        keys = jax.random.split(rng, 8)
        params: Params = {
            "embed": embedding_init(keys[0], c.vocab_size, c.d_model),
            "final_ln": rms_norm_init(c.d_model),
        }
        if not c.tie_embeddings:
            params["unembed"] = linear_init(keys[1], c.d_model, c.vocab_size)
        if c.family in ("dense", "moe", "vlm"):
            params["layers"] = _stack_layers(keys[2], c.num_layers,
                                             self._block_init)
        elif c.family == "ssm":
            params["layers"] = _stack_layers(keys[2], c.num_layers,
                                             self._mamba_block_init)
        elif c.family == "hybrid":
            period = c.hybrid_attn_period
            groups, rem = divmod(c.num_layers, period)
            params["layers"] = _stack_layers(keys[2], groups * period,
                                             self._mamba_block_init)
            if rem:
                params["tail_layers"] = _stack_layers(keys[3], rem,
                                                      self._mamba_block_init)
            params["shared_attn"] = self._block_init(keys[4])
        elif c.family == "encdec":
            params["enc_layers"] = _stack_layers(keys[2], c.encoder_layers,
                                                 self._block_init)
            params["enc_ln"] = rms_norm_init(c.d_model)

            def dec_init(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {
                    "ln1": rms_norm_init(c.d_model),
                    "attn": self._attn_init(k1),
                    "ln_x": rms_norm_init(c.d_model),
                    "xattn": self._attn_init(k2),
                    "ln2": rms_norm_init(c.d_model),
                    "mlp": swiglu_init(k3, c.d_model, c.d_ff),
                }

            params["layers"] = _stack_layers(keys[3], c.num_layers, dec_init)
        else:
            raise ValueError(c.family)
        return params

    # ------------------------------------------------------------------
    # blocks (train)
    # ------------------------------------------------------------------
    def _attn_block(self, p, x, *, causal=True, window=None, positions=None):
        c = self.cfg
        return attn.attention_train(
            p, x,
            num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
            head_dim=c.head_dim, rope_theta=c.rope_theta,
            rotary_pct=c.rotary_pct, causal=causal,
            window=c.sliding_window if window is None else window,
            softcap=c.attn_logit_softcap, positions=positions,
            use_flash=self.use_flash, policy=self.policy,
        )

    def _block(self, p, x, *, causal=True, positions=None):
        c = self.cfg
        h = x + self._attn_block(p["attn"], rms_norm(p["ln1"], x, c.norm_eps),
                                 causal=causal, positions=positions)
        moe_aux = jnp.zeros((), jnp.float32)
        if c.is_moe:
            y, moe_aux = moe_mod.moe_ffn(
                p["moe"], rms_norm(p["ln2"], h, c.norm_eps),
                num_experts=c.num_experts,
                experts_per_token=c.experts_per_token,
                capacity_factor=c.capacity_factor,
            )
        else:
            y = swiglu(p["mlp"], rms_norm(p["ln2"], h, c.norm_eps))
        return h + y, moe_aux

    def _mamba_block(self, p, x):
        c = self.cfg
        return x + ssm_mod.ssd_block(
            p["ssd"], rms_norm(p["ln"], x, c.norm_eps),
            head_dim=c.ssm_head_dim, state=c.ssm_state, chunk=c.ssm_chunk,
            conv_width=c.ssm_conv_width, policy=self.policy,
        )

    # ------------------------------------------------------------------
    # forward (train)
    # ------------------------------------------------------------------
    def _body_dense(self, params, h, *, causal=True):
        block = self._maybe_remat(
            lambda lp, h: self._block(lp, h, causal=causal))

        def step(carry, lp):
            h, aux = carry
            h, a = block(lp, h)
            h = self._constrain_seq(h)
            return (h, aux + a), None

        h = self._constrain_seq(h)
        (h, aux), _ = lax.scan(step, (h, jnp.zeros((), jnp.float32)),
                               params["layers"])
        return h, aux

    def _body_ssm(self, params, h):
        block = self._maybe_remat(lambda lp, h: self._mamba_block(lp, h))

        def step(h, lp):
            return self._constrain_seq(block(lp, h)), None

        h, _ = lax.scan(step, self._constrain_seq(h), params["layers"])
        return h, jnp.zeros((), jnp.float32)

    def _body_hybrid(self, params, h):
        c = self.cfg
        period = c.hybrid_attn_period
        groups = c.num_layers // period
        stacked = jax.tree.map(
            lambda a: a.reshape(groups, period, *a.shape[1:]), params["layers"]
        )
        mamba = self._maybe_remat(lambda lp, h: self._mamba_block(lp, h))
        shared = self._maybe_remat(
            lambda sp, h: self._block(sp, h)[0])

        def group_step(h, glp):
            def inner(h2, lp):
                return self._constrain_seq(mamba(lp, h2)), None

            h, _ = lax.scan(inner, h, glp)
            h = self._constrain_seq(shared(params["shared_attn"], h))
            return h, None

        h, _ = lax.scan(group_step, self._constrain_seq(h), stacked)
        if "tail_layers" in params:
            def inner(h2, lp):
                return self._constrain_seq(mamba(lp, h2)), None

            h, _ = lax.scan(inner, h, params["tail_layers"])
        return h, jnp.zeros((), jnp.float32)

    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings [B, T, d]."""
        h = frames
        block = self._maybe_remat(
            lambda lp, h: self._block(lp, h, causal=False)[0])

        def step(h, lp):
            return block(lp, h), None

        h, _ = lax.scan(step, h, params["enc_layers"])
        return rms_norm(params["enc_ln"], h, self.cfg.norm_eps)

    def _body_encdec(self, params, h, enc_out):
        c = self.cfg

        def one(lp, h):
            hh = h + self._attn_block(lp["attn"],
                                      rms_norm(lp["ln1"], h, c.norm_eps))
            kv = attn.encode_cross_kv(lp["xattn"], enc_out,
                                      num_kv_heads=c.num_kv_heads,
                                      head_dim=c.head_dim)
            hh = hh + attn.cross_attention(
                lp["xattn"], rms_norm(lp["ln_x"], hh, c.norm_eps), kv,
                num_heads=c.num_heads, head_dim=c.head_dim)
            hh = hh + swiglu(lp["mlp"], rms_norm(lp["ln2"], hh, c.norm_eps))
            return hh

        block = self._maybe_remat(one)

        def step(h, lp):
            return self._constrain_seq(block(lp, h)), None

        h, _ = lax.scan(step, self._constrain_seq(h), params["layers"])
        return h, jnp.zeros((), jnp.float32)

    def _logits(self, params, h):
        c = self.cfg
        h = rms_norm(params["final_ln"], h, c.norm_eps)
        logits = (unembed(params["embed"], h) if c.tie_embeddings
                  else unembed_separate(params["unembed"], h))
        if logits.ndim == 3:
            pol = self.policy
            if pol is not None and pol.tp is not None and pol.tp_size > 1 \
                    and logits.shape[1] % pol.tp_size == 0:
                from jax.sharding import PartitionSpec as P

                logits = pol.constrain(logits, P(pol.dp, pol.tp, None))
        return logits

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        """batch: tokens [B,S], labels [B,S]; + 'frames' [B,T,d] (encdec) or
        'patches' [B,P,d] (vlm)."""
        c = self.cfg
        dtype = jnp.bfloat16 if c.dtype == "bfloat16" else jnp.float32
        h = embed(params["embed"], batch["tokens"], dtype)
        aux = jnp.zeros((), jnp.float32)
        if c.family in ("dense", "moe"):
            h, aux = self._body_dense(params, h)
        elif c.family == "vlm":
            patches = batch["patches"].astype(dtype)  # [B, P, d] stub
            npatch = patches.shape[1]
            h = jnp.concatenate([patches, h], axis=1)
            h, true_len = self._pad_seq(h)
            h, aux = self._body_dense(params, h)
            # loss masking instead of slicing h: a mid-graph seq slice forces
            # an awkward reshard under GSPMD; padded labels keep shapes static
            B = h.shape[0]
            labels = batch["labels"]
            pad_tail = h.shape[1] - true_len
            labels = jnp.concatenate(
                [jnp.full((B, npatch), -1, labels.dtype), labels,
                 jnp.full((B, pad_tail), -1, labels.dtype)], axis=1)
            logits = self._logits(params, h)
            xent = softmax_xent(logits, labels)
            loss = xent + 0.01 * aux
            return loss, {"xent": xent, "moe_aux": aux}
        elif c.family == "ssm":
            h, aux = self._body_ssm(params, h)
        elif c.family == "hybrid":
            h, aux = self._body_hybrid(params, h)
        elif c.family == "encdec":
            enc_out = self._encode(params, batch["frames"].astype(dtype))
            h, aux = self._body_encdec(params, h, enc_out)
        else:
            raise ValueError(c.family)
        logits = self._logits(params, h)
        xent = softmax_xent(logits, batch["labels"])
        loss = xent + 0.01 * aux
        return loss, {"xent": xent, "moe_aux": aux}

    def forward_logits(self, params, batch) -> jax.Array:
        """Inference prefill: full-sequence logits (same compute shape as the
        loss path, no labels). [B, S, vocab]."""
        c = self.cfg
        dtype = jnp.bfloat16 if c.dtype == "bfloat16" else jnp.float32
        h = embed(params["embed"], batch["tokens"], dtype)
        if c.family in ("dense", "moe"):
            h, _ = self._body_dense(params, h)
        elif c.family == "vlm":
            patches = batch["patches"].astype(dtype)
            h = jnp.concatenate([patches, h], axis=1)
            h, true_len = self._pad_seq(h)
            h, _ = self._body_dense(params, h)
            h = h[:, patches.shape[1]:true_len, :]
        elif c.family == "ssm":
            h, _ = self._body_ssm(params, h)
        elif c.family == "hybrid":
            h, _ = self._body_hybrid(params, h)
        elif c.family == "encdec":
            enc_out = self._encode(params, batch["frames"].astype(dtype))
            h, _ = self._body_encdec(params, h, enc_out)
        else:
            raise ValueError(c.family)
        return self._logits(params, h)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_init(self, batch_size: int, max_seq: int,
                    dtype=jnp.bfloat16) -> Params:
        c = self.cfg
        kv_len = min(max_seq, c.sliding_window) if c.sliding_window > 0 else max_seq

        def kv(n):
            return {
                "k": jnp.zeros((n, batch_size, kv_len, c.num_kv_heads,
                                c.head_dim), dtype),
                "v": jnp.zeros((n, batch_size, kv_len, c.num_kv_heads,
                                c.head_dim), dtype),
            }

        def ssm(n):
            return {
                "state": jnp.zeros((n, batch_size, c.ssm_heads, c.ssm_head_dim,
                                    c.ssm_state), jnp.float32),
                "conv_x": jnp.zeros((n, batch_size, c.ssm_conv_width - 1,
                                     c.d_inner), jnp.float32),
                "conv_B": jnp.zeros((n, batch_size, c.ssm_conv_width - 1,
                                     c.ssm_state), jnp.float32),
                "conv_C": jnp.zeros((n, batch_size, c.ssm_conv_width - 1,
                                     c.ssm_state), jnp.float32),
            }

        if c.family in ("dense", "moe", "vlm"):
            return {"kv": kv(c.num_layers)}
        if c.family == "ssm":
            return {"ssm": ssm(c.num_layers)}
        if c.family == "hybrid":
            period = c.hybrid_attn_period
            groups, rem = divmod(c.num_layers, period)
            cache = {"ssm": ssm(groups * period), "kv": kv(groups)}
            if rem:
                cache["ssm_tail"] = ssm(rem)
            return cache
        if c.family == "encdec":
            return {
                "kv": kv(c.num_layers),
                # cross-attention KV computed at prefill from encoder output
                "cross": {
                    "k": jnp.zeros((c.num_layers, batch_size, c.encoder_seq,
                                    c.num_kv_heads, c.head_dim), dtype),
                    "v": jnp.zeros((c.num_layers, batch_size, c.encoder_seq,
                                    c.num_kv_heads, c.head_dim), dtype),
                },
            }
        raise ValueError(c.family)

    def _attn_decode(self, p, x, kv_slice, pos):
        c = self.cfg
        return attn.attention_decode(
            p, x, kv_slice, pos,
            num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
            head_dim=c.head_dim, rope_theta=c.rope_theta,
            rotary_pct=c.rotary_pct, window=c.sliding_window,
            softcap=c.attn_logit_softcap,
        )

    def _block_decode(self, lp, x, kv_slice, pos):
        c = self.cfg
        h_in = rms_norm(lp["ln1"], x, c.norm_eps)
        a, new_kv = self._attn_decode(lp["attn"], h_in, kv_slice, pos)
        h = x + a
        if c.is_moe:
            y, _ = moe_mod.moe_ffn(
                lp["moe"], rms_norm(lp["ln2"], h, c.norm_eps),
                num_experts=c.num_experts,
                experts_per_token=c.experts_per_token,
                capacity_factor=c.capacity_factor,
            )
        else:
            y = swiglu(lp["mlp"], rms_norm(lp["ln2"], h, c.norm_eps))
        return h + y, new_kv

    def _mamba_decode(self, lp, x, ssm_slice):
        c = self.cfg
        y, new_cache = ssm_mod.ssd_decode_step(
            lp["ssd"], rms_norm(lp["ln"], x, c.norm_eps), ssm_slice,
            head_dim=c.ssm_head_dim, state=c.ssm_state,
        )
        return x + y, new_cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens: [B] int32; pos: [] absolute position. Returns
        (logits [B, vocab], new cache)."""
        c = self.cfg
        dtype = jnp.bfloat16 if c.dtype == "bfloat16" else jnp.float32
        x = embed(params["embed"], tokens[:, None], dtype)  # [B,1,d]

        if c.family in ("dense", "moe", "vlm"):
            def step(x, inp):
                lp, kv_slice = inp
                x, new_kv = self._block_decode(lp, x, kv_slice, pos)
                return x, new_kv

            x, new_kv = lax.scan(step, x, (params["layers"], cache["kv"]))
            cache = {"kv": new_kv}
        elif c.family == "ssm":
            def step(x, inp):
                lp, sl = inp
                x, new = self._mamba_decode(lp, x, sl)
                return x, new

            x, new_ssm = lax.scan(step, x, (params["layers"], cache["ssm"]))
            cache = {"ssm": new_ssm}
        elif c.family == "hybrid":
            period = c.hybrid_attn_period
            groups = c.num_layers // period
            stacked = jax.tree.map(
                lambda a: a.reshape(groups, period, *a.shape[1:]),
                params["layers"])
            ssm_stacked = jax.tree.map(
                lambda a: a.reshape(groups, period, *a.shape[1:]),
                cache["ssm"])

            def group_step(x, inp):
                glp, gssm, kv_slice = inp

                def inner(x2, ii):
                    lp, sl = ii
                    x2, new = self._mamba_decode(lp, x2, sl)
                    return x2, new

                x, new_ssm = lax.scan(inner, x, (glp, gssm))
                h_in = rms_norm(params["shared_attn"]["ln1"], x, c.norm_eps)
                a, new_kv = self._attn_decode(params["shared_attn"]["attn"],
                                              h_in, kv_slice, pos)
                x = x + a
                x = x + swiglu(params["shared_attn"]["mlp"],
                               rms_norm(params["shared_attn"]["ln2"], x,
                                        c.norm_eps))
                return x, (new_ssm, new_kv)

            x, (new_ssm, new_kv) = lax.scan(
                group_step, x, (stacked, ssm_stacked, cache["kv"]))
            new_cache = {
                "ssm": jax.tree.map(
                    lambda a: a.reshape(groups * period, *a.shape[2:]), new_ssm),
                "kv": new_kv,
            }
            if "ssm_tail" in cache:
                def inner(x2, ii):
                    lp, sl = ii
                    x2, new = self._mamba_decode(lp, x2, sl)
                    return x2, new

                x, new_tail = lax.scan(inner, x,
                                       (params["tail_layers"], cache["ssm_tail"]))
                new_cache["ssm_tail"] = new_tail
            cache = new_cache
        elif c.family == "encdec":
            def step(x, inp):
                lp, kv_slice, cross_k, cross_v = inp
                a, new_kv = self._attn_decode(
                    lp["attn"], rms_norm(lp["ln1"], x, c.norm_eps),
                    kv_slice, pos)
                hh = x + a
                hh = hh + attn.cross_attention(
                    lp["xattn"], rms_norm(lp["ln_x"], hh, c.norm_eps),
                    (cross_k, cross_v),
                    num_heads=c.num_heads, head_dim=c.head_dim)
                hh = hh + swiglu(lp["mlp"], rms_norm(lp["ln2"], hh, c.norm_eps))
                return hh, new_kv

            x, new_kv = lax.scan(
                step, x,
                (params["layers"], cache["kv"], cache["cross"]["k"],
                 cache["cross"]["v"]))
            cache = {"kv": new_kv, "cross": cache["cross"]}
        else:
            raise ValueError(c.family)

        logits = self._logits(params, x)[:, 0, :]
        return logits, cache
