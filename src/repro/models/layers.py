"""Shared neural-net building blocks (pure JAX, param pytrees, no framework).

Parameters live in nested dicts of jnp arrays; layer stacks keep a leading
[num_layers, ...] axis so the transformer body is one `lax.scan` — compile
time stays flat in depth, which matters for the 512-device dry-run compiles.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full or partial/"2d" rotary a la ChatGLM)
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float,
                rotary_pct: float = 1.0):
    """cos/sin tables [*, rot_dim/2] for the rotated prefix of head_dim."""
    rot_dim = int(head_dim * rotary_pct)
    rot_dim -= rot_dim % 2
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    )
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(angles), jnp.sin(angles), rot_dim


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, rot_dim: int):
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, rot_dim/2]."""
    rot, keep = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = rot[..., 0::2], rot[..., 1::2]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    rotated = jnp.stack([y1, y2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([rotated, keep], axis=-1) if keep.shape[-1] else rotated


# ---------------------------------------------------------------------------
# Dense projections / SwiGLU FFN
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int) -> Params:
    return {"w": _init(key, (d_in, d_out))}


def linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"].astype(x.dtype)


def swiglu_init(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": _init(k1, (d, d_ff)),
        "up": _init(k2, (d, d_ff)),
        "down": _init(k3, (d_ff, d)),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    g = x @ p["gate"].astype(x.dtype)
    u = x @ p["up"].astype(x.dtype)
    return (jax.nn.silu(g) * u) @ p["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int) -> Params:
    return {"table": _init(key, (vocab, d), scale=0.02)}


def embed(p: Params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    # cast the (sharded, param-sized) table BEFORE the gather: gathering in
    # f32 materializes an f32 activation that GSPMD may replicate while
    # resharding (half the bytes -> half the spill)
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    # bf16 operands, f32 accumulation/logits: avoids materializing an f32
    # copy of the activations (28 GiB/device on llava before this)
    w = p["table"].T.astype(x.dtype)
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def unembed_separate(p: Params, x: jax.Array) -> jax.Array:
    return jnp.matmul(x, p["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; labels < 0 are masked."""
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
