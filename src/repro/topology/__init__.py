from repro.topology.topology import Link, Node, NodeType, Topology, TopologyView
from repro.topology.generators import (
    ring,
    line,
    mesh2d,
    torus2d,
    torus3d,
    hypercube,
    star_switch,
    two_level_switch,
    tpu_v5e_pod,
    multi_pod,
    three_level,
)

__all__ = [
    "Link",
    "Node",
    "NodeType",
    "Topology",
    "TopologyView",
    "ring",
    "line",
    "mesh2d",
    "torus2d",
    "torus3d",
    "hypercube",
    "star_switch",
    "two_level_switch",
    "tpu_v5e_pod",
    "multi_pod",
    "three_level",
]
