"""Standard topology generators used in the paper's evaluation and ours.

All generators return NPU-dense topologies (NPU ids 0..n-1 first, switch ids
after) so process groups can be specified directly as NPU-id lists.

Unless stated otherwise links are bidirectional (one directed link each way)
and homogeneous with (alpha, beta) given by the caller. The paper's
homogeneous experiments use unit link time: alpha=0, beta=1 with chunk
bytes=1 -> 1 us per hop per chunk.
"""

from __future__ import annotations

from typing import Sequence

from repro.topology.topology import NodeType, Topology


def ring(n: int, alpha: float = 0.0, beta: float = 1.0, bidirectional: bool = False) -> Topology:
    """Unidirectional (default) or bidirectional ring of n NPUs (paper Fig. 4a)."""
    topo = Topology(f"ring{n}{'_bidir' if bidirectional else ''}")
    topo.add_npus(n)
    for i in range(n):
        topo.add_link(i, (i + 1) % n, alpha, beta)
        if bidirectional:
            topo.add_link((i + 1) % n, i, alpha, beta)
    topo.automorphism_generators = [tuple((i + 1) % n for i in range(n))]
    return topo


def line(n: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """Bidirectional line (path) of n NPUs."""
    topo = Topology(f"line{n}")
    topo.add_npus(n)
    for i in range(n - 1):
        topo.add_bidir_link(i, i + 1, alpha, beta)
    return topo


def mesh2d(rows: int, cols: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """Bidirectional 2D mesh (no wraparound) — the paper's main scalability target."""
    topo = Topology(f"mesh2d_{rows}x{cols}")
    topo.add_npus(rows * cols)
    idx = lambda r, c: r * cols + c
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                topo.add_bidir_link(idx(r, c), idx(r, c + 1), alpha, beta)
            if r + 1 < rows:
                topo.add_bidir_link(idx(r, c), idx(r + 1, c), alpha, beta)
    # mesh symmetries: row and column reflections (no wraparound -> no shifts)
    topo.automorphism_generators = [
        tuple(idx(rows - 1 - r, c) for r in range(rows) for c in range(cols)),
        tuple(idx(r, cols - 1 - c) for r in range(rows) for c in range(cols)),
    ]
    return topo


def torus2d(rows: int, cols: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """Bidirectional 2D torus (mesh + wraparound), the TPU pod abstraction."""
    topo = Topology(f"torus2d_{rows}x{cols}")
    topo.add_npus(rows * cols)
    idx = lambda r, c: r * cols + c
    for r in range(rows):
        for c in range(cols):
            topo.add_bidir_link(idx(r, c), idx(r, (c + 1) % cols), alpha, beta)
            topo.add_bidir_link(idx(r, c), idx((r + 1) % rows, c), alpha, beta)
    # torus symmetries: cyclic row/column translations (every row of a mesh
    # of process groups is isomorphic to every other row through these)
    topo.automorphism_generators = [
        tuple(idx((r + 1) % rows, c) for r in range(rows) for c in range(cols)),
        tuple(idx(r, (c + 1) % cols) for r in range(rows) for c in range(cols)),
    ]
    return topo


def torus3d(x: int, y: int, z: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    topo = Topology(f"torus3d_{x}x{y}x{z}")
    topo.add_npus(x * y * z)
    idx = lambda i, j, k: (i * y + j) * z + k
    for i in range(x):
        for j in range(y):
            for k in range(z):
                topo.add_bidir_link(idx(i, j, k), idx((i + 1) % x, j, k), alpha, beta)
                topo.add_bidir_link(idx(i, j, k), idx(i, (j + 1) % y, k), alpha, beta)
                topo.add_bidir_link(idx(i, j, k), idx(i, j, (k + 1) % z), alpha, beta)
    iters = [(i, j, k) for i in range(x) for j in range(y) for k in range(z)]
    topo.automorphism_generators = [
        tuple(idx((i + 1) % x, j, k) for i, j, k in iters),
        tuple(idx(i, (j + 1) % y, k) for i, j, k in iters),
        tuple(idx(i, j, (k + 1) % z) for i, j, k in iters),
    ]
    return topo


def hypercube(dims: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """dims-dimensional binary hypercube: 2**dims NPUs; paper uses '3D Hypercube'
    meaning the generalization with side>2 — see :func:`grid_hypercube`."""
    n = 1 << dims
    topo = Topology(f"hypercube{dims}d")
    topo.add_npus(n)
    for i in range(n):
        for b in range(dims):
            j = i ^ (1 << b)
            if j > i:
                topo.add_bidir_link(i, j, alpha, beta)
    # XOR translations generate a transitive symmetry group of size 2**dims
    topo.automorphism_generators = [
        tuple(i ^ (1 << b) for i in range(n)) for b in range(dims)
    ]
    return topo


def grid_hypercube(side: int, dims: int, alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """'3D Hypercube' in the paper's sense = dims-dimensional torus with equal
    sides (side**dims NPUs). dims=3 gives the paper's 3D Hypercube.

    The fabric is partitioned into ``side`` pods along the first dimension
    (one (dims-1)-torus plane each); the wraparound dim-0 links are the
    boundary fabric, so hierarchical synthesis decomposes per-plane."""
    if dims == 3:
        t = torus3d(side, side, side, alpha, beta)
        t.name = f"hypercube3d_{side}"
    elif dims == 2:
        t = torus2d(side, side, alpha, beta)
        t.name = f"hypercube2d_{side}"
    else:
        raise ValueError(f"unsupported dims={dims}")
    plane = side ** (dims - 1)
    t.set_partition([n // plane for n in range(t.num_nodes)])
    return t


def star_switch(
    n: int,
    alpha: float = 0.0,
    beta: float = 1.0,
    buffer_limit: int | None = None,
    multicast: bool = True,
) -> Topology:
    """n NPUs hanging off one switch (explicit switch node, paper §4.7)."""
    topo = Topology(f"star_switch{n}")
    topo.add_npus(n)
    sw = topo.add_node(NodeType.SWITCH, buffer_limit=buffer_limit, multicast=multicast)
    for i in range(n):
        topo.add_bidir_link(i, sw, alpha, beta)
    # any rotation of the leaves fixes the star (switch stays put)
    topo.automorphism_generators = [tuple((i + 1) % n for i in range(n)) + (sw,)]
    return topo


def two_level_switch(
    num_nodes: int,
    npus_per_node: int = 8,
    local_alpha: float = 0.5,
    local_beta: float = 1.0 / 400.0,  # ~400 GB/s scale-up per us-per-KiB scaling
    spine_alpha: float = 2.0,
    spine_beta: float = 1.0 / 50.0,  # ~50 GB/s scale-out
    buffer_limit: int | None = None,
    multicast: bool = True,
) -> Topology:
    """Heterogeneous 2D switch topology of paper Fig. 13: nodes of 8 NPUs with
    a fast local switch, node switches joined by a slower spine switch."""
    topo = Topology(f"switch2d_{num_nodes}x{npus_per_node}")
    topo.add_npus(num_nodes * npus_per_node)
    local = [
        topo.add_node(NodeType.SWITCH, buffer_limit=buffer_limit, multicast=multicast)
        for _ in range(num_nodes)
    ]
    spine = topo.add_node(NodeType.SWITCH, buffer_limit=buffer_limit, multicast=multicast)
    for node in range(num_nodes):
        for j in range(npus_per_node):
            topo.add_bidir_link(node * npus_per_node + j, local[node], local_alpha, local_beta)
        topo.add_bidir_link(local[node], spine, spine_alpha, spine_beta)
    # pods = {node's NPUs + its local switch}; the spine is shared (-1)
    pod_of = [i // npus_per_node for i in range(num_nodes * npus_per_node)]
    pod_of += list(range(num_nodes)) + [-1]
    topo.set_partition(pod_of)
    return topo


def tpu_v5e_pod(rows: int = 16, cols: int = 16, link_gbps: float = 50.0) -> Topology:
    """One TPU-v5e-like pod: 2D torus with ~50 GB/s/direction ICI links.

    beta is expressed in us per MiB so synthesized schedule times are in us
    for MiB-sized chunks: 1 MiB / (50 GB/s) = ~20 us/MiB.
    """
    beta_us_per_mib = (1.0 / (link_gbps * 1e9)) * (1 << 20) * 1e6
    t = torus2d(rows, cols, alpha=1.0, beta=beta_us_per_mib)
    t.name = f"tpu_v5e_pod_{rows}x{cols}"
    return t


def multi_pod(
    num_pods: int = 2,
    rows: int = 16,
    cols: int = 16,
    link_gbps: float = 50.0,
    dci_gbps: float = 25.0,
    dci_alpha: float = 10.0,
    dci_ports_per_pod: int = 16,
    unit_links: bool = False,
    dci_port_gbps: Sequence[float] | None = None,
    dci_ports_by_pod: Sequence[int] | None = None,
) -> Topology:
    """num_pods TPU pods; pod edge devices uplink to a DCI switch.

    NPU ids: pod p occupies [p*rows*cols, (p+1)*rows*cols). A single switch
    models the inter-pod fabric; each pod contributes `dci_ports_per_pod`
    uplinks from its first row (the 'edge' row). The partition (pod per
    torus, DCI switch shared) is set automatically, so hierarchical
    synthesis applies out of the box.

    Asymmetric-DCI variants (the traffic-engineering benchmark fabrics):

    * ``dci_port_gbps`` — per-uplink bandwidths in GB/s; uplink ``c`` of
      every pod runs at ``dci_port_gbps[c]`` (same profile per pod, so the
      pods stay isomorphic while their uplinks are mutually heterogeneous).
      When given it also sets the uplink count, overriding
      ``dci_ports_per_pod``.
    * ``dci_ports_by_pod`` — per-pod uplink *counts* (length
      ``num_pods``), for skewed-degree fabrics.

    ``unit_links=True`` collapses every link to (alpha=0, beta=1) — the
    paper's homogeneous unit-time regime — so the integer TEN fast path
    drives all phases; used by the scale benchmarks. It is incompatible
    with ``dci_port_gbps`` (unit links are uniform by definition).
    """
    if dci_port_gbps is not None:
        if unit_links:
            raise ValueError(
                "dci_port_gbps is incompatible with unit_links=True")
        dci_port_gbps = [float(g) for g in dci_port_gbps]
        if not dci_port_gbps or min(dci_port_gbps) <= 0:
            raise ValueError("dci_port_gbps must be non-empty positives")
    if dci_ports_by_pod is not None:
        dci_ports_by_pod = [int(k) for k in dci_ports_by_pod]
        if len(dci_ports_by_pod) != num_pods:
            raise ValueError(
                f"dci_ports_by_pod needs {num_pods} entries, got "
                f"{len(dci_ports_by_pod)}")
        if min(dci_ports_by_pod) < 1:
            raise ValueError("every pod needs >= 1 DCI uplink")
    beta_ici = (1.0 / (link_gbps * 1e9)) * (1 << 20) * 1e6
    beta_dci = (1.0 / (dci_gbps * 1e9)) * (1 << 20) * 1e6
    alpha_ici, alpha_dci = 1.0, dci_alpha
    if unit_links:
        alpha_ici = alpha_dci = 0.0
        beta_ici = beta_dci = 1.0
    suffix = "_unit" if unit_links else ""
    if dci_port_gbps is not None or dci_ports_by_pod is not None:
        suffix += "_asym"
    topo = Topology(f"multi_pod_{num_pods}x{rows}x{cols}{suffix}")
    per_pod = rows * cols
    topo.add_npus(num_pods * per_pod)
    idx = lambda p, r, c: p * per_pod + r * cols + c
    for p in range(num_pods):
        for r in range(rows):
            for c in range(cols):
                topo.add_bidir_link(idx(p, r, c), idx(p, r, (c + 1) % cols), alpha_ici, beta_ici)
                topo.add_bidir_link(idx(p, r, c), idx(p, (r + 1) % rows, c), alpha_ici, beta_ici)
    dci = topo.add_node(NodeType.SWITCH, buffer_limit=None, multicast=True)
    base_ports = (len(dci_port_gbps) if dci_port_gbps is not None
                  else dci_ports_per_pod)
    for p in range(num_pods):
        ports = dci_ports_by_pod[p] if dci_ports_by_pod is not None \
            else base_ports
        for c in range(min(ports, cols)):
            if dci_port_gbps is not None:
                gbps = dci_port_gbps[c % len(dci_port_gbps)]
                beta_c = (1.0 / (gbps * 1e9)) * (1 << 20) * 1e6
            else:
                beta_c = beta_dci
            topo.add_bidir_link(idx(p, 0, c), dci, alpha_dci, beta_c)
    topo.set_partition(
        [n // per_pod for n in range(num_pods * per_pod)] + [-1]
    )
    return topo


def three_level(
    num_pods: int = 2,
    racks_per_pod: int = 2,
    npus_per_rack: int = 4,
    rack_gbps: float = 400.0,
    agg_gbps: float = 100.0,
    dci_gbps: float = 25.0,
    dci_alpha: float = 10.0,
    dci_ports_per_pod: int | None = None,
    unit_links: bool = False,
    dci_port_gbps: Sequence[float] | None = None,
) -> Topology:
    """Three-level datacenter fabric: racks of NPUs, pods of racks, and a
    DCI plane of pods — the pods-of-pods regime where flat TEN search is
    hopeless and even one partition level leaves per-pod sub-problems too
    large.

    ``dci_port_gbps`` gives per-uplink DCI bandwidths (GB/s): the rack-``r``
    uplink of every pod runs at ``dci_port_gbps[r]`` — the same profile per
    pod, so pods stay isomorphic (pod rotation remains an automorphism)
    while the DCI plane is heterogeneous. Sets the uplink count when
    ``dci_ports_per_pod`` is not given; incompatible with ``unit_links``.

    Structure (NPU ids dense first: pod p, rack r, slot i at
    ``(p*R + r)*K + i``):

    * **rack**: ``npus_per_rack`` NPUs on a bidirectional ring (scale-up
      fabric); NPU 0 is the rack gateway.
    * **pod**: ``racks_per_pod`` racks; each rack gateway uplinks to the
      pod's aggregation switch (scale-out fabric).
    * **plane**: the first ``dci_ports_per_pod`` rack gateways of every pod
      uplink to a shared DCI switch (default: every rack gateway).

    The nested partition is derived automatically: NPU (p, r, i) carries
    path ``(p, r)``, the pod aggregation switches ``(p, -1)`` (inside their
    pod, shared across its racks), and the DCI switch ``-1`` — so
    ``pod_subtopology(p)`` is itself partitioned into racks and
    hierarchical synthesis recurses rack -> pod -> plane.

    ``unit_links=True`` collapses every link to (alpha=0, beta=1) — the
    homogeneous unit-time regime driving the integer-TEN fast paths; used
    by the scale benchmarks.
    """
    if npus_per_rack < 1 or racks_per_pod < 1 or num_pods < 1:
        raise ValueError("three_level sizes must be >= 1")
    if dci_ports_per_pod is not None and dci_ports_per_pod < 1:
        raise ValueError(
            "dci_ports_per_pod must be >= 1 (0 would disconnect the pods)")
    if dci_port_gbps is not None:
        if unit_links:
            raise ValueError(
                "dci_port_gbps is incompatible with unit_links=True")
        dci_port_gbps = [float(g) for g in dci_port_gbps]
        if not dci_port_gbps or min(dci_port_gbps) <= 0:
            raise ValueError("dci_port_gbps must be non-empty positives")
        if dci_ports_per_pod is None:
            dci_ports_per_pod = len(dci_port_gbps)
    ports = racks_per_pod if dci_ports_per_pod is None else min(
        dci_ports_per_pod, racks_per_pod)
    beta_rack = (1.0 / (rack_gbps * 1e9)) * (1 << 20) * 1e6
    beta_agg = (1.0 / (agg_gbps * 1e9)) * (1 << 20) * 1e6
    beta_dci = (1.0 / (dci_gbps * 1e9)) * (1 << 20) * 1e6
    alpha_rack, alpha_agg, alpha_dci = 0.5, 1.0, dci_alpha
    if unit_links:
        alpha_rack = alpha_agg = alpha_dci = 0.0
        beta_rack = beta_agg = beta_dci = 1.0
    suffix = "_unit" if unit_links else ""
    topo = Topology(
        f"three_level_{num_pods}x{racks_per_pod}x{npus_per_rack}{suffix}")
    per_rack, per_pod = npus_per_rack, racks_per_pod * npus_per_rack
    topo.add_npus(num_pods * per_pod)
    nid = lambda p, r, i: (p * racks_per_pod + r) * per_rack + i
    for p in range(num_pods):
        for r in range(racks_per_pod):
            if per_rack == 2:
                topo.add_bidir_link(nid(p, r, 0), nid(p, r, 1),
                                    alpha_rack, beta_rack)
            elif per_rack > 2:
                for i in range(per_rack):
                    topo.add_bidir_link(nid(p, r, i),
                                        nid(p, r, (i + 1) % per_rack),
                                        alpha_rack, beta_rack)
    agg = [topo.add_node(NodeType.SWITCH) for _ in range(num_pods)]
    for p in range(num_pods):
        for r in range(racks_per_pod):
            topo.add_bidir_link(nid(p, r, 0), agg[p], alpha_agg, beta_agg)
    dci = topo.add_node(NodeType.SWITCH)
    for p in range(num_pods):
        for r in range(ports):
            beta_r = beta_dci
            if dci_port_gbps is not None:
                gbps = dci_port_gbps[r % len(dci_port_gbps)]
                beta_r = (1.0 / (gbps * 1e9)) * (1 << 20) * 1e6
            topo.add_bidir_link(nid(p, r, 0), dci, alpha_dci, beta_r)
    paths: list = [
        (n // per_pod, (n % per_pod) // per_rack)
        for n in range(num_pods * per_pod)
    ]
    paths += [(p, -1) for p in range(num_pods)] + [-1]
    topo.set_partition(paths)
    # pod rotation is always a symmetry; rack rotation within every pod is
    # one exactly when every rack uplinks to the DCI (the registry verifies
    # each generator before use, so this only ever *adds* cache sharing)
    n_npus = num_pods * per_pod
    pod_rot = tuple(
        (n + per_pod) % n_npus for n in range(n_npus)
    ) + tuple(n_npus + (p + 1) % num_pods
              for p in range(num_pods)) + (dci,)
    topo.automorphism_generators = [pod_rot]
    # ... and only when the uplinks are mutually uniform: with per-port
    # DCI bandwidths, rotating racks would map a fast uplink onto a slow one
    uniform_ports = dci_port_gbps is None or len(
        {dci_port_gbps[r % len(dci_port_gbps)] for r in range(ports)}) == 1
    if ports == racks_per_pod and uniform_ports:
        rack_rot = tuple(
            (n // per_pod) * per_pod + (n % per_pod + per_rack) % per_pod
            for n in range(n_npus)
        ) + tuple(agg) + (dci,)
        topo.automorphism_generators.append(rack_rot)
    return topo
