"""Physical network topology model.

A topology is a directed multigraph over *devices*. Devices are either NPUs
(compute endpoints that may source/sink collective chunks) or switches
(forwarding-only devices with optional buffer limits and multicast support,
paper §4.7). Every directed link carries its own alpha (latency, us) and
beta (1/bandwidth, us per byte) — the alpha-beta model of paper §4.6 — so
heterogeneous and asymmetric networks are first-class.

Multi-pod fabrics additionally carry *partition metadata*: a pod id per
device (``set_partition``), from which derived views are computed — per-pod
sub-topologies, the boundary link set, the boundary sub-topology the
inter-pod synthesis phase runs on, and a quotient "pod graph" whose nodes
are pods. The hierarchical synthesis pipeline (:mod:`repro.core.hierarchy`)
consumes these views; generators that know their pod structure
(``multi_pod``, ``two_level_switch``, ``grid_hypercube``, ``three_level``)
set the partition automatically, and custom fabrics can call
``set_partition`` directly.

Partitions form a *tree*, not just one level: ``set_partition`` accepts
nested specs — each entry is either a pod id or a path ``(pod, sub_pod,
...)`` naming the device's pod at every level (rack -> pod -> plane
fabrics). ``pod_subtopology`` then returns a sub-topology that itself
carries the next level's partition (the path tails), so hierarchical
synthesis recurses: each intra-pod phase re-enters the pod-aware pipeline
on the pod's own partitioned fabric, with parent-id lifting composed
across levels through the stacked :class:`TopologyView` maps.
"""

from __future__ import annotations

import enum
import hashlib
from dataclasses import dataclass

import numpy as np

try:  # scipy serves all-pairs hop distances in one C sweep when present;
    # imported at module load so the cost never lands inside a timed region
    from scipy.sparse import csr_matrix as _sp_csr_matrix
    from scipy.sparse.csgraph import shortest_path as _sp_shortest_path
except ImportError:  # pragma: no cover - scipy ships in the image
    _sp_csr_matrix = _sp_shortest_path = None


class NodeType(enum.Enum):
    NPU = "npu"
    SWITCH = "switch"


@dataclass(frozen=True)
class Node:
    """A device in the network."""

    id: int
    type: NodeType = NodeType.NPU
    # Switch-only attributes (ignored for NPUs):
    buffer_limit: int | None = None  # max chunks resident at once (None = inf)
    multicast: bool = True  # can forward one incoming chunk on >1 link per step


@dataclass(frozen=True)
class Link:
    """A directed physical link src -> dst with alpha-beta timing."""

    id: int
    src: int
    dst: int
    alpha: float = 0.0  # latency in us
    beta: float = 1.0  # us per byte (1/bandwidth)

    def transfer_time(self, chunk_bytes: float) -> float:
        """alpha + m * beta (paper Fig. 9)."""
        return self.alpha + chunk_bytes * self.beta


@dataclass(frozen=True)
class CSRAdjacency:
    """Cached array export of the out-adjacency, in ``out_links`` order.

    Edge ``e`` of node ``u`` lives at positions ``indptr[u] .. indptr[u+1]``;
    ``link_ids[e]``/``dst_ids[e]`` are the link id and head node. The numpy
    arrays drive vectorized passes (frontier masks, distance sweeps); the
    plain-list mirrors (`adj`, `is_switch`, `serial_switch`) serve the scalar
    hot loops in :mod:`repro.core.pathfinding`, where list indexing beats
    numpy scalar indexing by ~3x.
    """

    indptr: np.ndarray  # [num_nodes + 1] int32
    link_ids: np.ndarray  # [num_links] int32
    dst_ids: np.ndarray  # [num_links] int32
    src_ids: np.ndarray  # [num_links] int32 (edge -> tail node)
    # scalar mirrors for the pathfinding hot loop
    adj: tuple  # adj[u] = ((edge_idx, dst, link_id), ...)
    edge_dst: tuple  # per-edge head node
    edge_src: tuple  # per-edge tail node
    edge_link: tuple  # per-edge link id
    is_switch: tuple  # per-node bool
    serial_switch: tuple  # per-node bool: switch and not multicast
    limited_switches: tuple  # node ids of switches with a buffer_limit
    any_switch: bool
    # True iff some switch actually constrains the search (finite buffer or
    # serialized egress); unlimited multicast switches behave like NPUs, so
    # unconstrained fabrics take the fast pathfinding/commit paths
    constrained_switch: bool = False


@dataclass(frozen=True)
class TopologyView:
    """A sub-topology extracted from a parent fabric, plus the coordinate
    maps needed to lift synthesized transfers back into the parent.

    ``nodes[i]`` / ``links[j]`` are the parent ids of local node ``i`` /
    local link ``j``; local ids are dense and assigned in ascending parent-id
    order, so two structurally-identical pods of one fabric extract to
    byte-identical local topologies (and therefore equal registry
    fingerprints — the property hierarchical synthesis relies on to pay one
    synthesis for N isomorphic pods).
    """

    topology: "Topology"
    parent: "Topology"
    nodes: tuple[int, ...]  # local node id -> parent node id
    links: tuple[int, ...]  # local link id -> parent link id

    @property
    def to_local(self) -> dict[int, int]:
        got = self.__dict__.get("_to_local")
        if got is None:
            got = {g: l for l, g in enumerate(self.nodes)}
            self.__dict__["_to_local"] = got
        return got


class Topology:
    """Directed multigraph with O(1) adjacency lookups.

    Node ids must be dense integers starting at 0 (NPUs and switches share
    one id space). Link ids are assigned densely in insertion order.
    """

    def __init__(self, name: str = "topology"):
        self.name = name
        self.nodes: list[Node] = []
        self.links: list[Link] = []
        self._out: list[list[Link]] = []  # node id -> outgoing links
        self._in: list[list[Link]] = []  # node id -> incoming links
        # Known symmetry generators: node permutations that map the topology
        # onto itself (set by generators that know their structure, e.g.
        # torus translations). The algorithm registry verifies each one
        # before use, so a wrong generator degrades cache sharing, never
        # correctness. Empty = only the identity is assumed.
        self.automorphism_generators: list[tuple[int, ...]] = []
        # Partition metadata: node id -> pod id (-1 = shared/unassigned,
        # e.g. an inter-pod switch). None until set_partition is called.
        self._pod_of: tuple[int, ...] | None = None
        # Full partition-tree paths: node id -> (pod, sub_pod, ...). Kept
        # alongside the top-level view; tails seed nested sub-partitions.
        self._pod_paths: tuple[tuple[int, ...], ...] | None = None

    # -- construction ------------------------------------------------------
    def _invalidate_caches(self) -> None:
        """Drop memoized derived state (structure hash, automorphism closure,
        attached synthesis engines) when the graph mutates."""
        # the reversed-view memo is symmetric: mutating either side must
        # break BOTH backlinks, or the unchanged peer would keep serving
        # this (no longer link-reversed) object from its cache
        rev = getattr(self, "_rev_cache", None)
        if rev is not None and getattr(rev, "_rev_cache", None) is self:
            del rev._rev_cache
        for attr in ("_structure_hash", "_automorphism_closure",
                     "_pccl_engines", "_csr_cache", "_rev_dist_rows",
                     "_adjh_rows", "_bfs_scratch", "_hop_matrix_cache",
                     "_pod_views", "_rev_cache", "_partition_fp",
                     "_degraded_views"):
            if hasattr(self, attr):
                delattr(self, attr)

    def add_node(
        self,
        type: NodeType = NodeType.NPU,
        buffer_limit: int | None = None,
        multicast: bool = True,
    ) -> int:
        self._invalidate_caches()
        nid = len(self.nodes)
        self.nodes.append(Node(nid, type, buffer_limit, multicast))
        self._out.append([])
        self._in.append([])
        if self._pod_of is not None:  # nodes added later start unassigned
            self._pod_of = self._pod_of + (-1,)
            self._pod_paths = self._pod_paths + ((-1,),)
        return nid

    def add_npus(self, n: int) -> list[int]:
        return [self.add_node(NodeType.NPU) for _ in range(n)]

    def add_link(
        self, src: int, dst: int, alpha: float = 0.0, beta: float = 1.0
    ) -> int:
        if src == dst:
            raise ValueError(f"self-link on node {src}")
        self._invalidate_caches()
        link = Link(len(self.links), src, dst, alpha, beta)
        self.links.append(link)
        self._out[src].append(link)
        self._in[dst].append(link)
        return link.id

    def add_bidir_link(
        self, a: int, b: int, alpha: float = 0.0, beta: float = 1.0
    ) -> tuple[int, int]:
        return self.add_link(a, b, alpha, beta), self.add_link(b, a, alpha, beta)

    # -- queries -----------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_links(self) -> int:
        return len(self.links)

    @property
    def npus(self) -> list[int]:
        return [n.id for n in self.nodes if n.type is NodeType.NPU]

    @property
    def switches(self) -> list[int]:
        return [n.id for n in self.nodes if n.type is NodeType.SWITCH]

    def out_links(self, node: int) -> list[Link]:
        return self._out[node]

    def in_links(self, node: int) -> list[Link]:
        return self._in[node]

    def is_switch(self, node: int) -> bool:
        return self.nodes[node].type is NodeType.SWITCH

    def homogeneous(self) -> bool:
        """True iff every link has identical (alpha, beta)."""
        if not self.links:
            return True
        a0, b0 = self.links[0].alpha, self.links[0].beta
        return all(l.alpha == a0 and l.beta == b0 for l in self.links)

    # -- partition metadata (multi-pod fabrics) ----------------------------
    @staticmethod
    def _validate_paths(paths: list[tuple[int, ...]], where: str) -> None:
        """Recursive partition-tree validation: at every level the pod ids in
        use are dense ``0..P-1`` (``-1`` = shared, and terminates its path),
        and each pod's tails form a valid partition of the next level."""
        heads = [p[0] for p in paths]
        if any(h < -1 for h in heads):
            raise ValueError(f"pod ids must be >= -1 ({where})")
        used = sorted({h for h in heads if h >= 0})
        if used != list(range(len(used))):
            raise ValueError(
                f"pod ids must be dense 0..P-1, got {used} ({where})")
        for p in paths:
            if p[0] == -1 and len(p) > 1:
                raise ValueError(
                    f"shared (-1) must terminate its partition path, got "
                    f"{p} ({where})")
        for pod in used:
            tails = [p[1:] for p in paths if p[0] == pod and len(p) > 1]
            if tails:
                Topology._validate_paths(tails, f"{where}/pod{pod}")

    def set_partition(self, pod_of) -> None:
        """Declare pod membership: ``pod_of[node]`` is either a pod id with
        pods dense ``0..P-1`` (``-1`` marks shared devices owned by no pod,
        e.g. an inter-pod DCI switch), or a nested *path* ``(pod, sub_pod,
        ...)`` assigning the device at every level of a partition tree
        (``(p, -1)`` = in pod ``p`` but shared at the next level). Generators
        with known structure call this; custom fabrics may too. Derived views
        (:meth:`pod_subtopology`, :meth:`boundary_subtopology`,
        :meth:`pod_graph`) are recomputed lazily after every call;
        ``pod_subtopology`` of a pod with a sub-partition returns a topology
        carrying that sub-partition, which is how hierarchical synthesis
        recurses through rack -> pod -> plane fabrics."""
        paths = []
        for p in pod_of:
            if isinstance(p, (int, np.integer)):
                paths.append((int(p),))
            else:
                path = tuple(int(x) for x in p)
                if not path:
                    raise ValueError("empty partition path")
                paths.append(path)
        if len(paths) != self.num_nodes:
            raise ValueError(
                f"partition names {len(paths)} nodes, fabric has "
                f"{self.num_nodes}"
            )
        self._validate_paths(paths, self.name)
        self._pod_paths = tuple(paths)
        self._pod_of = tuple(p[0] for p in paths)
        for attr in ("_pod_views", "_partition_fp"):
            if hasattr(self, attr):
                delattr(self, attr)

    @property
    def partition(self) -> tuple[int, ...] | None:
        """Top-level ``pod_of`` tuple, or None for unpartitioned fabrics."""
        return self._pod_of

    @property
    def partition_paths(self) -> tuple[tuple[int, ...], ...] | None:
        """Full per-node partition-tree paths (None = unpartitioned)."""
        return self._pod_paths

    @property
    def partition_depth(self) -> int:
        """Number of partition levels: 0 = unpartitioned, 1 = flat pods,
        2 = pods-of-pods (three routing levels), counting only assigned
        (``>= 0``) path entries."""
        if self._pod_paths is None:
            return 0
        return max(
            (sum(1 for x in p if x >= 0) for p in self._pod_paths),
            default=0,
        )

    def partition_fingerprint(self) -> str | None:
        """Stable hash of the full partition tree (None = unpartitioned).

        Registry keys for hierarchical routes must include this: the
        topology *structure* hash is partition-blind, so a 2-level and a
        3-level view of the same fabric would otherwise collide and a
        cached 2-level plan could be served for the 3-level view."""
        if self._pod_paths is None:
            return None
        got = getattr(self, "_partition_fp", None)
        if got is None:
            got = hashlib.sha256(
                repr(self._pod_paths).encode()).hexdigest()[:16]
            self._partition_fp = got
        return got

    @property
    def num_pods(self) -> int:
        if self._pod_of is None:
            return 0
        return max(self._pod_of) + 1 if self._pod_of else 0

    def pod_of(self, node: int) -> int:
        if self._pod_of is None:
            raise ValueError(f"{self.name}: no partition set")
        return self._pod_of[node]

    def _views(self) -> dict:
        views = getattr(self, "_pod_views", None)
        if views is None:
            views = self._pod_views = {}
        return views

    def pods(self) -> list[list[int]]:
        """Node ids per pod (ascending), excluding unassigned devices."""
        views = self._views()
        got = views.get("pods")
        if got is None:
            if self._pod_of is None:
                raise ValueError(f"{self.name}: no partition set")
            got = [[] for _ in range(self.num_pods)]
            for node, p in enumerate(self._pod_of):
                if p >= 0:
                    got[p].append(node)
            views["pods"] = got
        return got

    def pod_npus(self, pod: int) -> list[int]:
        return [n for n in self.pods()[pod]
                if self.nodes[n].type is NodeType.NPU]

    def boundary_links(self) -> list[Link]:
        """Links whose endpoints lie in different pods (a ``-1`` endpoint
        counts as its own side): the inter-pod fabric."""
        views = self._views()
        got = views.get("boundary")
        if got is None:
            pod = self.pod_of
            got = [l for l in self.links if pod(l.src) != pod(l.dst)]
            views["boundary"] = got
        return got

    def _extract(self, node_ids, link_ids, name: str) -> TopologyView:
        """Build a :class:`TopologyView` over the given parent node/link ids
        (ascending parent order -> dense local ids)."""
        node_ids = sorted(node_ids)
        link_ids = sorted(link_ids)
        sub = Topology(name)
        local = {}
        for g in node_ids:
            nd = self.nodes[g]
            local[g] = sub.add_node(nd.type, nd.buffer_limit, nd.multicast)
        for g in link_ids:
            l = self.links[g]
            sub.add_link(local[l.src], local[l.dst], l.alpha, l.beta)
        return TopologyView(sub, self, tuple(node_ids), tuple(link_ids))

    def pod_subtopology(self, pod: int) -> TopologyView:
        """Pod ``pod``'s internal fabric: its nodes plus the links with both
        endpoints inside it. Isomorphic pods extract to identical local
        topologies (same registry fingerprint), which is what lets one
        synthesized pod plan serve every pod.

        On a nested partition tree the extracted topology carries the next
        level's partition (the members' path tails), so hierarchical
        synthesis re-enters the pod-aware pipeline on it — the recursion
        step of rack -> pod -> plane decomposition. Two isomorphic pods with
        equal sub-partitions extract to identical sub-topologies *and*
        identical partition fingerprints, preserving registry sharing at
        every level."""
        views = self._views()
        got = views.get(("sub", pod))
        if got is None:
            members = set(self.pods()[pod])
            links = [l.id for l in self.links
                     if l.src in members and l.dst in members]
            got = self._extract(members, links,
                                f"{self.name}_pod{pod}")
            if self._pod_paths is not None:
                tails = [self._pod_paths[g][1:] or (-1,)
                         for g in got.nodes]
                if any(t[0] >= 0 for t in tails):
                    got.topology.set_partition(tails)
            views[("sub", pod)] = got
        return got

    def gateways(self, pod: int) -> list[int]:
        """Pod ``pod``'s gateway NPUs: NPU endpoints of boundary links when
        any exist, else the pod NPUs one hop inside its boundary switches
        (two-level-switch style fabrics, where the boundary port is the
        local switch itself)."""
        views = self._views()
        got = views.get(("gw", pod))
        if got is not None:
            return got
        members = set(self.pods()[pod])
        ports = sorted(
            {e for l in self.boundary_links()
             for e in (l.src, l.dst) if e in members}
        )
        npu_ports = [n for n in ports
                     if self.nodes[n].type is NodeType.NPU]
        if npu_ports:
            got = npu_ports
        else:
            got = sorted({
                l.src
                for sw in ports
                for l in self._in[sw]
                if l.src in members
                and self.nodes[l.src].type is NodeType.NPU
            })
        views[("gw", pod)] = got
        return got

    def boundary_subtopology(self) -> TopologyView:
        """The fabric the inter-pod synthesis phase runs on: every boundary
        link, the unassigned (shared) devices with their internal links, each
        pod's boundary ports — and, for pods whose ports are switches, the
        gateway NPUs plus their links to those switches, so inter-pod
        conditions can still originate and terminate at NPUs."""
        views = self._views()
        got = views.get("bsub")
        if got is not None:
            return got
        pod = self.pod_of
        nodes: set[int] = set()
        links: set[int] = set()
        for l in self.boundary_links():
            links.add(l.id)
            nodes.update((l.src, l.dst))
        # shared devices and the links among them
        shared = {n.id for n in self.nodes if pod(n.id) == -1}
        nodes.update(shared)
        links.update(l.id for l in self.links
                     if l.src in shared and l.dst in shared)
        # switch-port pods: pull in gateway NPUs + their port links
        for p in range(self.num_pods):
            gws = set(self.gateways(p))
            if gws & nodes:
                continue  # NPU ports already present
            nodes.update(gws)
            links.update(
                l.id for l in self.links
                if (l.src in gws and l.dst in nodes and pod(l.dst) == p)
                or (l.dst in gws and l.src in nodes and pod(l.src) == p)
            )
        got = self._extract(nodes, links, f"{self.name}_boundary")
        views["bsub"] = got
        return got

    def pod_graph(self) -> "Topology":
        """Quotient "pod graph": one NPU-node per pod, one node per shared
        device (keeping its type/attrs), and one link per boundary link with
        its timing carried over — the coarse view used to reason about
        pod-level routes and reachability."""
        views = self._views()
        got = views.get("graph")
        if got is not None:
            return got
        g = Topology(f"{self.name}_podgraph")
        for _ in range(self.num_pods):
            g.add_node(NodeType.NPU)
        shared_map = {}
        for n in self.nodes:
            if self.pod_of(n.id) == -1:
                shared_map[n.id] = g.add_node(
                    n.type, n.buffer_limit, n.multicast)

        def q(node: int) -> int:
            p = self.pod_of(node)
            return shared_map[node] if p == -1 else p

        for l in self.boundary_links():
            g.add_link(q(l.src), q(l.dst), l.alpha, l.beta)
        for l in self.links:
            if self.pod_of(l.src) == -1 and self.pod_of(l.dst) == -1:
                g.add_link(q(l.src), q(l.dst), l.alpha, l.beta)
        views["graph"] = g
        return g

    # -- array adjacency ---------------------------------------------------
    def csr(self) -> CSRAdjacency:
        """The cached :class:`CSRAdjacency` export (rebuilt on mutation)."""
        cached = getattr(self, "_csr_cache", None)
        if cached is not None:
            return cached
        n = self.num_nodes
        indptr = np.zeros(n + 1, dtype=np.int32)
        link_ids = np.empty(self.num_links, dtype=np.int32)
        dst_ids = np.empty(self.num_links, dtype=np.int32)
        src_ids = np.empty(self.num_links, dtype=np.int32)
        adj = []
        e = 0
        for u in range(n):
            rows = []
            for link in self._out[u]:
                link_ids[e] = link.id
                dst_ids[e] = link.dst
                src_ids[e] = u
                rows.append((e, link.dst, link.id))
                e += 1
            indptr[u + 1] = e
            adj.append(tuple(rows))
        is_switch = tuple(nd.type is NodeType.SWITCH for nd in self.nodes)
        serial = tuple(
            is_switch[nd.id] and not nd.multicast for nd in self.nodes
        )
        limited = tuple(
            nd.id for nd in self.nodes
            if is_switch[nd.id] and nd.buffer_limit is not None
        )
        cached = CSRAdjacency(
            indptr, link_ids, dst_ids, src_ids, tuple(adj),
            tuple(int(x) for x in dst_ids),
            tuple(int(x) for x in src_ids),
            tuple(int(x) for x in link_ids),
            is_switch, serial, limited, any(is_switch),
            bool(limited) or any(serial),
        )
        self._csr_cache = cached
        return cached

    # -- distances ---------------------------------------------------------
    def hop_distances_from(self, src: int) -> list[int]:
        """Unweighted BFS hop distance from src to all nodes (-1 = unreachable)."""
        return self.hop_distances_np(src).tolist()

    def hop_distances_np(self, src: int) -> np.ndarray:
        """Vectorized hop distances from ``src`` (int32, -1 = unreachable):
        one numpy frontier sweep per BFS level over the CSR arrays."""
        csr = self.csr()
        dist = np.full(self.num_nodes, -1, dtype=np.int32)
        dist[src] = 0
        frontier = np.array([src], dtype=np.int32)
        d = 0
        indptr, dst_ids = csr.indptr, csr.dst_ids
        while frontier.size:
            starts = indptr[frontier]
            counts = indptr[frontier + 1] - starts
            total = int(counts.sum())
            if not total:
                break
            owner = np.repeat(np.arange(frontier.size), counts)
            offsets = np.arange(total) - np.repeat(
                np.cumsum(counts) - counts, counts
            )
            nbrs = dst_ids[starts[owner] + offsets]
            nbrs = np.unique(nbrs[dist[nbrs] < 0])
            d += 1
            dist[nbrs] = d
            frontier = nbrs.astype(np.int32)
        return dist

    def hop_matrix(self):
        """All-pairs hop-distance matrix ``D[i, j] = hops i -> j`` (float,
        inf = unreachable), computed in one scipy C sweep and cached — the
        single source for both from-source rows (condition ordering) and
        to-destination columns (the pathfinding heuristic). Returns ``None``
        when scipy is unavailable or the graph has no links."""
        cached = getattr(self, "_hop_matrix_cache", None)
        if cached is None:
            if _sp_shortest_path is not None and self.num_links:
                csr = self.csr()
                n = self.num_nodes
                graph = _sp_csr_matrix(
                    (np.ones(len(csr.dst_ids)),
                     (csr.src_ids, csr.dst_ids)),
                    shape=(n, n),
                )
                cached = (_sp_shortest_path(graph, method="D",
                                            unweighted=True),)
            else:
                cached = (False,)
            self._hop_matrix_cache = cached
        matrix = cached[0]
        return None if matrix is False else matrix

    def hop_distances_to(self, dst: int) -> list[int]:
        """Hop distance from every node to ``dst`` over directed links
        (reverse BFS), cached per destination — the admissible heuristic
        used by the pathfinding search bound. Served from the shared
        all-pairs matrix when available."""
        rows = getattr(self, "_rev_dist_rows", None)
        if rows is None:
            rows = self._rev_dist_rows = {}
        got = rows.get(dst)
        if got is not None:
            return got
        matrix = self.hop_matrix()
        if matrix is not None:
            col = matrix[:, dst]
            dist = [-1 if x == float("inf") else int(x) for x in col]
            rows[dst] = dist
            return dist
        dist = [-1] * self.num_nodes
        dist[dst] = 0
        frontier = [dst]
        while frontier:
            nxt = []
            for x in frontier:
                dx1 = dist[x] + 1
                for link in self._in[x]:
                    if dist[link.src] < 0:
                        dist[link.src] = dx1
                        nxt.append(link.src)
            frontier = nxt
        rows[dst] = dist
        return dist

    def degraded(self, failed_links=(), failed_npus=()) -> TopologyView:
        """The surviving fabric after losing ``failed_links`` (link ids)
        and/or ``failed_npus`` (node ids): a :class:`TopologyView` whose
        topology keeps *every* node — dead devices stay as isolated nodes,
        so node ids are stable across degradation (``view.nodes`` is the
        identity) — and drops the failed links plus every link incident to
        a failed node (``view.links`` maps surviving local link ids back to
        this fabric's).

        The view's topology carries the full partition tree and the
        declared automorphism generators: an *undamaged* pod of the
        degraded fabric extracts to a sub-topology byte-identical to the
        original pod's (same nodes, surviving links in the same relative
        order), so registry entries synthesized on the healthy fabric keep
        serving the undamaged pods of the degraded one — the property
        incremental plan repair (:mod:`repro.core.repair`) relies on.
        Generators broken by the damage are filtered out by the registry's
        per-use verification, degrading sharing, never correctness.

        Memoized per (failed links, failed npus) set pair; mutation of the
        fabric drops the memo."""
        fl = frozenset(int(l) for l in failed_links)
        fn = frozenset(int(n) for n in failed_npus)
        for l in fl:
            if not 0 <= l < self.num_links:
                raise ValueError(f"unknown link id {l}")
        for n in fn:
            if not 0 <= n < self.num_nodes:
                raise ValueError(f"unknown node id {n}")
        views = getattr(self, "_degraded_views", None)
        if views is None:
            views = self._degraded_views = {}
        got = views.get((fl, fn))
        if got is not None:
            return got
        keep = [l.id for l in self.links
                if l.id not in fl and l.src not in fn and l.dst not in fn]
        got = self._extract(range(self.num_nodes), keep,
                            f"{self.name}_degraded")
        sub = got.topology
        sub.automorphism_generators = list(self.automorphism_generators)
        if self._pod_paths is not None:
            sub.set_partition(list(self._pod_paths))
        views[(fl, fn)] = got
        return got

    def reversed(self) -> "Topology":
        """The link-reversed view (used for reduction synthesis), memoized.

        Link ``k`` of the reversed topology is link ``k`` of this one with its
        endpoints swapped, so link ids carry over between the two orientations
        — the property the time-reversal trick relies on to lift reduction
        schedules back onto the forward fabric. The view is cached and carries
        a backlink, so ``reversed()`` of the reversed view round-trips to this
        very object (pod sub-/boundary views derived on either orientation
        therefore extract the same parent node/link id sets). Mutating the
        fabric drops the cache and a fresh view is built.

        Derived caches are carried instead of recomputed: the reversed view's
        all-pairs hop matrix is the transpose of the forward one (link
        reversal flips every path), so an already-computed forward matrix is
        shared by value. The CSR export and per-destination rows stay lazy —
        they are direction-dependent and rebuild on first use against the
        reversed adjacency, so no stale forward adjacency can leak. Partition
        metadata (pod membership, and therefore gateways) is
        direction-agnostic and carries over."""
        cached_rev = getattr(self, "_rev_cache", None)
        if cached_rev is not None:
            return cached_rev
        rev = Topology(self.name + "_rev")
        for node in self.nodes:
            rev.add_node(node.type, node.buffer_limit, node.multicast)
        for link in self.links:
            rev.add_link(link.dst, link.src, link.alpha, link.beta)
        # node symmetries are direction-agnostic, as is pod membership
        # (the full partition-tree paths carry over, so nested reversed
        # pod views decompose identically)
        rev.automorphism_generators = list(self.automorphism_generators)
        if self._pod_of is not None:
            rev._pod_of = self._pod_of
            rev._pod_paths = self._pod_paths
        cached = getattr(self, "_hop_matrix_cache", None)
        if cached is not None and cached[0] is not False:
            rev._hop_matrix_cache = (cached[0].T,)
        self._rev_cache = rev
        rev._rev_cache = self
        return rev

    def __repr__(self) -> str:
        return (
            f"Topology({self.name!r}, nodes={self.num_nodes} "
            f"(npus={len(self.npus)}, switches={len(self.switches)}), "
            f"links={self.num_links})"
        )
