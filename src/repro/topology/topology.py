"""Physical network topology model.

A topology is a directed multigraph over *devices*. Devices are either NPUs
(compute endpoints that may source/sink collective chunks) or switches
(forwarding-only devices with optional buffer limits and multicast support,
paper §4.7). Every directed link carries its own alpha (latency, us) and
beta (1/bandwidth, us per byte) — the alpha-beta model of paper §4.6 — so
heterogeneous and asymmetric networks are first-class.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class NodeType(enum.Enum):
    NPU = "npu"
    SWITCH = "switch"


@dataclass(frozen=True)
class Node:
    """A device in the network."""

    id: int
    type: NodeType = NodeType.NPU
    # Switch-only attributes (ignored for NPUs):
    buffer_limit: int | None = None  # max chunks resident at once (None = inf)
    multicast: bool = True  # can forward one incoming chunk on >1 link per step


@dataclass(frozen=True)
class Link:
    """A directed physical link src -> dst with alpha-beta timing."""

    id: int
    src: int
    dst: int
    alpha: float = 0.0  # latency in us
    beta: float = 1.0  # us per byte (1/bandwidth)

    def transfer_time(self, chunk_bytes: float) -> float:
        """alpha + m * beta (paper Fig. 9)."""
        return self.alpha + chunk_bytes * self.beta


class Topology:
    """Directed multigraph with O(1) adjacency lookups.

    Node ids must be dense integers starting at 0 (NPUs and switches share
    one id space). Link ids are assigned densely in insertion order.
    """

    def __init__(self, name: str = "topology"):
        self.name = name
        self.nodes: list[Node] = []
        self.links: list[Link] = []
        self._out: list[list[Link]] = []  # node id -> outgoing links
        self._in: list[list[Link]] = []  # node id -> incoming links
        # Known symmetry generators: node permutations that map the topology
        # onto itself (set by generators that know their structure, e.g.
        # torus translations). The algorithm registry verifies each one
        # before use, so a wrong generator degrades cache sharing, never
        # correctness. Empty = only the identity is assumed.
        self.automorphism_generators: list[tuple[int, ...]] = []

    # -- construction ------------------------------------------------------
    def _invalidate_caches(self) -> None:
        """Drop memoized derived state (structure hash, automorphism closure,
        attached synthesis engines) when the graph mutates."""
        for attr in ("_structure_hash", "_automorphism_closure",
                     "_pccl_engines"):
            if hasattr(self, attr):
                delattr(self, attr)

    def add_node(
        self,
        type: NodeType = NodeType.NPU,
        buffer_limit: int | None = None,
        multicast: bool = True,
    ) -> int:
        self._invalidate_caches()
        nid = len(self.nodes)
        self.nodes.append(Node(nid, type, buffer_limit, multicast))
        self._out.append([])
        self._in.append([])
        return nid

    def add_npus(self, n: int) -> list[int]:
        return [self.add_node(NodeType.NPU) for _ in range(n)]

    def add_link(
        self, src: int, dst: int, alpha: float = 0.0, beta: float = 1.0
    ) -> int:
        if src == dst:
            raise ValueError(f"self-link on node {src}")
        self._invalidate_caches()
        link = Link(len(self.links), src, dst, alpha, beta)
        self.links.append(link)
        self._out[src].append(link)
        self._in[dst].append(link)
        return link.id

    def add_bidir_link(
        self, a: int, b: int, alpha: float = 0.0, beta: float = 1.0
    ) -> tuple[int, int]:
        return self.add_link(a, b, alpha, beta), self.add_link(b, a, alpha, beta)

    # -- queries -----------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_links(self) -> int:
        return len(self.links)

    @property
    def npus(self) -> list[int]:
        return [n.id for n in self.nodes if n.type is NodeType.NPU]

    @property
    def switches(self) -> list[int]:
        return [n.id for n in self.nodes if n.type is NodeType.SWITCH]

    def out_links(self, node: int) -> list[Link]:
        return self._out[node]

    def in_links(self, node: int) -> list[Link]:
        return self._in[node]

    def is_switch(self, node: int) -> bool:
        return self.nodes[node].type is NodeType.SWITCH

    def homogeneous(self) -> bool:
        """True iff every link has identical (alpha, beta)."""
        if not self.links:
            return True
        a0, b0 = self.links[0].alpha, self.links[0].beta
        return all(l.alpha == a0 and l.beta == b0 for l in self.links)

    # -- distances ---------------------------------------------------------
    def hop_distances_from(self, src: int) -> list[int]:
        """Unweighted BFS hop distance from src to all nodes (-1 = unreachable)."""
        dist = [-1] * self.num_nodes
        dist[src] = 0
        frontier = [src]
        while frontier:
            nxt = []
            for u in frontier:
                for link in self._out[u]:
                    if dist[link.dst] < 0:
                        dist[link.dst] = dist[u] + 1
                        nxt.append(link.dst)
            frontier = nxt
        return dist

    def reversed(self) -> "Topology":
        """A copy with every link direction flipped (used for reduction synthesis)."""
        rev = Topology(self.name + "_rev")
        for node in self.nodes:
            rev.add_node(node.type, node.buffer_limit, node.multicast)
        for link in self.links:
            rev.add_link(link.dst, link.src, link.alpha, link.beta)
        # node symmetries are direction-agnostic
        rev.automorphism_generators = list(self.automorphism_generators)
        return rev

    def __repr__(self) -> str:
        return (
            f"Topology({self.name!r}, nodes={self.num_nodes} "
            f"(npus={len(self.npus)}, switches={len(self.switches)}), "
            f"links={self.num_links})"
        )
