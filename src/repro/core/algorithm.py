"""Synthesized collective algorithms: timed chunk transfers + validation oracle.

A synthesized algorithm is a congestion-free schedule of store-and-forward
chunk transfers over physical links. ``validate()`` replays the schedule and
checks every invariant the synthesizer promises:

  * links exist and transfer durations follow the alpha-beta model,
  * no two transfers overlap on one link (congestion-freedom, paper §4.4),
  * store-and-forward causality (a chunk leaves a device only after arriving),
  * switch buffer limits and multicast capability (paper §4.7),
  * post-conditions: every destination holds its chunk; reduced chunks carry
    each contribution exactly once (no double counting).
"""

from __future__ import annotations

import operator
from collections import defaultdict
from dataclasses import dataclass, field

from repro.core.conditions import Condition, ReduceCondition
from repro.topology.topology import Topology

_EPS = 1e-6


@dataclass(frozen=True, slots=True)
class Transfer:
    """Chunk moves src -> dst over `link` during [start, end)."""

    chunk: int
    link: int
    src: int
    dst: int
    start: float
    end: float
    reduce: bool = False

    def overlaps(self, other: "Transfer") -> bool:
        return self.start < other.end - _EPS and other.start < self.end - _EPS


@dataclass
class CollectiveAlgorithm:
    """The synthesis result for a set of conditions over a topology."""

    topology: Topology
    conditions: list  # list[Condition | ReduceCondition]
    transfers: list[Transfer] = field(default_factory=list)
    name: str = "pccl"

    def __post_init__(self):
        self.transfers = sorted(
            self.transfers, key=operator.attrgetter("start", "chunk", "link")
        )

    @property
    def makespan(self) -> float:
        if not self.transfers:
            return 0.0
        release = min((c.release for c in self.conditions), default=0.0)
        return max(t.end for t in self.transfers) - release

    @property
    def num_transfers(self) -> int:
        return len(self.transfers)

    def total_bytes_moved(self) -> float:
        sizes = {c.chunk: c.bytes for c in self.conditions}
        return sum(sizes[t.chunk] for t in self.transfers)

    def link_busy_time(self) -> dict[int, float]:
        busy: dict[int, float] = defaultdict(float)
        for t in self.transfers:
            busy[t.link] += t.end - t.start
        return dict(busy)

    def link_utilization(self) -> dict[int, float]:
        span = self.makespan or 1.0
        return {l: b / span for l, b in self.link_busy_time().items()}

    # ------------------------------------------------------------------
    # Validation oracle
    # ------------------------------------------------------------------
    def validate(self) -> None:
        topo = self.topology
        sizes = {c.chunk: c.bytes for c in self.conditions}
        releases = {c.chunk: c.release for c in self.conditions}

        # 1. Link-level checks: existence, duration, congestion-freedom.
        by_link: dict[int, list[Transfer]] = defaultdict(list)
        for t in self.transfers:
            link = topo.links[t.link]
            if (link.src, link.dst) != (t.src, t.dst):
                raise AssertionError(f"{t} does not ride link {link}")
            want = link.transfer_time(sizes[t.chunk])
            if abs((t.end - t.start) - want) > _EPS:
                raise AssertionError(
                    f"{t}: duration {t.end - t.start} != alpha-beta time {want}"
                )
            by_link[t.link].append(t)
        for link_id, ts in by_link.items():
            ts.sort(key=lambda t: t.start)
            for a, b in zip(ts, ts[1:]):
                if a.overlaps(b):
                    raise AssertionError(f"congestion on link {link_id}: {a} vs {b}")

        # 2. Replay: presence/causality/switch constraints/reduction algebra.
        # holdings[node][chunk] = frozenset of contributions (presence for
        # plain chunks is the singleton {src}).
        holdings: dict[int, dict[int, frozenset[int]]] = defaultdict(dict)
        sent_reduce: set[tuple[int, int]] = set()  # (node, chunk) partial already sent
        full_sets: dict[int, frozenset[int]] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                full_sets[c.chunk] = c.srcs
                for s in c.srcs:
                    holdings[s][c.chunk] = frozenset([s])
            else:
                full_sets[c.chunk] = frozenset([c.src])
                holdings[c.src][c.chunk] = frozenset([c.src])

        # switch occupancy / multicast bookkeeping:
        # residency of (switch, chunk) = [arrival end, last outgoing send end]
        switch_arrive: dict[tuple[int, int], float] = {}
        switch_depart: dict[tuple[int, int], float] = {}
        switch_sends: dict[int, list[Transfer]] = defaultdict(list)

        for t in self.transfers:
            held = holdings[t.src].get(t.chunk)
            if held is None:
                raise AssertionError(f"{t}: sender does not hold chunk")
            if t.start < releases[t.chunk] - _EPS:
                raise AssertionError(f"{t}: starts before chunk release")
            if t.reduce:
                if (t.src, t.chunk) in sent_reduce:
                    raise AssertionError(f"{t}: node sent its partial twice")
                sent_reduce.add((t.src, t.chunk))
                prev = holdings[t.dst].get(t.chunk, frozenset())
                if prev & held:
                    raise AssertionError(
                        f"{t}: double-counted contributions {sorted(prev & held)}"
                    )
                holdings[t.dst][t.chunk] = prev | held
                # The partial leaves the sender (it must not contribute again);
                # keep it recorded for causality of later copies only if it is
                # the full set (i.e. sender was the reduction root).
                if held != full_sets[t.chunk]:
                    del holdings[t.src][t.chunk]
            else:
                if full_sets[t.chunk] != held:
                    # copying a partially-reduced chunk is a correctness bug
                    if len(full_sets[t.chunk]) > 1:
                        raise AssertionError(
                            f"{t}: copies partial reduction {sorted(held)}"
                        )
                holdings[t.dst][t.chunk] = held
            if topo.is_switch(t.src):
                switch_sends[t.src].append(t)
                key = (t.src, t.chunk)
                switch_depart[key] = max(switch_depart.get(key, 0.0), t.end)
            if topo.is_switch(t.dst):
                key = (t.dst, t.chunk)
                if key not in switch_arrive:
                    switch_arrive[key] = t.end

        # 2b. causality in time: arrival must precede departure. Replay above
        # processed transfers in start order; verify explicitly with times.
        arrival: dict[tuple[int, int], float] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                for s in c.srcs:
                    arrival[(s, c.chunk)] = c.release
            else:
                arrival[(c.src, c.chunk)] = c.release
        for t in self.transfers:
            a = arrival.get((t.src, t.chunk))
            if a is None or t.start < a - _EPS:
                raise AssertionError(f"{t}: departs before chunk arrived (arr={a})")
            prev = arrival.get((t.dst, t.chunk), float("inf"))
            arrival[(t.dst, t.chunk)] = min(prev, t.end)

        # 3. Switch constraints.
        for sw, sends in switch_sends.items():
            node = topo.nodes[sw]
            if not node.multicast:
                # a non-multicast switch cannot duplicate one chunk onto
                # several egress ports at once (paper §4.7); distinct chunks
                # may still flow through different ports concurrently.
                per_chunk: dict[int, list[Transfer]] = defaultdict(list)
                for t in sends:
                    per_chunk[t.chunk].append(t)
                for chunk, ts in per_chunk.items():
                    ts.sort(key=lambda t: t.start)
                    for a, b in zip(ts, ts[1:]):
                        if a.overlaps(b):
                            raise AssertionError(
                                f"non-multicast switch {sw} duplicates chunk "
                                f"{chunk} concurrently: {a} / {b}"
                            )
        residency: dict[int, list[tuple[float, float]]] = defaultdict(list)
        for (sw, chunk), arr in switch_arrive.items():
            dep = switch_depart.get((sw, chunk), arr)
            residency[sw].append((arr, max(dep, arr)))
        for sw, intervals in residency.items():
            limit = topo.nodes[sw].buffer_limit
            if limit is None:
                continue
            events = []
            for a, d in intervals:
                events.append((a, +1))
                events.append((d, -1))
            occ = 0
            # departures (-1) release the slot before same-instant arrivals
            for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
                occ += delta
                if occ > limit:
                    raise AssertionError(f"switch {sw} buffer exceeded ({occ} > {limit})")

        # 4. Post-conditions.
        for c in self.conditions:
            dests = c.dests
            for d in dests:
                got = holdings[d].get(c.chunk)
                if got is None:
                    raise AssertionError(f"chunk {c.chunk} never reached NPU {d}")
                if got != full_sets[c.chunk]:
                    raise AssertionError(
                        f"chunk {c.chunk} at NPU {d} has contributions "
                        f"{sorted(got)} != {sorted(full_sets[c.chunk])}"
                    )

    def is_valid(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False
