"""Synthesized collective algorithms: timed chunk transfers + validation oracle.

A synthesized algorithm is a congestion-free schedule of store-and-forward
chunk transfers over physical links. ``validate()`` replays the schedule and
checks every invariant the synthesizer promises:

  * links exist and transfer durations follow the alpha-beta model,
  * no two transfers overlap on one link (congestion-freedom, paper §4.4),
  * store-and-forward causality (a chunk leaves a device only after arriving),
  * switch buffer limits and multicast capability (paper §4.7),
  * post-conditions: every destination holds its chunk; reduced chunks carry
    each contribution exactly once (no double counting).
"""

from __future__ import annotations

import operator
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core.conditions import Condition, ReduceCondition
from repro.topology.topology import Topology

_EPS = 1e-6

# transfer lists past this size sort via numpy lexsort (stable, same order
# as sorted()); below it, plain sorted() wins on constant factors
_VECTOR_SORT_MIN = 1 << 17


class _NotInForest(Exception):
    """Internal: a reduction schedule is not in the in-forest normal form
    the bulk validator's structural shortcut covers — defer the verdict to
    the reference oracle (which rejects the genuinely broken schedules and
    accepts valid-but-nonstandard ones)."""


@dataclass(frozen=True, slots=True)
class Transfer:
    """Chunk moves src -> dst over `link` during [start, end)."""

    chunk: int
    link: int
    src: int
    dst: int
    start: float
    end: float
    reduce: bool = False

    def overlaps(self, other: "Transfer") -> bool:
        return self.start < other.end - _EPS and other.start < self.end - _EPS


@dataclass
class CollectiveAlgorithm:
    """The synthesis result for a set of conditions over a topology."""

    topology: Topology
    conditions: list  # list[Condition | ReduceCondition]
    transfers: list[Transfer] = field(default_factory=list)
    name: str = "pccl"
    # Phase provenance for composed algorithms (hierarchical / PhasePlan
    # synthesis): [(phase name, first start, last end)], in execution order.
    # Multi-level compositions record sub-phase provenance as nested
    # "parent/child" names (e.g. "intra:0/inter" — the pod-boundary phase
    # inside pod 0's recursive plan), whose windows lie inside the parent's.
    # Purely descriptive — validation and replay never consult it.
    phase_spans: list = field(default_factory=list)

    def __post_init__(self):
        ts = self.transfers
        if len(ts) >= _VECTOR_SORT_MIN:
            # same stable (start, chunk, link) order, bulk-keyed: million-
            # transfer composed schedules sort in C instead of via
            # attrgetter tuples
            start = np.fromiter((t.start for t in ts), dtype=float,
                                count=len(ts))
            chunk = np.fromiter((t.chunk for t in ts), dtype=np.int64,
                                count=len(ts))
            link = np.fromiter((t.link for t in ts), dtype=np.int64,
                               count=len(ts))
            order = np.lexsort((link, chunk, start))
            self.transfers = [ts[i] for i in order]
        else:
            self.transfers = sorted(
                ts, key=operator.attrgetter("start", "chunk", "link")
            )

    def top_phase_spans(self) -> list:
        """Top-level ``phase_spans`` entries only — nested sub-phase
        provenance (recorded as ``"parent/child"`` names by multi-level
        composition) filtered out."""
        return [s for s in self.phase_spans if "/" not in s[0]]

    @property
    def makespan(self) -> float:
        if not self.transfers:
            return 0.0
        release = min((c.release for c in self.conditions), default=0.0)
        return max(t.end for t in self.transfers) - release

    @property
    def num_transfers(self) -> int:
        return len(self.transfers)

    def total_bytes_moved(self) -> float:
        sizes = {c.chunk: c.bytes for c in self.conditions}
        return sum(sizes[t.chunk] for t in self.transfers)

    def link_busy_time(self) -> dict[int, float]:
        busy: dict[int, float] = defaultdict(float)
        for t in self.transfers:
            busy[t.link] += t.end - t.start
        return dict(busy)

    def link_utilization(self) -> dict[int, float]:
        span = self.makespan or 1.0
        return {l: b / span for l, b in self.link_busy_time().items()}

    # ------------------------------------------------------------------
    # Validation oracle
    # ------------------------------------------------------------------
    def validate(self, mode: str = "auto") -> None:
        """Replay the schedule and check every synthesizer invariant.

        ``mode="auto"`` dispatches million-transfer schedules of the
        *unconstrained* class (every switch unlimited and
        multicast-capable; reductions in the in-forest normal form PCCL
        synthesizes) to a vectorized implementation of the same checks —
        identical accept/reject behavior, enforced by the differential
        tests in ``tests/test_validation_bulk.py`` — and everything else
        to the reference oracle. ``"oracle"``/``"bulk"`` force a path."""
        if mode not in ("auto", "oracle", "bulk"):
            raise ValueError(f"mode={mode!r} not in auto/oracle/bulk")
        if mode == "oracle":
            return self._validate_oracle()
        eligible = (
            len(self.transfers) >= _VECTOR_SORT_MIN or mode == "bulk"
        ) and self._bulk_validatable()
        if mode == "bulk" and not eligible:
            raise ValueError(
                "bulk validation requires plain/reduce conditions and "
                "unconstrained switches"
            )
        if eligible:
            return self._validate_bulk()
        return self._validate_oracle()

    def _bulk_validatable(self) -> bool:
        if not all(n.buffer_limit is None and n.multicast
                   for n in self.topology.nodes):
            return False
        if not all(type(c) in (Condition, ReduceCondition)
                   for c in self.conditions):
            return False
        # reduce transfers must ride reduction chunks — a reduce-flagged
        # copy of a plain chunk is a nonstandard schedule the oracle judges
        # with its full replay, so keep it there
        rchunks = {c.chunk for c in self.conditions
                   if type(c) is ReduceCondition}
        return all(t.chunk in rchunks for t in self.transfers if t.reduce)

    def _validate_bulk(self) -> None:
        """Vectorized validation for schedules on unconstrained fabrics.
        Check-for-check equivalent to the oracle: link endpoints and
        alpha-beta durations, adjacent-interval congestion per link, release
        bounds, store-and-forward causality (a chunk departs a node only
        at/after its earliest arrival there), and post-condition delivery.

        Reduction schedules are checked against the in-forest normal form
        every PCCL reduction synthesizes (flat reversed-gather and
        hierarchical phase-composed alike): per chunk, reduce transfers form
        an in-forest in which each device forwards its accumulated partial
        at most once and only after every partial merged into it arrived;
        all chains terminate at a single root, where the full contribution
        set assembles; plain copies of the chunk flow only from that root,
        no earlier than assembly. Within that class the verdict matches the
        oracle's replay (each contribution delivered exactly once, no
        partial-state copies). A schedule outside the normal form — e.g. a
        hand-written one that reduce-forwards an already-assembled chunk —
        is handed to the oracle for the final verdict instead of being
        rejected structurally, so ``validate`` returns the same answer at
        every size and through every mode."""
        topo = self.topology
        ts = self.transfers
        conds = self.conditions
        n = len(ts)
        chunk = np.fromiter((t.chunk for t in ts), np.int64, n)
        link = np.fromiter((t.link for t in ts), np.int64, n)
        src = np.fromiter((t.src for t in ts), np.int64, n)
        dst = np.fromiter((t.dst for t in ts), np.int64, n)
        start = np.fromiter((t.start for t in ts), float, n)
        end = np.fromiter((t.end for t in ts), float, n)
        red = np.fromiter((t.reduce for t in ts), bool, n)

        if n and (link.min() < 0 or link.max() >= topo.num_links):
            raise AssertionError("transfer references unknown link")
        lsrc = np.fromiter((l.src for l in topo.links), np.int64,
                           topo.num_links)
        ldst = np.fromiter((l.dst for l in topo.links), np.int64,
                           topo.num_links)
        bad = (lsrc[link] != src) | (ldst[link] != dst)
        if bad.any():
            raise AssertionError(
                f"{ts[int(bad.argmax())]} does not ride its link")

        cchunk = np.fromiter((c.chunk for c in conds), np.int64, len(conds))
        uchunks, cidx = np.unique(cchunk, return_index=True)
        if len(uchunks) != len(conds):
            raise AssertionError("duplicate chunk id in conditions")
        pos = np.searchsorted(uchunks, chunk)
        if n and ((pos >= len(uchunks)) | (uchunks[np.minimum(
                pos, len(uchunks) - 1)] != chunk)).any():
            raise AssertionError("transfer moves unknown chunk")
        csize = np.fromiter((c.bytes for c in conds), float, len(conds))
        crel = np.fromiter((c.release for c in conds), float, len(conds))
        sizes = csize[cidx][pos] if n else csize[:0]
        rel = crel[cidx][pos] if n else crel[:0]

        alpha = np.fromiter((l.alpha for l in topo.links), float,
                            topo.num_links)
        beta = np.fromiter((l.beta for l in topo.links), float,
                           topo.num_links)
        want = alpha[link] + sizes * beta[link]
        bad = np.abs((end - start) - want) > _EPS
        if bad.any():
            k = int(bad.argmax())
            raise AssertionError(
                f"{ts[k]}: duration {end[k] - start[k]} != alpha-beta "
                f"time {want[k]}")

        # congestion: per link, adjacent intervals in start order
        order = np.lexsort((start, link))
        ls, ss, es = link[order], start[order], end[order]
        same = ls[1:] == ls[:-1]
        overlap = same & (ss[1:] < es[:-1] - _EPS) & (ss[:-1] < es[1:] - _EPS)
        if overlap.any():
            k = int(overlap.argmax())
            raise AssertionError(
                f"congestion on link {ls[k]}: {ts[int(order[k])]} vs "
                f"{ts[int(order[k + 1])]}")

        if (start < rel - _EPS).any():
            k = int((start < rel - _EPS).argmax())
            raise AssertionError(f"{ts[k]}: starts before chunk release")

        nn = topo.num_nodes
        # per-upos condition views (uchunks[j] is the chunk of conds[cidx[j]])
        is_rc_u = np.fromiter(
            (type(conds[i]) is ReduceCondition for i in cidx), bool,
            len(cidx))
        origin_u = np.fromiter(
            (getattr(conds[i], "src", -1) for i in cidx), np.int64, len(cidx))
        rel_u = crel[cidx]
        rel_eff_u = rel_u

        # -- reduction algebra: in-forest per chunk -------------------------
        if is_rc_u.any():
            try:
                origin_u, rel_eff_u = self._bulk_reduce_structure(
                    conds, cidx, uchunks, is_rc_u, origin_u, rel_u,
                    pos, src, dst, start, end, red, nn)
            except _NotInForest:
                # outside the normal form PCCL synthesizes: the structural
                # shortcut does not apply, so the reference replay decides
                return self._validate_oracle()

        # earliest copy arrival per (chunk, node), origins at release (for
        # reduced chunks: at the root, at assembly time)
        cp = np.nonzero(~red)[0]
        akey = (pos * nn + dst)[cp]
        ukey, inv = np.unique(akey, return_inverse=True)
        amin = np.full(len(ukey), np.inf)
        np.minimum.at(amin, inv, end[cp])

        if len(cp):
            origin_t = origin_u[pos[cp]]
            rel_eff_t = rel_eff_u[pos[cp]]
            skey2 = (pos * nn + src)[cp]
            if len(ukey):
                sloc = np.minimum(np.searchsorted(ukey, skey2),
                                  len(ukey) - 1)
                found = ukey[sloc] == skey2
                arr = np.where(found, amin[sloc], np.inf)
            else:
                arr = np.full(len(cp), np.inf)
            arr = np.where(src[cp] == origin_t,
                           np.minimum(arr, rel_eff_t), arr)
            bad = start[cp] < arr - _EPS
            if bad.any():
                # a "bad" copy of a reduced chunk may be legal outside the
                # normal form (a mid-chain node that assembled the full set
                # may copy it onward) — the oracle decides those; a bad copy
                # of a plain chunk is a definite causality violation
                bad_plain = bad & ~is_rc_u[pos[cp]]
                if not bad_plain.any():
                    return self._validate_oracle()
                k = int(cp[int(bad_plain.argmax())])
                a = arr[int(bad_plain.argmax())]
                raise AssertionError(
                    f"{ts[k]}: departs before chunk arrived "
                    f"(arr={a if np.isfinite(a) else None})")

        # post-conditions: every destination reached (or holds from origin /
        # is the assembly root)
        pk, pd = [], []
        for ci, c in enumerate(conds):
            for d in c.dests:
                pk.append(ci)
                pd.append(d)
        pk = np.asarray(pk, np.int64)
        pd = np.asarray(pd, np.int64)
        cond_upos = np.searchsorted(uchunks, cchunk)
        got = pd == origin_u[cond_upos[pk]]
        if len(ukey):
            dkey = cond_upos[pk] * nn + pd
            dloc = np.minimum(np.searchsorted(ukey, dkey), len(ukey) - 1)
            got |= ukey[dloc] == dkey
        if not got.all():
            # an unreached dest of a reduced chunk may still hold the full
            # set outside the normal form (an interior forest node that
            # assembled it before forwarding) — defer those to the oracle;
            # a missing plain-chunk delivery is definite
            miss_plain = ~got & ~is_rc_u[cond_upos[pk]]
            if not miss_plain.any():
                return self._validate_oracle()
            k = int(miss_plain.argmax())
            raise AssertionError(
                f"chunk {conds[pk[k]].chunk} never reached NPU {pd[k]}")

    @staticmethod
    def _bulk_reduce_structure(conds, cidx, uchunks, is_rc_u, origin_u,
                               rel_u, pos, src, dst, start, end, red, nn):
        """Verify the in-forest normal form of the reduce transfers and
        return the effective (origin, release) per chunk for the copy-phase
        checks: per reduce chunk, its single assembly root and the time the
        full contribution set assembles there. Raises :class:`_NotInForest`
        when the schedule is outside the normal form — the caller then hands
        the verdict to the reference oracle."""
        su, sn = [], []
        for j, ci in enumerate(cidx):
            c = conds[ci]
            if type(c) is ReduceCondition:
                for s in c.srcs:
                    su.append(j)
                    sn.append(s)
        skey = np.asarray(su, np.int64) * nn + np.asarray(sn, np.int64)
        skey.sort()

        ridx = np.nonzero(red)[0]
        rpos, rsrc, rdst = pos[ridx], src[ridx], dst[ridx]
        rstart, rend = start[ridx], end[ridx]
        if len(ridx) and not is_rc_u[rpos].all():
            raise _NotInForest("reduce transfer on a non-reduction chunk")
        # each device forwards its accumulated partial at most once
        okey = rpos * nn + rsrc
        u_out, out_counts = np.unique(okey, return_counts=True)
        if (out_counts > 1).any():
            raise _NotInForest("a node forwards its partial twice")
        # latest merged-partial arrival per (chunk, node)
        ikey = rpos * nn + rdst
        u_in, inv_in = np.unique(ikey, return_inverse=True)
        in_max = np.full(len(u_in), -np.inf)
        np.maximum.at(in_max, inv_in, rend)
        if len(u_in):
            loc = np.minimum(np.searchsorted(u_in, okey), len(u_in) - 1)
            has_in = u_in[loc] == okey
            need = np.where(has_in, in_max[loc], -np.inf)
        else:
            has_in = np.zeros(len(okey), bool)
            need = np.full(len(okey), -np.inf)
        if (rstart < need - _EPS).any():
            raise _NotInForest("a partial forwards before every merged "
                               "contribution arrived")
        # senders that merged nothing must be declared contributors
        if len(skey):
            loc = np.minimum(np.searchsorted(skey, okey), len(skey) - 1)
            is_src_sender = skey[loc] == okey
        else:
            is_src_sender = np.zeros(len(okey), bool)
        if (~has_in & ~is_src_sender).any():
            raise _NotInForest("a reduce sender holds no contribution")
        # every participant (contributor or merge point) forwards except
        # exactly one root per chunk, where the full set assembles;
        # acyclicity comes from the arrival-before-forward check above
        pkeys = np.union1d(skey, u_in)
        if len(u_out):
            loc = np.minimum(np.searchsorted(u_out, pkeys), len(u_out) - 1)
            has_out = u_out[loc] == pkeys
        else:
            has_out = np.zeros(len(pkeys), bool)
        roots = pkeys[~has_out]
        root_upos = roots // nn
        counts = np.zeros(len(uchunks), np.int64)
        np.add.at(counts, root_upos, 1)
        if (is_rc_u & (counts != 1)).any():
            raise _NotInForest("contributions do not assemble at a single "
                               "root")
        root_node = np.full(len(uchunks), -1, np.int64)
        root_node[root_upos] = roots % nn
        assembled = rel_u.copy()
        if len(u_in):
            loc = np.minimum(np.searchsorted(u_in, roots), len(u_in) - 1)
            found = u_in[loc] == roots
            assembled[root_upos] = np.maximum(
                assembled[root_upos],
                np.where(found, in_max[loc], -np.inf))
        # copies of a reduced chunk originate at its root, post-assembly
        return (np.where(is_rc_u, root_node, origin_u),
                np.where(is_rc_u, assembled, rel_u))

    def _validate_oracle(self) -> None:
        topo = self.topology
        sizes = {c.chunk: c.bytes for c in self.conditions}
        releases = {c.chunk: c.release for c in self.conditions}

        # 1. Link-level checks: existence, duration, congestion-freedom.
        by_link: dict[int, list[Transfer]] = defaultdict(list)
        for t in self.transfers:
            link = topo.links[t.link]
            if (link.src, link.dst) != (t.src, t.dst):
                raise AssertionError(f"{t} does not ride link {link}")
            want = link.transfer_time(sizes[t.chunk])
            if abs((t.end - t.start) - want) > _EPS:
                raise AssertionError(
                    f"{t}: duration {t.end - t.start} != alpha-beta time {want}"
                )
            by_link[t.link].append(t)
        for link_id, ts in by_link.items():
            ts.sort(key=lambda t: t.start)
            for a, b in zip(ts, ts[1:]):
                if a.overlaps(b):
                    raise AssertionError(f"congestion on link {link_id}: {a} vs {b}")

        # 2. Replay: presence/causality/switch constraints/reduction algebra.
        # holdings[node][chunk] = frozenset of contributions (presence for
        # plain chunks is the singleton {src}).
        holdings: dict[int, dict[int, frozenset[int]]] = defaultdict(dict)
        sent_reduce: set[tuple[int, int]] = set()  # (node, chunk) partial already sent
        full_sets: dict[int, frozenset[int]] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                full_sets[c.chunk] = c.srcs
                for s in c.srcs:
                    holdings[s][c.chunk] = frozenset([s])
            else:
                full_sets[c.chunk] = frozenset([c.src])
                holdings[c.src][c.chunk] = frozenset([c.src])

        # switch occupancy / multicast bookkeeping:
        # residency of (switch, chunk) = [arrival end, last outgoing send end]
        switch_arrive: dict[tuple[int, int], float] = {}
        switch_depart: dict[tuple[int, int], float] = {}
        switch_sends: dict[int, list[Transfer]] = defaultdict(list)

        for t in self.transfers:
            held = holdings[t.src].get(t.chunk)
            if held is None:
                raise AssertionError(f"{t}: sender does not hold chunk")
            if t.start < releases[t.chunk] - _EPS:
                raise AssertionError(f"{t}: starts before chunk release")
            if t.reduce:
                if (t.src, t.chunk) in sent_reduce:
                    raise AssertionError(f"{t}: node sent its partial twice")
                sent_reduce.add((t.src, t.chunk))
                prev = holdings[t.dst].get(t.chunk, frozenset())
                if prev & held:
                    raise AssertionError(
                        f"{t}: double-counted contributions {sorted(prev & held)}"
                    )
                holdings[t.dst][t.chunk] = prev | held
                # The partial leaves the sender (it must not contribute again);
                # keep it recorded for causality of later copies only if it is
                # the full set (i.e. sender was the reduction root).
                if held != full_sets[t.chunk]:
                    del holdings[t.src][t.chunk]
            else:
                if full_sets[t.chunk] != held:
                    # copying a partially-reduced chunk is a correctness bug
                    if len(full_sets[t.chunk]) > 1:
                        raise AssertionError(
                            f"{t}: copies partial reduction {sorted(held)}"
                        )
                holdings[t.dst][t.chunk] = held
            if topo.is_switch(t.src):
                switch_sends[t.src].append(t)
                key = (t.src, t.chunk)
                switch_depart[key] = max(switch_depart.get(key, 0.0), t.end)
            if topo.is_switch(t.dst):
                key = (t.dst, t.chunk)
                if key not in switch_arrive:
                    switch_arrive[key] = t.end

        # 2b. causality in time: arrival must precede departure. Replay above
        # processed transfers in start order; verify explicitly with times.
        arrival: dict[tuple[int, int], float] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                for s in c.srcs:
                    arrival[(s, c.chunk)] = c.release
            else:
                arrival[(c.src, c.chunk)] = c.release
        for t in self.transfers:
            a = arrival.get((t.src, t.chunk))
            if a is None or t.start < a - _EPS:
                raise AssertionError(f"{t}: departs before chunk arrived (arr={a})")
            prev = arrival.get((t.dst, t.chunk), float("inf"))
            arrival[(t.dst, t.chunk)] = min(prev, t.end)

        # 3. Switch constraints.
        for sw, sends in switch_sends.items():
            node = topo.nodes[sw]
            if not node.multicast:
                # a non-multicast switch cannot duplicate one chunk onto
                # several egress ports at once (paper §4.7); distinct chunks
                # may still flow through different ports concurrently.
                per_chunk: dict[int, list[Transfer]] = defaultdict(list)
                for t in sends:
                    per_chunk[t.chunk].append(t)
                for chunk, ts in per_chunk.items():
                    ts.sort(key=lambda t: t.start)
                    for a, b in zip(ts, ts[1:]):
                        if a.overlaps(b):
                            raise AssertionError(
                                f"non-multicast switch {sw} duplicates chunk "
                                f"{chunk} concurrently: {a} / {b}"
                            )
        residency: dict[int, list[tuple[float, float]]] = defaultdict(list)
        for (sw, chunk), arr in switch_arrive.items():
            dep = switch_depart.get((sw, chunk), arr)
            residency[sw].append((arr, max(dep, arr)))
        for sw, intervals in residency.items():
            limit = topo.nodes[sw].buffer_limit
            if limit is None:
                continue
            events = []
            for a, d in intervals:
                events.append((a, +1))
                events.append((d, -1))
            occ = 0
            # departures (-1) release the slot before same-instant arrivals
            for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
                occ += delta
                if occ > limit:
                    raise AssertionError(f"switch {sw} buffer exceeded ({occ} > {limit})")

        # 4. Post-conditions.
        for c in self.conditions:
            dests = c.dests
            for d in dests:
                got = holdings[d].get(c.chunk)
                if got is None:
                    raise AssertionError(f"chunk {c.chunk} never reached NPU {d}")
                if got != full_sets[c.chunk]:
                    raise AssertionError(
                        f"chunk {c.chunk} at NPU {d} has contributions "
                        f"{sorted(got)} != {sorted(full_sets[c.chunk])}"
                    )

    def is_valid(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False
