"""Synthesized collective algorithms: timed chunk transfers + validation oracle.

A synthesized algorithm is a congestion-free schedule of store-and-forward
chunk transfers over physical links. ``validate()`` replays the schedule and
checks every invariant the synthesizer promises:

  * links exist and transfer durations follow the alpha-beta model,
  * no two transfers overlap on one link (congestion-freedom, paper §4.4),
  * store-and-forward causality (a chunk leaves a device only after arriving),
  * switch buffer limits and multicast capability (paper §4.7),
  * post-conditions: every destination holds its chunk; reduced chunks carry
    each contribution exactly once (no double counting).

Storage is **columnar**: the source of truth for a schedule is a
:class:`TransferColumns` struct of parallel numpy arrays
(``chunk/link/src/dst/start/end/reduce``), ~37 bytes/row instead of the
~150+ bytes a boxed :class:`Transfer` object costs. Every aggregate
(`makespan`, `link_busy_time`, bulk validation, sorting) runs directly on
the arrays; the object API survives through :class:`TransferList`, a lazy
``Sequence[Transfer]`` view that materializes rows on demand.
"""

from __future__ import annotations

from collections import defaultdict
from collections.abc import Sequence
from dataclasses import dataclass

import numpy as np

from repro.core.conditions import Condition, ReduceCondition
from repro.topology.topology import Topology

_EPS = 1e-6

# historical threshold between the object-sort and lexsort paths; sorting is
# always columnar now, but `validate(mode="auto")` still uses it as the
# schedule size past which the vectorized validator takes over
_VECTOR_SORT_MIN = 1 << 17

# row block size for the lazy Transfer iterator: tolist() per block keeps
# python-object churn off the hot loop without materializing the whole plan
_ITER_BLOCK = 1 << 16


class _NotInForest(Exception):
    """Internal: a reduction schedule is not in the in-forest normal form
    the bulk validator's structural shortcut covers — defer the verdict to
    the reference oracle (which rejects the genuinely broken schedules and
    accepts valid-but-nonstandard ones)."""


@dataclass(frozen=True, slots=True)
class Transfer:
    """Chunk moves src -> dst over `link` during [start, end)."""

    chunk: int
    link: int
    src: int
    dst: int
    start: float
    end: float
    reduce: bool = False

    def overlaps(self, other: "Transfer") -> bool:
        return self.start < other.end - _EPS and other.start < self.end - _EPS


# columnar field order and dtypes; link/src/dst are int32 (fabrics stay well
# under 2^31 links), chunk is int64 (hierarchical compositions renumber into
# wide global id spaces)
_COLUMN_DTYPES = (
    ("chunk", np.int64),
    ("link", np.int32),
    ("src", np.int32),
    ("dst", np.int32),
    ("start", np.float64),
    ("end", np.float64),
    ("reduce", np.bool_),
)


def remap_ids(values: np.ndarray, mapping: dict) -> np.ndarray:
    """Vectorized ``mapping.get(v, v)`` over an int array: ids present in
    `mapping` are translated, everything else passes through unchanged."""
    if not len(mapping) or not len(values):
        return values
    keys = np.fromiter(mapping.keys(), np.int64, len(mapping))
    vals = np.fromiter(mapping.values(), np.int64, len(mapping))
    order = np.argsort(keys)
    keys, vals = keys[order], vals[order]
    pos = np.searchsorted(keys, values)
    pos_c = np.minimum(pos, len(keys) - 1)
    hit = keys[pos_c] == values
    return np.where(hit, vals[pos_c], values)


class TransferColumns:
    """Parallel arrays holding one schedule: the columnar source of truth.

    Arrays are treated as immutable after construction (they may be
    zero-copy views into an mmap'ed registry entry) — every transform
    (`shifted`, `take`, `relabeled`, ...) returns a new instance, sharing
    unchanged columns. ``presorted`` records that rows are already in the
    canonical ``(start, chunk, link)`` schedule order, letting loads of
    persisted plans skip the sort (and the page-in it would force).
    """

    __slots__ = ("chunk", "link", "src", "dst", "start", "end", "reduce",
                 "presorted")

    def __init__(self, chunk, link, src, dst, start, end, reduce, *,
                 presorted: bool = False):
        self.chunk = np.asarray(chunk, np.int64)
        self.link = np.asarray(link, np.int32)
        self.src = np.asarray(src, np.int32)
        self.dst = np.asarray(dst, np.int32)
        self.start = np.asarray(start, np.float64)
        self.end = np.asarray(end, np.float64)
        self.reduce = np.asarray(reduce, np.bool_)
        self.presorted = presorted

    # -- construction --------------------------------------------------
    @classmethod
    def empty(cls) -> "TransferColumns":
        return cls(*(np.empty(0, dt) for _, dt in _COLUMN_DTYPES),
                   presorted=True)

    @classmethod
    def from_transfers(cls, transfers) -> "TransferColumns":
        ts = transfers if isinstance(transfers, (list, tuple)) \
            else list(transfers)
        n = len(ts)
        if not n:
            return cls.empty()
        return cls(
            np.fromiter((t.chunk for t in ts), np.int64, n),
            np.fromiter((t.link for t in ts), np.int32, n),
            np.fromiter((t.src for t in ts), np.int32, n),
            np.fromiter((t.dst for t in ts), np.int32, n),
            np.fromiter((t.start for t in ts), np.float64, n),
            np.fromiter((t.end for t in ts), np.float64, n),
            np.fromiter((t.reduce for t in ts), np.bool_, n),
        )

    @classmethod
    def concat(cls, blocks: list) -> "TransferColumns":
        blocks = [b for b in blocks if len(b)]
        if not blocks:
            return cls.empty()
        if len(blocks) == 1:
            return blocks[0]
        return cls(*(np.concatenate([getattr(b, f) for b in blocks])
                     for f, _ in _COLUMN_DTYPES))

    # -- basics --------------------------------------------------------
    def __len__(self) -> int:
        return len(self.chunk)

    @property
    def nbytes(self) -> int:
        """Bytes held by the seven column arrays (the plan's working set)."""
        return sum(getattr(self, f).nbytes for f, _ in _COLUMN_DTYPES)

    def row(self, i: int) -> Transfer:
        return Transfer(int(self.chunk[i]), int(self.link[i]),
                        int(self.src[i]), int(self.dst[i]),
                        float(self.start[i]), float(self.end[i]),
                        bool(self.reduce[i]))

    # -- transforms (all pure) -----------------------------------------
    def take(self, order: np.ndarray, *,
             presorted: bool = False) -> "TransferColumns":
        return TransferColumns(*(getattr(self, f)[order]
                                 for f, _ in _COLUMN_DTYPES),
                               presorted=presorted)

    def sorted_schedule(self) -> "TransferColumns":
        """Rows in canonical ``(start, chunk, link)`` order — the same
        stable order ``sorted(key=attrgetter("start", "chunk", "link"))``
        produced on the object path (``np.lexsort`` is stable)."""
        if self.presorted or len(self) <= 1:
            self.presorted = True
            return self
        order = np.lexsort((self.link, self.chunk, self.start))
        if np.array_equal(order, np.arange(len(order))):
            self.presorted = True
            return self
        return self.take(order, presorted=True)

    def shifted(self, dt: float) -> "TransferColumns":
        if dt == 0.0:
            return self
        return TransferColumns(self.chunk, self.link, self.src, self.dst,
                               self.start + dt, self.end + dt, self.reduce,
                               presorted=self.presorted)

    def relabeled(self, node_map=None, link_map=None,
                  chunk_map=None, shift: float = 0.0) -> "TransferColumns":
        """Apply id translations (and an optional time shift) in one pass:
        `node_map`/`link_map` are dense old->new arrays or sequences,
        `chunk_map` a sparse dict (ids absent from it pass through)."""
        chunk = self.chunk if not chunk_map \
            else remap_ids(self.chunk, chunk_map)
        link, src, dst = self.link, self.src, self.dst
        if link_map is not None:
            link = np.asarray(link_map, np.int64)[link].astype(np.int32)
        if node_map is not None:
            nm = np.asarray(node_map, np.int64)
            src = nm[src].astype(np.int32)
            dst = nm[dst].astype(np.int32)
        start, end = self.start, self.end
        if shift != 0.0:
            start, end = start + shift, end + shift
        return TransferColumns(chunk, link, src, dst, start, end, self.reduce)

    def time_reversed(self, pivot: float) -> "TransferColumns":
        """The reversed-schedule transform behind Reduce-Scatter synthesis:
        every transfer flips direction, runs reduce-flagged in the mirrored
        window ``[pivot - end, pivot - start)``."""
        return TransferColumns(self.chunk, self.link, self.dst, self.src,
                               pivot - self.end, pivot - self.start,
                               np.ones(len(self), np.bool_))


class TransferList(Sequence):
    """Lazy ``Sequence[Transfer]`` view over :class:`TransferColumns`.

    Rows are materialized on access only; iteration materializes in
    blocks so per-row numpy scalar boxing stays off the hot path. Equality
    against another view compares the arrays (no objects built at all);
    equality against a plain list compares element-wise."""

    __slots__ = ("columns",)

    def __init__(self, columns: TransferColumns):
        self.columns = columns

    def __len__(self) -> int:
        return len(self.columns)

    def __getitem__(self, i):
        n = len(self.columns)
        if isinstance(i, slice):
            idx = range(*i.indices(n))
            return [self.columns.row(j) for j in idx]
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError("transfer index out of range")
        return self.columns.row(i)

    def __iter__(self):
        c = self.columns
        n = len(c)
        for lo in range(0, n, _ITER_BLOCK):
            hi = min(lo + _ITER_BLOCK, n)
            yield from map(Transfer,
                           c.chunk[lo:hi].tolist(), c.link[lo:hi].tolist(),
                           c.src[lo:hi].tolist(), c.dst[lo:hi].tolist(),
                           c.start[lo:hi].tolist(), c.end[lo:hi].tolist(),
                           c.reduce[lo:hi].tolist())

    def __eq__(self, other) -> bool:
        if isinstance(other, TransferList):
            a, b = self.columns, other.columns
            return len(a) == len(b) and all(
                np.array_equal(getattr(a, f), getattr(b, f))
                for f, _ in _COLUMN_DTYPES)
        if isinstance(other, (list, tuple)):
            return len(self) == len(other) and all(
                x == y for x, y in zip(self, other))
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    __hash__ = None

    def __add__(self, other):
        return list(self) + list(other)

    def __radd__(self, other):
        return list(other) + list(self)

    def __repr__(self) -> str:
        return f"TransferList(n={len(self)})"


def _as_columns(transfers) -> TransferColumns:
    if transfers is None:
        return TransferColumns.empty()
    if isinstance(transfers, TransferColumns):
        return transfers
    if isinstance(transfers, TransferList):
        return transfers.columns
    return TransferColumns.from_transfers(transfers)


class CollectiveAlgorithm:
    """The synthesis result for a set of conditions over a topology.

    ``transfers`` accepts a list of :class:`Transfer`, a
    :class:`TransferColumns`, or another algorithm's :class:`TransferList`;
    it is stored columnar (``self.columns``) in canonical schedule order
    and exposed back through the lazy ``transfers`` view.
    """

    __slots__ = ("topology", "conditions", "columns", "name", "phase_spans")

    def __init__(self, topology: Topology, conditions: list, transfers=None,
                 name: str = "pccl", phase_spans: list | None = None):
        self.topology = topology
        self.conditions = list(conditions)
        self.name = name
        # Phase provenance for composed algorithms (hierarchical / PhasePlan
        # synthesis): [(phase name, first start, last end)], in execution
        # order. Multi-level compositions record sub-phase provenance as
        # nested "parent/child" names (e.g. "intra:0/inter" — the
        # pod-boundary phase inside pod 0's recursive plan), whose windows
        # lie inside the parent's. Purely descriptive — validation and
        # replay never consult it.
        self.phase_spans = list(phase_spans) if phase_spans else []
        self.columns = _as_columns(transfers).sorted_schedule()

    @property
    def transfers(self) -> TransferList:
        return TransferList(self.columns)

    def __eq__(self, other) -> bool:
        if not isinstance(other, CollectiveAlgorithm):
            return NotImplemented
        return (self.topology == other.topology
                and self.conditions == other.conditions
                and self.transfers == other.transfers
                and self.name == other.name
                and self.phase_spans == other.phase_spans)

    __hash__ = None

    def __repr__(self) -> str:
        return (f"CollectiveAlgorithm(name={self.name!r}, "
                f"conditions={len(self.conditions)}, "
                f"transfers={len(self.columns)})")

    def top_phase_spans(self) -> list:
        """Top-level ``phase_spans`` entries only — nested sub-phase
        provenance (recorded as ``"parent/child"`` names by multi-level
        composition) filtered out."""
        return [s for s in self.phase_spans if "/" not in s[0]]

    @property
    def makespan(self) -> float:
        if not len(self.columns):
            return 0.0
        release = min((c.release for c in self.conditions), default=0.0)
        return float(self.columns.end.max()) - release

    @property
    def num_transfers(self) -> int:
        return len(self.columns)

    @property
    def plan_nbytes(self) -> int:
        """In-memory footprint of the columnar schedule."""
        return self.columns.nbytes

    def total_bytes_moved(self) -> float:
        cols = self.columns
        if not len(cols):
            return 0.0
        sizes = {c.chunk: c.bytes for c in self.conditions}
        ck = np.fromiter(sizes.keys(), np.int64, len(sizes))
        cb = np.fromiter(sizes.values(), np.float64, len(sizes))
        order = np.argsort(ck)
        ck, cb = ck[order], cb[order]
        return float(cb[np.searchsorted(ck, cols.chunk)].sum())

    def link_busy_time(self) -> dict[int, float]:
        cols = self.columns
        if not len(cols):
            return {}
        busy = np.zeros(self.topology.num_links, np.float64)
        np.add.at(busy, cols.link, cols.end - cols.start)
        present = np.unique(cols.link)
        return dict(zip(present.tolist(), busy[present].tolist()))

    def link_utilization(self) -> dict[int, float]:
        span = self.makespan or 1.0
        return {l: b / span for l, b in self.link_busy_time().items()}

    # ------------------------------------------------------------------
    # Validation oracle
    # ------------------------------------------------------------------
    def validate(self, mode: str = "auto") -> None:
        """Replay the schedule and check every synthesizer invariant.

        ``mode="auto"`` dispatches million-transfer schedules of the
        *unconstrained* class (every switch unlimited and
        multicast-capable; reductions in the in-forest normal form PCCL
        synthesizes) to a vectorized implementation of the same checks —
        identical accept/reject behavior, enforced by the differential
        tests in ``tests/test_validation_bulk.py`` — and everything else
        to the reference oracle. ``"oracle"``/``"bulk"`` force a path."""
        if mode not in ("auto", "oracle", "bulk"):
            raise ValueError(f"mode={mode!r} not in auto/oracle/bulk")
        if mode == "oracle":
            return self._validate_oracle()
        eligible = (
            len(self.columns) >= _VECTOR_SORT_MIN or mode == "bulk"
        ) and self._bulk_validatable()
        if mode == "bulk" and not eligible:
            raise ValueError(
                "bulk validation requires plain/reduce conditions and "
                "unconstrained switches"
            )
        if eligible:
            return self._validate_bulk()
        return self._validate_oracle()

    def _bulk_validatable(self) -> bool:
        if not all(n.buffer_limit is None and n.multicast
                   for n in self.topology.nodes):
            return False
        if not all(type(c) in (Condition, ReduceCondition)
                   for c in self.conditions):
            return False
        # reduce transfers must ride reduction chunks — a reduce-flagged
        # copy of a plain chunk is a nonstandard schedule the oracle judges
        # with its full replay, so keep it there
        cols = self.columns
        if not cols.reduce.any():
            return True
        rchunks = sorted(c.chunk for c in self.conditions
                         if type(c) is ReduceCondition)
        if not rchunks:
            return False
        rarr = np.asarray(rchunks, np.int64)
        rc = cols.chunk[cols.reduce]
        loc = np.minimum(np.searchsorted(rarr, rc), len(rarr) - 1)
        return bool((rarr[loc] == rc).all())

    def _validate_bulk(self) -> None:
        """Vectorized validation for schedules on unconstrained fabrics.
        Check-for-check equivalent to the oracle: link endpoints and
        alpha-beta durations, adjacent-interval congestion per link, release
        bounds, store-and-forward causality (a chunk departs a node only
        at/after its earliest arrival there), and post-condition delivery.

        Reduction schedules are checked against the in-forest normal form
        every PCCL reduction synthesizes (flat reversed-gather and
        hierarchical phase-composed alike): per chunk, reduce transfers form
        an in-forest in which each device forwards its accumulated partial
        at most once and only after every partial merged into it arrived;
        all chains terminate at a single root, where the full contribution
        set assembles; plain copies of the chunk flow only from that root,
        no earlier than assembly. Within that class the verdict matches the
        oracle's replay (each contribution delivered exactly once, no
        partial-state copies). A schedule outside the normal form — e.g. a
        hand-written one that reduce-forwards an already-assembled chunk —
        is handed to the oracle for the final verdict instead of being
        rejected structurally, so ``validate`` returns the same answer at
        every size and through every mode."""
        topo = self.topology
        ts = self.transfers
        conds = self.conditions
        cols = self.columns
        n = len(cols)
        chunk, link = cols.chunk, cols.link
        src, dst = cols.src, cols.dst
        start, end, red = cols.start, cols.end, cols.reduce

        if n and (link.min() < 0 or link.max() >= topo.num_links):
            raise AssertionError("transfer references unknown link")
        lsrc = np.fromiter((l.src for l in topo.links), np.int64,
                           topo.num_links)
        ldst = np.fromiter((l.dst for l in topo.links), np.int64,
                           topo.num_links)
        bad = (lsrc[link] != src) | (ldst[link] != dst)
        if bad.any():
            raise AssertionError(
                f"{ts[int(bad.argmax())]} does not ride its link")

        cchunk = np.fromiter((c.chunk for c in conds), np.int64, len(conds))
        uchunks, cidx = np.unique(cchunk, return_index=True)
        if len(uchunks) != len(conds):
            raise AssertionError("duplicate chunk id in conditions")
        pos = np.searchsorted(uchunks, chunk)
        if n and ((pos >= len(uchunks)) | (uchunks[np.minimum(
                pos, len(uchunks) - 1)] != chunk)).any():
            raise AssertionError("transfer moves unknown chunk")
        csize = np.fromiter((c.bytes for c in conds), float, len(conds))
        crel = np.fromiter((c.release for c in conds), float, len(conds))
        sizes = csize[cidx][pos] if n else csize[:0]
        rel = crel[cidx][pos] if n else crel[:0]

        alpha = np.fromiter((l.alpha for l in topo.links), float,
                            topo.num_links)
        beta = np.fromiter((l.beta for l in topo.links), float,
                           topo.num_links)
        want = alpha[link] + sizes * beta[link]
        bad = np.abs((end - start) - want) > _EPS
        if bad.any():
            k = int(bad.argmax())
            raise AssertionError(
                f"{ts[k]}: duration {end[k] - start[k]} != alpha-beta "
                f"time {want[k]}")

        # congestion: per link, adjacent intervals in start order
        order = np.lexsort((start, link))
        ls, ss, es = link[order], start[order], end[order]
        same = ls[1:] == ls[:-1]
        overlap = same & (ss[1:] < es[:-1] - _EPS) & (ss[:-1] < es[1:] - _EPS)
        if overlap.any():
            k = int(overlap.argmax())
            raise AssertionError(
                f"congestion on link {ls[k]}: {ts[int(order[k])]} vs "
                f"{ts[int(order[k + 1])]}")

        if (start < rel - _EPS).any():
            k = int((start < rel - _EPS).argmax())
            raise AssertionError(f"{ts[k]}: starts before chunk release")

        nn = topo.num_nodes
        # per-upos condition views (uchunks[j] is the chunk of conds[cidx[j]])
        is_rc_u = np.fromiter(
            (type(conds[i]) is ReduceCondition for i in cidx), bool,
            len(cidx))
        origin_u = np.fromiter(
            (getattr(conds[i], "src", -1) for i in cidx), np.int64, len(cidx))
        rel_u = crel[cidx]
        rel_eff_u = rel_u

        # -- reduction algebra: in-forest per chunk -------------------------
        if is_rc_u.any():
            try:
                origin_u, rel_eff_u = self._bulk_reduce_structure(
                    conds, cidx, uchunks, is_rc_u, origin_u, rel_u,
                    pos, src, dst, start, end, red, nn)
            except _NotInForest:
                # outside the normal form PCCL synthesizes: the structural
                # shortcut does not apply, so the reference replay decides
                return self._validate_oracle()

        # earliest copy arrival per (chunk, node), origins at release (for
        # reduced chunks: at the root, at assembly time)
        cp = np.nonzero(~red)[0]
        akey = (pos * nn + dst)[cp]
        ukey, inv = np.unique(akey, return_inverse=True)
        amin = np.full(len(ukey), np.inf)
        np.minimum.at(amin, inv, end[cp])

        if len(cp):
            origin_t = origin_u[pos[cp]]
            rel_eff_t = rel_eff_u[pos[cp]]
            skey2 = (pos * nn + src)[cp]
            if len(ukey):
                sloc = np.minimum(np.searchsorted(ukey, skey2),
                                  len(ukey) - 1)
                found = ukey[sloc] == skey2
                arr = np.where(found, amin[sloc], np.inf)
            else:
                arr = np.full(len(cp), np.inf)
            arr = np.where(src[cp] == origin_t,
                           np.minimum(arr, rel_eff_t), arr)
            bad = start[cp] < arr - _EPS
            if bad.any():
                # a "bad" copy of a reduced chunk may be legal outside the
                # normal form (a mid-chain node that assembled the full set
                # may copy it onward) — the oracle decides those; a bad copy
                # of a plain chunk is a definite causality violation
                bad_plain = bad & ~is_rc_u[pos[cp]]
                if not bad_plain.any():
                    return self._validate_oracle()
                k = int(cp[int(bad_plain.argmax())])
                a = arr[int(bad_plain.argmax())]
                raise AssertionError(
                    f"{ts[k]}: departs before chunk arrived "
                    f"(arr={a if np.isfinite(a) else None})")

        # post-conditions: every destination reached (or holds from origin /
        # is the assembly root)
        pk, pd = [], []
        for ci, c in enumerate(conds):
            for d in c.dests:
                pk.append(ci)
                pd.append(d)
        pk = np.asarray(pk, np.int64)
        pd = np.asarray(pd, np.int64)
        cond_upos = np.searchsorted(uchunks, cchunk)
        got = pd == origin_u[cond_upos[pk]]
        if len(ukey):
            dkey = cond_upos[pk] * nn + pd
            dloc = np.minimum(np.searchsorted(ukey, dkey), len(ukey) - 1)
            got |= ukey[dloc] == dkey
        if not got.all():
            # an unreached dest of a reduced chunk may still hold the full
            # set outside the normal form (an interior forest node that
            # assembled it before forwarding) — defer those to the oracle;
            # a missing plain-chunk delivery is definite
            miss_plain = ~got & ~is_rc_u[cond_upos[pk]]
            if not miss_plain.any():
                return self._validate_oracle()
            k = int(miss_plain.argmax())
            raise AssertionError(
                f"chunk {conds[pk[k]].chunk} never reached NPU {pd[k]}")

    @staticmethod
    def _bulk_reduce_structure(conds, cidx, uchunks, is_rc_u, origin_u,
                               rel_u, pos, src, dst, start, end, red, nn):
        """Verify the in-forest normal form of the reduce transfers and
        return the effective (origin, release) per chunk for the copy-phase
        checks: per reduce chunk, its single assembly root and the time the
        full contribution set assembles there. Raises :class:`_NotInForest`
        when the schedule is outside the normal form — the caller then hands
        the verdict to the reference oracle."""
        su, sn = [], []
        for j, ci in enumerate(cidx):
            c = conds[ci]
            if type(c) is ReduceCondition:
                for s in c.srcs:
                    su.append(j)
                    sn.append(s)
        skey = np.asarray(su, np.int64) * nn + np.asarray(sn, np.int64)
        skey.sort()

        ridx = np.nonzero(red)[0]
        rpos, rsrc, rdst = pos[ridx], src[ridx], dst[ridx]
        rstart, rend = start[ridx], end[ridx]
        if len(ridx) and not is_rc_u[rpos].all():
            raise _NotInForest("reduce transfer on a non-reduction chunk")
        # each device forwards its accumulated partial at most once
        okey = rpos * nn + rsrc
        u_out, out_counts = np.unique(okey, return_counts=True)
        if (out_counts > 1).any():
            raise _NotInForest("a node forwards its partial twice")
        # latest merged-partial arrival per (chunk, node)
        ikey = rpos * nn + rdst
        u_in, inv_in = np.unique(ikey, return_inverse=True)
        in_max = np.full(len(u_in), -np.inf)
        np.maximum.at(in_max, inv_in, rend)
        if len(u_in):
            loc = np.minimum(np.searchsorted(u_in, okey), len(u_in) - 1)
            has_in = u_in[loc] == okey
            need = np.where(has_in, in_max[loc], -np.inf)
        else:
            has_in = np.zeros(len(okey), bool)
            need = np.full(len(okey), -np.inf)
        if (rstart < need - _EPS).any():
            raise _NotInForest("a partial forwards before every merged "
                               "contribution arrived")
        # senders that merged nothing must be declared contributors
        if len(skey):
            loc = np.minimum(np.searchsorted(skey, okey), len(skey) - 1)
            is_src_sender = skey[loc] == okey
        else:
            is_src_sender = np.zeros(len(okey), bool)
        if (~has_in & ~is_src_sender).any():
            raise _NotInForest("a reduce sender holds no contribution")
        # every participant (contributor or merge point) forwards except
        # exactly one root per chunk, where the full set assembles;
        # acyclicity comes from the arrival-before-forward check above
        pkeys = np.union1d(skey, u_in)
        if len(u_out):
            loc = np.minimum(np.searchsorted(u_out, pkeys), len(u_out) - 1)
            has_out = u_out[loc] == pkeys
        else:
            has_out = np.zeros(len(pkeys), bool)
        roots = pkeys[~has_out]
        root_upos = roots // nn
        counts = np.zeros(len(uchunks), np.int64)
        np.add.at(counts, root_upos, 1)
        if (is_rc_u & (counts != 1)).any():
            raise _NotInForest("contributions do not assemble at a single "
                               "root")
        root_node = np.full(len(uchunks), -1, np.int64)
        root_node[root_upos] = roots % nn
        assembled = rel_u.copy()
        if len(u_in):
            loc = np.minimum(np.searchsorted(u_in, roots), len(u_in) - 1)
            found = u_in[loc] == roots
            assembled[root_upos] = np.maximum(
                assembled[root_upos],
                np.where(found, in_max[loc], -np.inf))
        # copies of a reduced chunk originate at its root, post-assembly
        return (np.where(is_rc_u, root_node, origin_u),
                np.where(is_rc_u, assembled, rel_u))

    def _validate_oracle(self) -> None:
        topo = self.topology
        sizes = {c.chunk: c.bytes for c in self.conditions}
        releases = {c.chunk: c.release for c in self.conditions}

        # 1. Link-level checks: existence, duration, congestion-freedom.
        by_link: dict[int, list[Transfer]] = defaultdict(list)
        for t in self.transfers:
            link = topo.links[t.link]
            if (link.src, link.dst) != (t.src, t.dst):
                raise AssertionError(f"{t} does not ride link {link}")
            want = link.transfer_time(sizes[t.chunk])
            if abs((t.end - t.start) - want) > _EPS:
                raise AssertionError(
                    f"{t}: duration {t.end - t.start} != alpha-beta time {want}"
                )
            by_link[t.link].append(t)
        for link_id, ts in by_link.items():
            ts.sort(key=lambda t: t.start)
            for a, b in zip(ts, ts[1:]):
                if a.overlaps(b):
                    raise AssertionError(f"congestion on link {link_id}: {a} vs {b}")

        # 2. Replay: presence/causality/switch constraints/reduction algebra.
        # holdings[node][chunk] = frozenset of contributions (presence for
        # plain chunks is the singleton {src}).
        holdings: dict[int, dict[int, frozenset[int]]] = defaultdict(dict)
        sent_reduce: set[tuple[int, int]] = set()  # (node, chunk) partial already sent
        full_sets: dict[int, frozenset[int]] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                full_sets[c.chunk] = c.srcs
                for s in c.srcs:
                    holdings[s][c.chunk] = frozenset([s])
            else:
                full_sets[c.chunk] = frozenset([c.src])
                holdings[c.src][c.chunk] = frozenset([c.src])

        # switch occupancy / multicast bookkeeping:
        # residency of (switch, chunk) = [arrival end, last outgoing send end]
        switch_arrive: dict[tuple[int, int], float] = {}
        switch_depart: dict[tuple[int, int], float] = {}
        switch_sends: dict[int, list[Transfer]] = defaultdict(list)

        for t in self.transfers:
            held = holdings[t.src].get(t.chunk)
            if held is None:
                raise AssertionError(f"{t}: sender does not hold chunk")
            if t.start < releases[t.chunk] - _EPS:
                raise AssertionError(f"{t}: starts before chunk release")
            if t.reduce:
                if (t.src, t.chunk) in sent_reduce:
                    raise AssertionError(f"{t}: node sent its partial twice")
                sent_reduce.add((t.src, t.chunk))
                prev = holdings[t.dst].get(t.chunk, frozenset())
                if prev & held:
                    raise AssertionError(
                        f"{t}: double-counted contributions {sorted(prev & held)}"
                    )
                holdings[t.dst][t.chunk] = prev | held
                # The partial leaves the sender (it must not contribute again);
                # keep it recorded for causality of later copies only if it is
                # the full set (i.e. sender was the reduction root).
                if held != full_sets[t.chunk]:
                    del holdings[t.src][t.chunk]
            else:
                if full_sets[t.chunk] != held:
                    # copying a partially-reduced chunk is a correctness bug
                    if len(full_sets[t.chunk]) > 1:
                        raise AssertionError(
                            f"{t}: copies partial reduction {sorted(held)}"
                        )
                holdings[t.dst][t.chunk] = held
            if topo.is_switch(t.src):
                switch_sends[t.src].append(t)
                key = (t.src, t.chunk)
                switch_depart[key] = max(switch_depart.get(key, 0.0), t.end)
            if topo.is_switch(t.dst):
                key = (t.dst, t.chunk)
                if key not in switch_arrive:
                    switch_arrive[key] = t.end

        # 2b. causality in time: arrival must precede departure. Replay above
        # processed transfers in start order; verify explicitly with times.
        arrival: dict[tuple[int, int], float] = {}
        for c in self.conditions:
            if isinstance(c, ReduceCondition):
                for s in c.srcs:
                    arrival[(s, c.chunk)] = c.release
            else:
                arrival[(c.src, c.chunk)] = c.release
        for t in self.transfers:
            a = arrival.get((t.src, t.chunk))
            if a is None or t.start < a - _EPS:
                raise AssertionError(f"{t}: departs before chunk arrived (arr={a})")
            prev = arrival.get((t.dst, t.chunk), float("inf"))
            arrival[(t.dst, t.chunk)] = min(prev, t.end)

        # 3. Switch constraints.
        for sw, sends in switch_sends.items():
            node = topo.nodes[sw]
            if not node.multicast:
                # a non-multicast switch cannot duplicate one chunk onto
                # several egress ports at once (paper §4.7); distinct chunks
                # may still flow through different ports concurrently.
                per_chunk: dict[int, list[Transfer]] = defaultdict(list)
                for t in sends:
                    per_chunk[t.chunk].append(t)
                for chunk, ts in per_chunk.items():
                    ts.sort(key=lambda t: t.start)
                    for a, b in zip(ts, ts[1:]):
                        if a.overlaps(b):
                            raise AssertionError(
                                f"non-multicast switch {sw} duplicates chunk "
                                f"{chunk} concurrently: {a} / {b}"
                            )
        residency: dict[int, list[tuple[float, float]]] = defaultdict(list)
        for (sw, chunk), arr in switch_arrive.items():
            dep = switch_depart.get((sw, chunk), arr)
            residency[sw].append((arr, max(dep, arr)))
        for sw, intervals in residency.items():
            limit = topo.nodes[sw].buffer_limit
            if limit is None:
                continue
            events = []
            for a, d in intervals:
                events.append((a, +1))
                events.append((d, -1))
            occ = 0
            # departures (-1) release the slot before same-instant arrivals
            for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
                occ += delta
                if occ > limit:
                    raise AssertionError(f"switch {sw} buffer exceeded ({occ} > {limit})")

        # 4. Post-conditions.
        for c in self.conditions:
            dests = c.dests
            for d in dests:
                got = holdings[d].get(c.chunk)
                if got is None:
                    raise AssertionError(f"chunk {c.chunk} never reached NPU {d}")
                if got != full_sets[c.chunk]:
                    raise AssertionError(
                        f"chunk {c.chunk} at NPU {d} has contributions "
                        f"{sorted(got)} != {sorted(full_sets[c.chunk])}"
                    )

    def is_valid(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False
