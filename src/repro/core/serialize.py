"""Columnar plan persistence: uncompressed ``.npz`` entries, mmap-loadable.

The registry's on-disk format is one ``.npz`` per canonical algorithm: the
seven transfer columns verbatim, the conditions flattened into parallel
arrays (ragged ``dests``/``srcs`` sets in CSR ``flat + indptr`` form), and
the phase-span provenance. Entries are written uncompressed, so a load can
``mmap`` the file and hand the kernel-backed pages straight to numpy — no
parse, no per-row objects, and nothing is faulted in until a consumer
actually touches a column. A 4 M-transfer plan "loads" in the time it takes
to read the zip directory.

``np.load(mmap_mode=...)`` silently ignores mmap for ``.npz`` archives, so
the loader walks the zip members itself: for each ZIP_STORED entry it reads
the local file header to find the data offset, parses the ``.npy`` header,
and builds the array with ``np.frombuffer`` over one shared ``mmap``. The
resulting arrays are read-only — which is exactly the columnar contract
(:class:`~repro.core.algorithm.TransferColumns` never mutates in place).

Malformed files of any kind — truncated zip, wrong dtype, mismatched column
lengths, foreign topology fingerprint — raise ``ValueError`` so the registry
can drop the entry and resynthesize.
"""

from __future__ import annotations

import io
import mmap
import os
import zipfile

import numpy as np

from repro.core.algorithm import CollectiveAlgorithm, TransferColumns
from repro.core.conditions import Condition, ReduceCondition
from repro.topology.topology import Topology

# On-disk plan schema. v1: transfer columns + CSR conditions + phase spans.
PLAN_NPZ_VERSION = 1

# column name -> required on-disk dtype; anything else is a corrupt entry
_TRANSFER_FIELDS = {
    "t_chunk": np.dtype(np.int64),
    "t_link": np.dtype(np.int32),
    "t_src": np.dtype(np.int32),
    "t_dst": np.dtype(np.int32),
    "t_start": np.dtype(np.float64),
    "t_end": np.dtype(np.float64),
    "t_reduce": np.dtype(np.bool_),
}
_COND_FIELDS = {
    "c_chunk": np.dtype(np.int64),
    "c_bytes": np.dtype(np.float64),
    "c_release": np.dtype(np.float64),
    "c_is_reduce": np.dtype(np.bool_),
    "c_origin": np.dtype(np.int64),
    "c_dests_flat": np.dtype(np.int64),
    "c_dests_indptr": np.dtype(np.int64),
    "c_srcs_flat": np.dtype(np.int64),
    "c_srcs_indptr": np.dtype(np.int64),
}


def _csr(sets: list) -> tuple[np.ndarray, np.ndarray]:
    indptr = np.zeros(len(sets) + 1, np.int64)
    for i, s in enumerate(sets):
        indptr[i + 1] = indptr[i] + len(s)
    flat = np.fromiter((x for s in sets for x in s), np.int64,
                       int(indptr[-1]))
    return flat, indptr


def save_plan_npz(path: str, alg: CollectiveAlgorithm,
                  fingerprint: str) -> None:
    """Write ``alg`` as an uncompressed npz at ``path`` (not atomic — the
    caller owns tmp-file + rename semantics). ``fingerprint`` is the
    topology structure hash the plan belongs to; loads verify it."""
    cols = alg.columns
    conds = alg.conditions
    # sorted(set) keeps the on-disk bytes deterministic; condition order
    # itself is preserved exactly (renumber_chunks allocates ids by it)
    dflat, dptr = _csr([sorted(c.dests) for c in conds])
    sflat, sptr = _csr([sorted(c.srcs) if isinstance(c, ReduceCondition)
                        else () for c in conds])
    nc = len(conds)
    spans = alg.phase_spans
    arrays = {
        "schema": np.array([PLAN_NPZ_VERSION], np.int64),
        "fingerprint": np.array([fingerprint]),
        "name": np.array([alg.name]),
        "t_chunk": cols.chunk, "t_link": cols.link,
        "t_src": cols.src, "t_dst": cols.dst,
        "t_start": cols.start, "t_end": cols.end, "t_reduce": cols.reduce,
        "c_chunk": np.fromiter((c.chunk for c in conds), np.int64, nc),
        "c_bytes": np.fromiter((c.bytes for c in conds), np.float64, nc),
        "c_release": np.fromiter((c.release for c in conds), np.float64, nc),
        "c_is_reduce": np.fromiter(
            (isinstance(c, ReduceCondition) for c in conds), np.bool_, nc),
        "c_origin": np.fromiter(
            (getattr(c, "src", -1) for c in conds), np.int64, nc),
        "c_tag": np.array([c.tag for c in conds]),
        "c_dests_flat": dflat, "c_dests_indptr": dptr,
        "c_srcs_flat": sflat, "c_srcs_indptr": sptr,
        "p_name": np.array([s[0] for s in spans]),
        "p_lo": np.array([float(s[1]) for s in spans], np.float64),
        "p_hi": np.array([float(s[2]) for s in spans], np.float64),
    }
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def _mmap_npz(path: str) -> dict[str, np.ndarray]:
    """Zero-copy view of every array in an uncompressed npz: one shared
    read-only mmap, ``np.frombuffer`` per member at its zip data offset.
    The mmap stays alive through the arrays' ``.base`` chain."""
    # one fd for both the zip directory and the data mmap: a concurrent
    # atomic replace of `path` cannot mix old offsets with new bytes, and
    # the mapping stays valid even if the entry is unlinked underneath us
    f = open(path, "rb")
    try:
        mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        with zipfile.ZipFile(f) as zf:
            infos = zf.infolist()
    finally:
        f.close()
    out: dict[str, np.ndarray] = {}
    for info in infos:
        if info.compress_type != zipfile.ZIP_STORED:
            raise ValueError(f"{info.filename}: compressed member in plan npz")
        ho = info.header_offset
        if mm[ho:ho + 4] != b"PK\x03\x04":
            raise ValueError(f"{info.filename}: bad local file header")
        name_len = int.from_bytes(mm[ho + 26:ho + 28], "little")
        extra_len = int.from_bytes(mm[ho + 28:ho + 30], "little")
        data_off = ho + 30 + name_len + extra_len
        hdr = io.BytesIO(mm[data_off:data_off + min(info.file_size, 4096)])
        version = np.lib.format.read_magic(hdr)
        if version == (1, 0):
            shape, fortran, dtype = np.lib.format.read_array_header_1_0(hdr)
        elif version == (2, 0):
            shape, fortran, dtype = np.lib.format.read_array_header_2_0(hdr)
        else:
            raise ValueError(f"{info.filename}: npy format {version}")
        if fortran or dtype.hasobject:
            raise ValueError(f"{info.filename}: unsupported npy layout")
        count = int(np.prod(shape)) if shape else 1
        arr = np.frombuffer(mm, dtype=dtype, count=count,
                            offset=data_off + hdr.tell())
        name = info.filename
        if name.endswith(".npy"):
            name = name[:-4]
        out[name] = arr.reshape(shape)
    return out


def load_plan_npz(path: str, topology: Topology, *,
                  use_mmap: bool = True) -> CollectiveAlgorithm:
    """Load a plan written by :func:`save_plan_npz` for ``topology``.

    With ``use_mmap`` (the default) the transfer columns are zero-copy
    views over the file — validated by metadata (dtype, shape, length
    consistency) only, so nothing large is faulted in at load time.
    Raises ``ValueError`` for any malformed or foreign entry."""
    try:
        if use_mmap:
            arrays = _mmap_npz(path)
        else:
            with np.load(path, allow_pickle=False) as npz:
                arrays = {k: npz[k] for k in npz.files}
    except OSError:
        raise
    except ValueError:
        raise
    except Exception as exc:  # zipfile/struct errors on garbage bytes
        raise ValueError(f"unreadable plan npz: {exc}") from exc

    def get(key: str, dtype: np.dtype | None = None) -> np.ndarray:
        if key not in arrays:
            raise ValueError(f"plan npz missing array {key!r}")
        arr = arrays[key]
        if arr.ndim != 1:
            raise ValueError(f"{key}: expected 1-d array, got {arr.shape}")
        if dtype is not None and arr.dtype != dtype:
            raise ValueError(f"{key}: dtype {arr.dtype} != {dtype}")
        return arr

    schema = get("schema", np.dtype(np.int64))
    if len(schema) != 1 or int(schema[0]) != PLAN_NPZ_VERSION:
        raise ValueError(f"plan npz schema {schema} != {PLAN_NPZ_VERSION}")
    fp = get("fingerprint")
    from repro.core.registry import topology_fingerprint
    if len(fp) != 1 or str(fp[0]) != topology_fingerprint(topology):
        raise ValueError("plan npz topology fingerprint mismatch")
    name_arr = get("name")
    if len(name_arr) != 1:
        raise ValueError("plan npz malformed name")

    tcols = {k: get(k, dt) for k, dt in _TRANSFER_FIELDS.items()}
    n = len(tcols["t_chunk"])
    if any(len(a) != n for a in tcols.values()):
        raise ValueError("plan npz transfer columns disagree on length")

    ccols = {k: get(k, dt) for k, dt in _COND_FIELDS.items()}
    ctag = get("c_tag")
    nc = len(ccols["c_chunk"])
    if any(len(ccols[k]) != nc for k in
           ("c_bytes", "c_release", "c_is_reduce", "c_origin")) \
            or len(ctag) != nc:
        raise ValueError("plan npz condition columns disagree on length")
    for flat, indptr in (("c_dests_flat", "c_dests_indptr"),
                         ("c_srcs_flat", "c_srcs_indptr")):
        ptr = ccols[indptr]
        if (len(ptr) != nc + 1 or (nc >= 0 and (len(ptr) == 0
                or ptr[0] != 0 or int(ptr[-1]) != len(ccols[flat])
                or (np.diff(ptr) < 0).any()))):
            raise ValueError(f"plan npz {indptr} is not a valid CSR index")

    pname = get("p_name")
    plo = get("p_lo", np.dtype(np.float64))
    phi = get("p_hi", np.dtype(np.float64))
    if len(plo) != len(pname) or len(phi) != len(pname):
        raise ValueError("plan npz phase spans disagree on length")

    conds: list = []
    dptr, dflat = ccols["c_dests_indptr"], ccols["c_dests_flat"]
    sptr, sflat = ccols["c_srcs_indptr"], ccols["c_srcs_flat"]
    for i in range(nc):
        dests = frozenset(dflat[int(dptr[i]):int(dptr[i + 1])].tolist())
        common = dict(chunk=int(ccols["c_chunk"][i]), dests=dests,
                      bytes=float(ccols["c_bytes"][i]),
                      release=float(ccols["c_release"][i]),
                      tag=str(ctag[i]))
        if bool(ccols["c_is_reduce"][i]):
            srcs = frozenset(sflat[int(sptr[i]):int(sptr[i + 1])].tolist())
            conds.append(ReduceCondition(srcs=srcs, **common))
        else:
            conds.append(Condition(src=int(ccols["c_origin"][i]), **common))

    cols = TransferColumns(
        tcols["t_chunk"], tcols["t_link"], tcols["t_src"], tcols["t_dst"],
        tcols["t_start"], tcols["t_end"], tcols["t_reduce"],
        presorted=True)
    spans = [(str(pname[i]), float(plo[i]), float(phi[i]))
             for i in range(len(pname))]
    return CollectiveAlgorithm(topology, conds, cols,
                               name=str(name_arr[0]), phase_spans=spans)


def plan_disk_bytes(path: str) -> int:
    try:
        return os.path.getsize(path)
    except OSError:
        return 0
