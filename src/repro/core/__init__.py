"""PCCL core: process group-aware collective algorithm synthesis (the paper's
contribution), plus the validation oracle, baselines, and the alpha-beta
simulator used for evaluation."""

from repro.core.algorithm import (
    CollectiveAlgorithm,
    Transfer,
    TransferColumns,
    TransferList,
)
from repro.core.conditions import (
    ChunkIds,
    Condition,
    ReduceCondition,
    all_gather,
    all_reduce,
    all_to_all,
    all_to_allv,
    broadcast,
    gather,
    multicast,
    point_to_point,
    reduce,
    reduce_scatter,
    scatter,
)
from repro.core.engine import PhasePlan, PhaseSpec, SynthesisEngine
from repro.core.errors import FabricDegradedError, PCCLError
from repro.core.hierarchy import HierarchicalSynthesizer, HierarchyError
from repro.core.repair import (
    DamageReport,
    DegradationEvent,
    PlanRepairer,
    RepairResult,
)
from repro.core.request import (
    CollectiveRequest,
    PCCLDeprecationWarning,
)
from repro.core.traffic import CommSketch, SketchInfeasibleError, \
    TrafficEngineer
from repro.core.registry import (
    AlgorithmRegistry,
    canonicalize_group,
    default_registry,
    enumerate_automorphisms,
    is_automorphism,
    relabel_algorithm,
    topology_fingerprint,
)
from repro.core.synthesizer import (
    order_conditions,
    synthesize,
    synthesize_all_gather,
    synthesize_all_reduce,
    synthesize_all_to_all,
    synthesize_joint,
    synthesize_reduce,
    synthesize_reduce_scatter,
)
from repro.core.simulator import (
    Flow,
    SimResult,
    collective_bandwidth,
    phase_breakdown,
    replay_algorithm,
    simulate_flows,
)
from repro.core.baselines import (
    direct_all_gather,
    direct_all_to_all,
    ring_all_gather,
    shortest_path_links,
)
from repro.core.translate import (
    PpermuteProgram,
    Send,
    from_msccl_json,
    to_msccl_json,
    to_ppermute_program,
)
from repro.core.planservice import PlanService
from repro.core.serialize import (
    load_plan_npz,
    plan_disk_bytes,
    save_plan_npz,
)

__all__ = [
    "CollectiveAlgorithm",
    "Transfer",
    "TransferColumns",
    "TransferList",
    "PlanService",
    "load_plan_npz",
    "plan_disk_bytes",
    "save_plan_npz",
    "SynthesisEngine",
    "PhasePlan",
    "PhaseSpec",
    "HierarchicalSynthesizer",
    "HierarchyError",
    "PCCLError",
    "FabricDegradedError",
    "CollectiveRequest",
    "PCCLDeprecationWarning",
    "DamageReport",
    "DegradationEvent",
    "PlanRepairer",
    "RepairResult",
    "CommSketch",
    "SketchInfeasibleError",
    "TrafficEngineer",
    "AlgorithmRegistry",
    "canonicalize_group",
    "default_registry",
    "enumerate_automorphisms",
    "is_automorphism",
    "relabel_algorithm",
    "topology_fingerprint",
    "ChunkIds",
    "Condition",
    "ReduceCondition",
    "all_gather",
    "all_reduce",
    "all_to_all",
    "all_to_allv",
    "broadcast",
    "gather",
    "multicast",
    "point_to_point",
    "reduce",
    "reduce_scatter",
    "scatter",
    "order_conditions",
    "synthesize",
    "synthesize_all_gather",
    "synthesize_all_reduce",
    "synthesize_all_to_all",
    "synthesize_joint",
    "synthesize_reduce",
    "synthesize_reduce_scatter",
    "Flow",
    "SimResult",
    "collective_bandwidth",
    "phase_breakdown",
    "replay_algorithm",
    "simulate_flows",
    "direct_all_gather",
    "direct_all_to_all",
    "ring_all_gather",
    "shortest_path_links",
    "PpermuteProgram",
    "Send",
    "from_msccl_json",
    "to_msccl_json",
    "to_ppermute_program",
]
