"""AlgorithmRegistry: fingerprinted cache of synthesized collective algorithms.

Production pods re-synthesize the *same* collectives over and over: every
data-parallel row of a (data, model) mesh is an isomorphic process group, yet
each ``synthesize_all_gather(topo, row_i)`` call used to redo the full
TEN/BFS work. The registry makes synthesized algorithms first-class,
canonicalized, cached artifacts:

* **Fingerprint** — ``(topology structure hash, collective kind, canonical
  process group, bytes/chunking params)``.
* **Canonicalization** — the process group is relabeled through a *verified*
  topology automorphism into a normal form (the lexicographically smallest
  image over the enumerated symmetry group), so all 16 rows of a 16x16 torus
  share one cached plan. Every candidate permutation is checked against the
  link/node structure before use: a wrong symmetry generator can only reduce
  sharing, never produce an invalid algorithm.
* **Lookup** — a cache hit relabels the stored canonical algorithm back
  through the inverse automorphism (nodes, link ids, and chunk ids), which is
  O(transfers) instead of O(BFS * conditions). Relabeled algorithms have the
  same makespan and pass the full validation oracle.
* **Persistence** — in-memory LRU, plus optional on-disk binary plans
  (uncompressed ``.npz``, mmap-loaded zero-copy by ``core.serialize``) so a
  pod restart reuses plans synthesized by a previous job. Legacy ``.json``
  entries (the ``to_msccl_json`` schema) are still read and migrated to npz
  in place. Writes are atomic (tmp file + rename), so any number of
  registries — across threads *and* processes — can share one
  ``PCCL_CACHE_DIR``: readers only ever see complete entries, and a stale
  or corrupt entry is dropped and resynthesized.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from collections import Counter, OrderedDict
from dataclasses import dataclass, replace
from typing import Callable, Sequence

from repro.core.algorithm import (CollectiveAlgorithm, TransferColumns,
                                  remap_ids)
from repro.core.conditions import ChunkIds, ReduceCondition
from repro.topology.topology import Topology

# bound on the enumerated symmetry group (torus2d 16x16 translations = 256;
# the cap only matters for pathological generator sets)
_MAX_AUTOMORPHISMS = 4096

# Cache-key schema version, part of every fingerprint (memory and disk).
# Bump whenever the synthesis core changes in a way that could alter emitted
# schedules, so plans cached by an older core are never served by a newer
# one. v2: array-backed TEN + batched-frontier BFS core. v3: recursive
# multi-level hierarchy — hierarchical route/phase params now carry the
# partition-tree fingerprint, and pod phases on nested-partitioned
# sub-topologies synthesize recursively. v4: inter-pod traffic engineering
# — hierarchical route and hier:* phase params now carry the resolved
# gateway strategy and the CommSketch fingerprint. v5: chunk-granular
# cross-phase pipelining — the hierarchical All-Reduce junction and the
# pipelined scatter route are per-chunk released, and uniform-release
# phases are cached canonically (release-stripped); a v4 barrier plan and
# a v5 pipelined plan for the same key are different schedules, so entries
# must never cross-serve.
SCHEMA_VERSION = 5


# ---------------------------------------------------------------------------
# Topology structure hashing and automorphism handling
# ---------------------------------------------------------------------------

def topology_fingerprint(topo: Topology) -> str:
    """Hash of the labeled topology structure (nodes, attrs, links, timing).

    Name-independent: two generator calls producing the same graph hash
    equal, so registries persist across processes that rebuild the fabric.
    """
    cached = getattr(topo, "_structure_hash", None)
    if cached is not None:
        return cached
    h = hashlib.sha256()
    for n in topo.nodes:
        h.update(repr((n.type.value, n.buffer_limit, n.multicast)).encode())
    for l in topo.links:
        h.update(repr((l.src, l.dst, l.alpha, l.beta)).encode())
    digest = h.hexdigest()
    topo._structure_hash = digest
    return digest


def is_automorphism(topo: Topology, perm: Sequence[int]) -> bool:
    """Verify ``perm`` maps the topology onto itself: node attributes are
    preserved and the multiset of (src, dst, alpha, beta) link signatures is
    invariant. This is the safety gate for cache sharing."""
    n = topo.num_nodes
    if len(perm) != n or sorted(perm) != list(range(n)):
        return False
    for node in topo.nodes:
        img = topo.nodes[perm[node.id]]
        if (node.type, node.buffer_limit, node.multicast) != (
                img.type, img.buffer_limit, img.multicast):
            return False
    orig = Counter((l.src, l.dst, l.alpha, l.beta) for l in topo.links)
    mapped = Counter(
        (perm[l.src], perm[l.dst], l.alpha, l.beta) for l in topo.links
    )
    return orig == mapped


def _compose(p: tuple[int, ...], q: tuple[int, ...]) -> tuple[int, ...]:
    """(p ∘ q)(i) = p[q[i]]."""
    return tuple(p[x] for x in q)


def enumerate_automorphisms(
    topo: Topology, limit: int = _MAX_AUTOMORPHISMS
) -> list[tuple[int, ...]]:
    """Closure of the topology's declared (and verified) symmetry generators,
    including the identity. Cached on the topology object."""
    cached = getattr(topo, "_automorphism_closure", None)
    if cached is not None:
        return cached
    identity = tuple(range(topo.num_nodes))
    gens = [
        tuple(g) for g in getattr(topo, "automorphism_generators", [])
        if is_automorphism(topo, g)
    ]
    closure = {identity}
    frontier = [identity]
    while frontier and len(closure) < limit:
        nxt = []
        for p in frontier:
            for g in gens:
                q = _compose(g, p)
                if q not in closure:
                    closure.add(q)
                    nxt.append(q)
                    if len(closure) >= limit:
                        break
            if len(closure) >= limit:
                break
        frontier = nxt
    result = sorted(closure)
    topo._automorphism_closure = result
    return result


def canonicalize_group(
    topo: Topology, group: Sequence[int]
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Return ``(canonical_group, perm)`` where ``perm`` is a verified
    automorphism and ``canonical_group[i] == perm[group[i]]`` is the
    lexicographically smallest image of the (ordered) group over the
    topology's enumerated symmetries. Isomorphic process groups — e.g. the
    rows of a torus — share one canonical form."""
    group = list(group)
    best_perm = tuple(range(topo.num_nodes))
    best = tuple(group)
    for perm in enumerate_automorphisms(topo):
        img = tuple(perm[g] for g in group)
        if img < best:
            best, best_perm = img, perm
    return best, best_perm


def invert_permutation(perm: Sequence[int]) -> tuple[int, ...]:
    inv = [0] * len(perm)
    for i, p in enumerate(perm):
        inv[p] = i
    return tuple(inv)


# ---------------------------------------------------------------------------
# Algorithm relabeling
# ---------------------------------------------------------------------------

def _link_map(topo: Topology, node_map: Sequence[int]) -> list[int]:
    """Induced bijection on link ids for an automorphism ``node_map``.

    Parallel links with identical (src, dst, alpha, beta) are matched by
    ordinal, which is a consistent bijection because their attributes are
    interchangeable."""
    by_sig: dict[tuple, list[int]] = {}
    for l in topo.links:
        by_sig.setdefault((l.src, l.dst, l.alpha, l.beta), []).append(l.id)
    mapped = [0] * topo.num_links
    ordinal: dict[tuple, int] = {}
    for l in topo.links:
        sig = (l.src, l.dst, l.alpha, l.beta)
        k = ordinal.get(sig, 0)
        ordinal[sig] = k + 1
        target_sig = (node_map[l.src], node_map[l.dst], l.alpha, l.beta)
        mapped[l.id] = by_sig[target_sig][k]
    return mapped


def relabel_algorithm(
    alg: CollectiveAlgorithm,
    node_map: Sequence[int],
    *,
    chunk_map: dict[int, int] | None = None,
) -> CollectiveAlgorithm:
    """Relabel an algorithm through a topology automorphism (and optionally a
    chunk-id remap). Transfer times are untouched, so the makespan — and
    every validator invariant — is preserved by construction."""
    topo = alg.topology
    links = _link_map(topo, node_map)
    cm = chunk_map or {}

    def ch(c: int) -> int:
        return cm.get(c, c)

    conds = []
    for c in alg.conditions:
        if isinstance(c, ReduceCondition):
            conds.append(replace(
                c, chunk=ch(c.chunk),
                srcs=frozenset(node_map[s] for s in c.srcs),
                dests=frozenset(node_map[d] for d in c.dests),
            ))
        else:
            conds.append(replace(
                c, chunk=ch(c.chunk), src=node_map[c.src],
                dests=frozenset(node_map[d] for d in c.dests),
            ))
    cols = alg.columns.relabeled(node_map=node_map, link_map=links,
                                 chunk_map=cm)
    return CollectiveAlgorithm(topo, conds, cols, name=alg.name,
                               phase_spans=list(alg.phase_spans))


def renumber_chunks(
    alg: CollectiveAlgorithm, ids: ChunkIds | None
) -> CollectiveAlgorithm:
    """Remap chunk ids through the caller's allocator (condition order), so
    registry-returned algorithms compose with joint synthesis."""
    if ids is None:
        return alg
    mapping = {c.chunk: ids.next() for c in alg.conditions}
    if all(k == v for k, v in mapping.items()):
        return alg
    conds = [replace(c, chunk=mapping[c.chunk]) for c in alg.conditions]
    c = alg.columns
    cols = TransferColumns(remap_ids(c.chunk, mapping), c.link, c.src,
                           c.dst, c.start, c.end, c.reduce)
    return CollectiveAlgorithm(alg.topology, conds, cols, name=alg.name,
                               phase_spans=list(alg.phase_spans))


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

@dataclass
class RegistryStats:
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    evictions: int = 0
    bytes_loaded: int = 0  # on-disk bytes of entries served from the cache dir
    bytes_stored: int = 0  # on-disk bytes written for fresh syntheses
    disk_evictions: int = 0  # entries removed by the size-capped disk LRU
    disk_bytes: int = 0  # cache-dir size after the last store/evict sweep

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "disk_hits": self.disk_hits, "evictions": self.evictions,
                "bytes_loaded": self.bytes_loaded,
                "bytes_stored": self.bytes_stored,
                "disk_evictions": self.disk_evictions,
                "disk_bytes": self.disk_bytes}


class AlgorithmRegistry:
    """LRU cache of canonical synthesized algorithms, keyed by fingerprint.

    ``get_or_synthesize`` is the single entry point: it canonicalizes the
    process group, consults memory then disk, synthesizes on the canonical
    labels only on a true miss, and relabels the result back to the caller's
    group. Lookups are serialized on an internal lock, so one registry can
    be shared across threads (the plan service's ``warm()`` workers rely on
    this); the on-disk side is safe across *processes* as well — writes are
    atomic renames, and corrupt/partial entries are dropped + resynthesized.
    """

    def __init__(self, max_entries: int = 256, cache_dir: str | None = None,
                 max_disk_bytes: int | None = None):
        self.max_entries = max_entries
        self.cache_dir = cache_dir
        if max_disk_bytes is None:
            env = os.environ.get("PCCL_CACHE_MAX_BYTES", "").strip()
            if env:
                try:
                    max_disk_bytes = int(env)
                except ValueError:
                    max_disk_bytes = None
        self.max_disk_bytes = max_disk_bytes
        self.stats = RegistryStats()
        self._lru: OrderedDict[tuple, CollectiveAlgorithm] = OrderedDict()
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self.stats = RegistryStats()

    # -- key construction ---------------------------------------------------

    @staticmethod
    def _key(topo: Topology, kind: str, canon: tuple[int, ...],
             params: tuple) -> tuple:
        return (SCHEMA_VERSION, topology_fingerprint(topo), kind, canon,
                params)

    @staticmethod
    def fingerprint(topo: Topology, kind: str, group: Sequence[int],
                    params: tuple = ()) -> str:
        """Stable hex fingerprint of a canonicalized request (also the
        on-disk file stem)."""
        canon, _ = canonicalize_group(topo, group)
        key = AlgorithmRegistry._key(topo, kind, canon, params)
        return hashlib.sha256(repr(key).encode()).hexdigest()

    # -- disk persistence ---------------------------------------------------

    def _disk_path(self, key: tuple) -> str | None:
        if self.cache_dir is None:
            return None
        stem = hashlib.sha256(repr(key).encode()).hexdigest()
        return os.path.join(self.cache_dir, f"{stem}.npz")

    # -- disk-tier LRU eviction ---------------------------------------------
    #
    # A shared PCCL_CACHE_DIR grows without bound as fabrics and schema
    # versions churn, so the disk tier is size-capped (``max_disk_bytes`` /
    # ``PCCL_CACHE_MAX_BYTES``): every load and store stamps the entry's
    # access time into a manifest (atomic rename, last writer wins —
    # approximate LRU is all eviction needs), and each store sweeps the
    # directory, removing the stalest entries until the cap holds. The
    # sweep is safe under concurrent readers and a churning writer: a file
    # another process already evicted is simply skipped, a reader that
    # loses a race re-synthesizes (the registry already tolerates missing
    # entries), and the manifest tolerates corruption by rebuilding.

    def _manifest_path(self) -> str:
        return os.path.join(self.cache_dir, "manifest.json")

    def _read_manifest(self) -> dict[str, float]:
        try:
            with open(self._manifest_path(), encoding="utf-8") as f:
                man = json.load(f)
            return {str(k): float(v) for k, v in man.items()}
        except (OSError, ValueError, TypeError):
            # missing (fresh dir) or corrupt (killed writer): entries
            # unknown to the manifest rank oldest, so a rebuilt manifest
            # only makes eviction more conservative, never wrong
            return {}

    def _write_manifest(self, man: dict[str, float]) -> None:
        mf = self._manifest_path()
        tmp = f"{mf}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(man, f)
            os.replace(tmp, mf)
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass

    def _touch_manifest(self, path: str) -> None:
        """Stamp ``path``'s access time into the shared manifest."""
        if self.cache_dir is None or self.max_disk_bytes is None:
            return
        man = self._read_manifest()
        man[os.path.basename(path)] = time.time()
        self._write_manifest(man)

    def _evict_disk(self, keep: str | None = None) -> None:
        """Sweep the cache dir down to ``max_disk_bytes``, stalest-first
        by manifest access time (``keep`` — the entry just written — is
        never evicted). Missing files are tolerated: another process may
        have evicted them first."""
        cap = self.max_disk_bytes
        if cap is None or self.cache_dir is None:
            return
        try:
            names = [n for n in os.listdir(self.cache_dir)
                     if n.endswith(".npz")]
        except OSError:
            return
        sizes: dict[str, int] = {}
        total = 0
        for n in names:
            try:
                sz = os.path.getsize(os.path.join(self.cache_dir, n))
            except OSError:
                continue  # evicted under our feet
            sizes[n] = sz
            total += sz
        man = self._read_manifest()
        if total > cap:
            for n in sorted(sizes, key=lambda n: (man.get(n, 0.0), n)):
                if total <= cap:
                    break
                if n == keep:
                    continue
                try:
                    os.remove(os.path.join(self.cache_dir, n))
                except OSError:
                    pass  # a concurrent evictor got there first
                total -= sizes[n]
                man.pop(n, None)
                self.stats.disk_evictions += 1
            self._write_manifest(man)
        self.stats.disk_bytes = total

    def _load_disk(self, key: tuple, topo: Topology) -> CollectiveAlgorithm | None:
        path = self._disk_path(key)
        if path is None:
            return None
        if os.path.exists(path):
            from repro.core.serialize import load_plan_npz

            try:
                nbytes = os.path.getsize(path)
                alg = load_plan_npz(path, topo)
                self.stats.bytes_loaded += nbytes
                self._touch_manifest(path)
                return alg
            except (OSError, ValueError, KeyError, TypeError, AttributeError,
                    IndexError):
                # Corrupt, truncated, or wrong-shape entry (a half-written
                # file from a killed process, bit rot, a hand-edited file):
                # never fail the lookup — drop the bad entry so the fresh
                # plan replaces it, and resynthesize.
                try:
                    os.remove(path)
                except OSError:
                    pass
                return None
        return self._load_legacy_json(key, topo)

    def _load_legacy_json(self, key: tuple,
                          topo: Topology) -> CollectiveAlgorithm | None:
        """Back-compat import of a pre-npz ``.json`` entry; on success the
        plan is re-stored as npz and the JSON file retired (one-way
        migration)."""
        path = self._disk_path(key)
        jpath = path[:-len(".npz")] + ".json" if path else None
        if jpath is None or not os.path.exists(jpath):
            return None
        from repro.core.translate import from_msccl_json

        try:
            nbytes = os.path.getsize(jpath)
            with open(jpath, encoding="utf-8") as f:
                alg = from_msccl_json(f.read(), topo)
            self.stats.bytes_loaded += nbytes
        except (OSError, ValueError, KeyError, TypeError, AttributeError,
                IndexError):
            try:
                os.remove(jpath)
            except OSError:
                pass
            return None
        self._store_disk(key, alg)
        try:
            os.remove(jpath)
        except OSError:
            pass
        return alg

    def _store_disk(self, key: tuple, alg: CollectiveAlgorithm) -> None:
        path = self._disk_path(key)
        if path is None:
            return
        from repro.core.serialize import save_plan_npz

        os.makedirs(self.cache_dir, exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            save_plan_npz(tmp, alg, key[1])
            os.replace(tmp, path)
        except OSError:
            # disk-full / permission trouble degrades to a memory-only cache
            try:
                os.remove(tmp)
            except OSError:
                pass
            return
        self.stats.bytes_stored += os.path.getsize(path)
        self._touch_manifest(path)
        self._evict_disk(keep=os.path.basename(path))

    # -- main entry ---------------------------------------------------------

    def get_or_synthesize(
        self,
        topo: Topology,
        kind: str,
        group: Sequence[int],
        synth: Callable[[list[int]], CollectiveAlgorithm],
        *,
        params: tuple = (),
        ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        """Fetch (or synthesize and cache) the algorithm for ``kind`` over
        ``group``. ``synth`` receives the canonicalized group (the images of
        ``group``'s members, in order) and must build conditions with a fresh
        ``ChunkIds()`` so cached chunk ids are dense from 0."""
        group = list(group)
        canon, perm = canonicalize_group(topo, group)
        key = self._key(topo, kind, canon, params)

        with self._lock:
            alg = self._lru.get(key)
            if alg is not None:
                self._lru.move_to_end(key)
                self.stats.hits += 1
            else:
                alg = self._load_disk(key, topo)
                if alg is not None:
                    self.stats.disk_hits += 1
                else:
                    alg = synth(list(canon))
                    self.stats.misses += 1
                    self._store_disk(key, alg)
                self._lru[key] = alg
                while len(self._lru) > self.max_entries:
                    self._lru.popitem(last=False)
                    self.stats.evictions += 1

        if canon != tuple(group):
            alg = relabel_algorithm(alg, invert_permutation(perm))
        return renumber_chunks(alg, ids)


_DEFAULT_REGISTRY: AlgorithmRegistry | None = None
_DEFAULT_REGISTRY_LOCK = threading.Lock()


def default_registry() -> AlgorithmRegistry:
    """Process-wide shared registry (used by repro.comms and repro.launch).

    Set ``PCCL_CACHE_DIR`` to persist synthesized algorithms across runs.
    """
    global _DEFAULT_REGISTRY
    with _DEFAULT_REGISTRY_LOCK:
        if _DEFAULT_REGISTRY is None:
            _DEFAULT_REGISTRY = AlgorithmRegistry(
                cache_dir=os.environ.get("PCCL_CACHE_DIR") or None
            )
        return _DEFAULT_REGISTRY
