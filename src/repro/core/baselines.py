"""Baseline (topology-unaware) collective algorithms, as deployed CCLs use
today (paper §5.2): Direct pairwise send-receive for All-to-All, and logical
Ring algorithms for All-Gather / Reduce-Scatter / All-Reduce.

Baselines route each logical transfer along the static shortest path and are
evaluated under the queuing simulator — they have no global schedule, so they
congest (paper Fig. 17's "Direct" heat map) and never use links outside the
process group's shortest paths.
"""

from __future__ import annotations

import heapq

from repro.core.conditions import ChunkIds, all_gather, all_to_all
from repro.core.simulator import Flow, SimResult, simulate_flows
from repro.topology.topology import Topology


def shortest_path_links(topo: Topology, src: int, dst: int,
                        chunk_bytes: float = 1.0) -> list[int]:
    """Deterministic alpha-beta-weighted shortest path, as a list of link ids."""
    dist = [float("inf")] * topo.num_nodes
    pred: dict[int, tuple[int, int]] = {}
    dist[src] = 0.0
    heap = [(0.0, src)]
    while heap:
        du, u = heapq.heappop(heap)
        if u == dst:
            break
        if du > dist[u]:
            continue
        for link in topo.out_links(u):
            alt = du + link.transfer_time(chunk_bytes)
            v = link.dst
            if alt < dist[v] - 1e-12 or (
                abs(alt - dist[v]) <= 1e-12 and (v not in pred or link.id < pred[v][1])
            ):
                dist[v] = alt
                pred[v] = (u, link.id)
                heapq.heappush(heap, (alt, v))
    if dist[dst] == float("inf"):
        raise AssertionError(f"no route {src} -> {dst}")
    route: list[int] = []
    node = dst
    while node != src:
        u, link_id = pred[node]
        route.append(link_id)
        node = u
    return list(reversed(route))


def direct_all_to_all(
    topo: Topology,
    group: list[int],
    *,
    bytes: float = 1.0,
    chunks_per_pair: int = 1,
    ids: ChunkIds | None = None,
) -> SimResult:
    """Direct (pairwise point-to-point) All-to-All over shortest paths —
    what CCLs implement today (paper §3.3, §5.2)."""
    conds = all_to_all(list(group), ids=ids or ChunkIds(), bytes=bytes,
                       chunks_per_pair=chunks_per_pair)
    flows = [
        Flow(c.chunk, c.bytes,
             shortest_path_links(topo, c.src, next(iter(c.dests)), c.bytes))
        for c in conds
    ]
    return simulate_flows(topo, flows)


def ring_all_gather(
    topo: Topology,
    group: list[int],
    *,
    bytes: float = 1.0,
    ids: ChunkIds | None = None,
) -> SimResult:
    """Topology-unaware logical Ring All-Gather (paper Fig. 3b): chunk i makes
    n-1 logical hops around `group` order; each logical hop rides the physical
    shortest path."""
    group = list(group)
    n = len(group)
    conds = all_gather(group, ids=ids or ChunkIds(), bytes=bytes)
    hop_routes = [
        shortest_path_links(topo, group[i], group[(i + 1) % n], bytes)
        for i in range(n)
    ]
    flows = []
    for idx, c in enumerate(conds):
        # chunk originating at group[idx] travels idx -> idx+1 -> ... (n-1 hops)
        route: list[int] = []
        for k in range(n - 1):
            route.extend(hop_routes[(idx + k) % n])
        flows.append(Flow(c.chunk, c.bytes, route))
    return simulate_flows(topo, flows)


def direct_all_gather(
    topo: Topology,
    group: list[int],
    *,
    bytes: float = 1.0,
    ids: ChunkIds | None = None,
) -> SimResult:
    """Each NPU unicasts its chunk to every peer over shortest paths."""
    group = list(group)
    flows = []
    idgen = ids or ChunkIds()
    for src in group:
        for dst in group:
            if src == dst:
                continue
            flows.append(
                Flow(idgen.next(), bytes, shortest_path_links(topo, src, dst, bytes))
            )
    return simulate_flows(topo, flows)
