"""Fault-aware incremental plan repair for degraded fabrics.

A production fabric loses links and devices as a matter of course. PCCL's
partition tree localizes that damage: a rack-internal link failure touches
one pod's intra/scatter phases and nothing else, so re-synthesizing the
whole collective from scratch throws away every undamaged pod's schedule.
:class:`PlanRepairer` keeps the composed :class:`PhasePlan` record of a
synthesis (via the engine's plan-capture hook), and on a
:class:`DegradationEvent`:

1. derives the surviving fabric as a :meth:`Topology.degraded` view (node
   ids stable, failed links + links incident to failed devices dropped,
   partition tree carried over);
2. checks feasibility — if the surviving fabric cannot fulfil the request
   at all (a group member unreachable, a pod's sole gateway dead), raises
   :class:`FabricDegradedError` loudly, never a silently-wrong schedule;
3. classifies the damage through the partition tree (pod-internal vs
   boundary vs gateway-loss, see :class:`DamageReport`);
4. repairs *phase-locally* when the record allows it: undamaged phases are
   kept verbatim (their sub-fabrics are untouched — only the link map is
   re-indexed into the degraded fabric's compressed link ids) and damaged
   phases are re-synthesized on their degraded sub-topology views, where
   the shared registry still serves every undamaged isomorphic sub-pod
   (on a pods-of-pods fabric, a rack failure re-synthesizes one pod's
   intra phase and that pod's seven undamaged racks registry-hit their
   cached rack plans); the patched plan is re-stitched and validated;
5. falls back to a cold synthesis of the request on the degraded fabric —
   still through the shared registry — when the damage crosses what
   phase-local repair can express (a lost gateway changes every phase's
   gateway assignment; a dead group member changes the condition set; a
   pipelined record's releases are tied to the dead fabric's clock).

Phase-level registry keys stay structure-based on purpose: a degraded
sub-fabric that is structurally identical to a healthy one synthesizes the
identical phase plan, and that sharing *is* the repair speedup. The
whole-collective route keys, by contrast, carry the degradation
fingerprint (``SynthesisEngine.degradation``) on top of the degraded
topology's own structure hash, so a degraded plan can never cross-serve a
healthy fabric's request or another event's.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

try:  # scipy ships with the toolchain; degrade to BFS sweeps without it
    from scipy.sparse import csr_matrix as _sp_csr_matrix
    from scipy.sparse.csgraph import connected_components as _sp_scc
except ImportError:  # pragma: no cover
    _sp_csr_matrix = _sp_scc = None

from repro.core.algorithm import CollectiveAlgorithm, TransferColumns
from repro.core.conditions import Condition
from repro.core.engine import PhasePlan, SynthesisEngine
from repro.core.errors import FabricDegradedError
from repro.core.hierarchy import HierarchyError
from repro.core.request import CollectiveRequest
from repro.core.traffic import SketchInfeasibleError
from repro.topology.topology import Topology, TopologyView

__all__ = [
    "DamageReport",
    "DegradationEvent",
    "FabricDegradedError",
    "PlanRepairer",
    "RepairResult",
]


@dataclass(frozen=True)
class DegradationEvent:
    """One fabric-degradation event: the failed link ids and/or device
    (node) ids, normalized to sorted unique tuples."""

    failed_links: tuple = ()
    failed_npus: tuple = ()

    def __post_init__(self):
        object.__setattr__(
            self, "failed_links",
            tuple(sorted({int(l) for l in self.failed_links})))
        object.__setattr__(
            self, "failed_npus",
            tuple(sorted({int(n) for n in self.failed_npus})))

    def __bool__(self) -> bool:
        return bool(self.failed_links or self.failed_npus)

    def fingerprint(self) -> str:
        return f"L{','.join(map(str, self.failed_links))}" \
               f"|N{','.join(map(str, self.failed_npus))}"


@dataclass(frozen=True)
class DamageReport:
    """Where the damage landed, through the partition tree's eyes.

    ``pod_internal`` lists pods whose internal fabric lost a link or a
    non-gateway device; ``gateway_loss`` lists pods that lost a gateway
    NPU (every phase's gateway assignment is suspect); ``boundary`` is set
    when the inter-pod fabric itself lost a link. On an unpartitioned
    fabric everything is ``unpartitioned`` damage."""

    pod_internal: tuple = ()
    boundary: bool = False
    gateway_loss: tuple = ()
    unpartitioned: bool = False


@dataclass(frozen=True)
class RepairResult:
    """A repaired collective plus its provenance. ``strategy`` is
    ``"phases"`` (phase-local repair: ``phases_kept`` schedules survived
    verbatim, ``phases_resynthesized`` were re-synthesized on degraded
    sub-views) or ``"resynth"`` (cold synthesis on the degraded fabric,
    shared-registry warm). ``algorithm.topology`` is ``view.topology`` —
    the degraded fabric, whose node ids match the original's and whose
    link ids map back through ``view.links``."""

    algorithm: object
    view: TopologyView
    strategy: str
    event: DegradationEvent
    request: CollectiveRequest
    report: DamageReport
    phases_kept: int = 0
    phases_resynthesized: int = 0

    @property
    def topology(self) -> Topology:
        return self.view.topology


class PlanRepairer:
    """Synthesizes collectives with plan capture and repairs them against
    degradation events.

    :meth:`plan` synthesizes a request on the healthy fabric, keeping the
    composed ``PhasePlan`` record when the synthesis produced one (the
    hierarchical spanning family does; flat plans and reductions have no
    phase record and repair by resynthesis). :meth:`repair` patches a
    previously-planned request — or cold-synthesizes an unplanned one —
    onto the surviving fabric.
    """

    def __init__(self, topology: Topology, *, registry=None,
                 gateway_strategy: str = "auto", sketch=None,
                 pipeline: str | bool = "auto"):
        self.topology = topology
        self.registry = registry
        self.gateway_strategy = gateway_strategy
        self.sketch = sketch
        # regime for planned collectives: only the sequential regime's
        # canonically-timed, barrier-composed records repair phase-locally
        # (a pipelined record's releases are tied to the healthy fabric's
        # absolute clock). "auto" pipelines small groups as usual — their
        # plans then repair by resynthesis; pipeline=False trades a little
        # makespan tightness for phase-repairable records everywhere.
        self.pipeline = pipeline
        self.engine = SynthesisEngine(topology, registry=registry,
                                      gateway_strategy=gateway_strategy,
                                      sketch=sketch)
        # request fingerprint ->
        #   (request, captured PhasePlan | None, nested (result, plan) pairs)
        self._records: dict[str, tuple] = {}
        # event fingerprint -> (degraded Topology, SynthesisEngine)
        self._dengines: dict[str, tuple[Topology, SynthesisEngine]] = {}

    # -- planning (capture) --------------------------------------------------

    def plan(self, request: CollectiveRequest, *, ids=None):
        """Synthesize ``request`` on the healthy fabric, recording the
        composed phase structure for later repair.

        Drives the hierarchical synthesizer directly (bypassing the
        registry's whole-collective canonicalization, which could relabel
        the captured record into another group's coordinates); the
        per-phase registry sharing underneath is untouched. Requests the
        hierarchical route cannot take synthesize through the ordinary
        engine path and repair by resynthesis only."""
        req = request
        hier = self.engine.hierarchical()
        cap: list = []
        self.engine._capture = cap
        try:
            try:
                pl = self.pipeline
                if req.kind == "all_gather":
                    alg = hier.all_gather(list(req.group), bytes=req.bytes,
                                          chunks_per_npu=req.chunks, ids=ids,
                                          pipeline=pl)
                elif req.kind == "all_to_all":
                    alg = hier.all_to_all(list(req.group), bytes=req.bytes,
                                          chunks_per_pair=req.chunks, ids=ids,
                                          pipeline=pl)
                elif req.kind == "reduce_scatter":
                    alg = hier.reduce_scatter(list(req.group),
                                              bytes=req.bytes,
                                              chunks_per_npu=req.chunks,
                                              ids=ids, pipeline=pl)
                elif req.kind == "all_reduce":
                    alg = hier.all_reduce(list(req.group), bytes=req.bytes,
                                          ids=ids, pipeline=pl)
                else:  # reduce: no hierarchical route, no phase record
                    alg = self.engine.collective(req, ids=ids)
            except HierarchyError:
                if req.hierarchy == "always" or self.sketch is not None:
                    raise
                cap.clear()
                alg = self.engine.collective(req, ids=ids)
        finally:
            self.engine._capture = None
        record = cap[-1][0] if cap else None
        if record is not None and not self._sequential_record(record):
            # pipelined records carry run-specific absolute releases tied
            # to the healthy fabric's clock: not phase-repairable
            record = None
        # earlier captures are nested compositions (a pods-of-pods phase's
        # own per-rack spanning): kept keyed by their result algorithm, so
        # a damaged phase can be repaired *recursively* — only the damaged
        # rack re-synthesizes — instead of re-spanning the whole pod
        sub = tuple((res, pl) for pl, res in cap[:-1]
                    if self._sequential_record(pl))
        self._records[req.fingerprint()] = (req, record, sub)
        return alg

    def recorded(self, request: CollectiveRequest) -> bool:
        """True when :meth:`plan` has run for ``request`` (whether or not
        it yielded a phase-repairable record)."""
        return request.fingerprint() in self._records

    @staticmethod
    def _sequential_record(plan: PhasePlan) -> bool:
        """True iff the captured record is a sequential spanning
        composition: every phase a canonically-timed sub-topology
        algorithm, barriers via ``after`` (the inter phase waits on the
        intra phases). Only such records repair phase-locally — their
        per-phase schedules are release-0 canonical, so a re-synthesized
        replacement slots into the same barrier structure."""
        saw_after = False
        for ph in plan.phases:
            if ph.algorithm is None or ph.node_map is None \
                    or ph.link_map is None:
                return False
            if ph.preload_from or ph.floors_from or ph.floors:
                return False
            saw_after = saw_after or bool(ph.after)
        return saw_after

    # -- damage classification ----------------------------------------------

    def classify(self, event: DegradationEvent) -> DamageReport:
        """Route the event's damage through the partition tree."""
        topo = self.topology
        part = topo.partition
        if part is None:
            return DamageReport(unpartitioned=bool(event))
        boundary_ids = {l.id for l in topo.boundary_links()}
        pod_internal: set[int] = set()
        gateway_loss: set[int] = set()
        boundary = False
        for l in event.failed_links:
            if l in boundary_ids:
                boundary = True
            else:
                p = part[topo.links[l].src]
                if p < 0:
                    p = part[topo.links[l].dst]
                if p >= 0:
                    pod_internal.add(p)
                else:
                    boundary = True  # link between unassigned devices
        for n in event.failed_npus:
            p = part[n]
            if p >= 0 and n in topo.gateways(p):
                gateway_loss.add(p)
            elif p >= 0:
                pod_internal.add(p)
            else:
                boundary = True
        return DamageReport(
            pod_internal=tuple(sorted(pod_internal)), boundary=boundary,
            gateway_loss=tuple(sorted(gateway_loss)))

    # -- feasibility ---------------------------------------------------------

    def _check_feasible(self, dtopo: Topology, req: CollectiveRequest):
        """Raise :class:`FabricDegradedError` when the surviving fabric
        cannot connect the request's endpoints — the guard that makes a
        dead sole gateway fail loudly instead of synthesizing garbage."""
        group = list(req.group)
        if req.kind != "reduce" and _sp_scc is not None and dtopo.num_links:
            # all-pairs mutual reachability within the group == every
            # member in the same strongly connected component of the full
            # fabric (paths may transit non-members); one O(V+E) sweep
            # instead of an all-pairs hop matrix
            csr = dtopo.csr()
            n = dtopo.num_nodes
            graph = _sp_csr_matrix(
                (np.ones(len(csr.dst_ids)), (csr.src_ids, csr.dst_ids)),
                shape=(n, n))
            _, labels = _sp_scc(graph, directed=True, connection="strong")
            if len(set(labels[g] for g in group)) > 1:
                raise FabricDegradedError(
                    f"{dtopo.name}: surviving fabric disconnects the "
                    f"{req.kind} group (members span multiple strongly "
                    f"connected components)")
            return
        hm = dtopo.hop_matrix()
        if req.kind == "reduce":
            pairs = [(s, req.root) for s in group if s != req.root]
        else:
            pairs = None  # all-pairs within the group
        if hm is not None:
            idx = np.asarray(group, np.int64)
            if pairs is None:
                bad = ~np.isfinite(hm[np.ix_(idx, idx)])
            else:
                bad = ~np.isfinite(hm[idx, req.root])
            if bad.any():
                raise FabricDegradedError(
                    f"{dtopo.name}: surviving fabric disconnects the "
                    f"{req.kind} group (unreachable member pairs remain "
                    f"after {len(group)}-member feasibility sweep)")
            return
        for s in group:
            dist = dtopo.hop_distances_np(s)
            targets = [req.root] if pairs is not None else group
            if any(dist[t] < 0 for t in targets if t != s):
                raise FabricDegradedError(
                    f"{dtopo.name}: surviving fabric disconnects the "
                    f"{req.kind} group (node {s} cannot reach all "
                    f"required peers)")

    # -- repair --------------------------------------------------------------

    def repair(self, request: CollectiveRequest, event: DegradationEvent,
               *, ids=None, validate: str | None = "auto") -> RepairResult:
        """Repair ``request`` against ``event``: a :class:`RepairResult`
        whose algorithm fulfils, on the surviving fabric, the same
        per-chunk conditions a cold synthesis there would — or
        :class:`FabricDegradedError` when no schedule can.

        ``validate`` is the post-repair validation mode (default
        ``"auto"``: full bulk/oracle validation of the patched plan, with
        a validation miss on the phase-repair path falling back to cold
        resynthesis). ``None`` skips that final validation — for callers
        that gate validity downstream (the bench validates untimed and
        reports it as its own row), matching the cold synthesis path,
        which does not validate inline either. Feasibility checking and
        :class:`FabricDegradedError` gating are never skipped."""
        req = request
        dview = self.topology.degraded(event.failed_links, event.failed_npus)
        dtopo = dview.topology
        report = self.classify(event)

        dead = set(event.failed_npus)
        dead_members = sorted(dead & set(req.group))
        if dead_members:
            if req.kind == "reduce" and req.root in dead:
                raise FabricDegradedError(
                    f"reduce root {req.root} is among the failed devices")
            survivors = [n for n in req.group if n not in dead]
            if len(survivors) < 2:
                raise FabricDegradedError(
                    f"{req.kind}: fewer than two group members survive "
                    f"{event.fingerprint()}")
            req = req.with_group(survivors)
        self._check_feasible(dtopo, req)

        if not dead_members:
            got = self._records.get(req.fingerprint())
            if got is not None and got[1] is not None:
                result = self._repair_phases(req, got[1], got[2], event,
                                             dview, report, validate=validate)
                if result is not None:
                    return result
        alg = self._resynthesize(req, event, dview, ids=ids,
                                 validate=validate)
        return RepairResult(alg, dview, "resynth", event, req, report)

    def _engine_for(self, dview: TopologyView,
                    event: DegradationEvent) -> SynthesisEngine:
        """The degraded fabric's engine, memoized per event. Shares the
        repairer's registry (undamaged sub-fabrics keep hitting the
        healthy fabric's phase entries) and carries the event fingerprint
        as ``degradation``, which the engine folds into whole-collective
        route keys so degraded plans never cross-serve."""
        key = event.fingerprint()
        ent = self._dengines.get(key)
        if ent is None or ent[0] is not dview.topology:
            eng = SynthesisEngine(
                dview.topology, registry=self.registry,
                gateway_strategy=self.gateway_strategy,
                sketch=self._translate_sketch(dview))
            eng.degradation = key
            ent = (dview.topology, eng)
            self._dengines[key] = ent
        return ent[1]

    def _translate_sketch(self, dview: TopologyView):
        """The repairer's sketch re-indexed into the degraded fabric: node
        ids are stable, link exclusions map through the view's compressed
        link ids (already-dead excluded links simply drop out)."""
        sk = self.sketch
        if sk is None:
            return None
        dlink = {orig: d for d, orig in enumerate(dview.links)}
        return replace(
            sk,
            exclude_links=frozenset(
                dlink[l] for l in sk.exclude_links if l in dlink),
        )

    def _resynthesize(self, req: CollectiveRequest, event: DegradationEvent,
                      dview: TopologyView, *, ids=None,
                      validate: str | None = "auto"):
        """Strategy 2: cold synthesis of the request on the surviving
        fabric through the shared registry. A HierarchyError that escapes
        (the caller pinned ``hierarchy="always"`` on a fabric that can no
        longer take the pod-aware route) means the request as stated is
        unfulfillable — re-raised as FabricDegradedError; a
        SketchInfeasibleError keeps its own loud type."""
        deng = self._engine_for(dview, event)
        try:
            alg = deng.collective(req, ids=ids)
        except SketchInfeasibleError:
            raise
        except HierarchyError as e:
            raise FabricDegradedError(
                f"{req.kind} on {dview.topology.name}: {e}") from e
        if validate is not None:
            alg.validate(validate)
        return alg

    def _repair_phases(self, req: CollectiveRequest, record: PhasePlan,
                       sub_records: tuple, event: DegradationEvent,
                       dview: TopologyView, report: DamageReport, *,
                       validate: str | None = "auto") -> RepairResult | None:
        """Strategy 1: keep undamaged phases verbatim, re-synthesize
        damaged ones on their degraded sub-views, re-stitch, validate.
        Returns None whenever the damage crosses what phase-local repair
        can express — the caller falls back to resynthesis."""
        if report.gateway_loss:
            # a lost gateway re-routes every chunk's egress/ingress: the
            # kept phases' condition sets would be wrong, not just stale
            return None
        topo = self.topology
        removed = set(event.failed_links)
        for n in event.failed_npus:
            removed.update(l.id for l in topo.links
                           if l.src == n or l.dst == n)
        dead = set(event.failed_npus)
        dlink = {orig: d for d, orig in enumerate(dview.links)}
        deng = self._engine_for(dview, event)
        dhier = deng.hierarchical()
        try:
            repaired = self._repair_record(
                record, dtopo=dview.topology, deng=deng, dhier=dhier,
                lmap=dlink, dead=dead, sub_records=sub_records)
            if repaired is None:
                return None
            alg, kept, resynth = repaired
            if validate is not None:
                alg.validate(validate)
        except (HierarchyError, ValueError, KeyError, RuntimeError,
                AssertionError):
            # anything phase repair cannot express — an unreachable phase
            # condition (pathfinding asserts on a dest no longer reachable
            # within the damaged sub-view), a validation miss on the
            # stitched plan — falls back to cold degraded synthesis:
            # never a wrong plan
            return None
        return RepairResult(alg, dview, "phases", event, req, report,
                            phases_kept=kept, phases_resynthesized=resynth)

    def _repair_record(self, record: PhasePlan, *, dtopo: Topology,
                       deng: SynthesisEngine, dhier, lmap: dict,
                       dead: set, sub_records: tuple):
        """Repair one captured composition onto a degraded topology whose
        node ids coincide with the record's coordinate space (at the top
        level that space is global; in a recursive call it is the damaged
        pod's local ids, which are position-stable because degradation
        keeps node ids). ``lmap`` maps the record's link ids into
        ``dtopo``'s — a missing key is a dead link.

        A damaged phase is repaired by the cheapest route that holds:
        chunk-granular splice (:meth:`_patch_phase`), then — when the
        phase's own nested composition was captured at plan() time —
        *recursive* phase repair (only the damaged rack of the damaged pod
        re-synthesizes; the pod's other racks are kept verbatim), then
        whole-phase re-synthesis through the shared registry. Returns
        ``(algorithm, phases_kept, phases_resynthesized)`` or None when
        the record cannot express the damage."""
        new_phases = []
        kept = resynth = 0
        for ph in record.phases:
            if all(l in lmap for l in ph.link_map) \
                    and not (set(ph.node_map) & dead):
                new_phases.append(replace(
                    ph, link_map=[lmap[l] for l in ph.link_map]))
                kept += 1
                continue
            kind = ph.name.split(":", 1)[0]
            if kind == "inter":
                dsub = dhier._boundary()
            elif kind in ("intra", "scatter"):
                dsub = dtopo.pod_subtopology(int(ph.name.split(":")[1]))
            else:
                return None
            if list(dsub.nodes) != list(ph.node_map):
                # the damage changed the sub-view's node set (e.g. a
                # gateway fell off the boundary): phase-local ids no
                # longer line up — resynthesize the whole collective
                return None
            alg = self._patch_phase(ph, dsub, lmap, dead, deng)
            if alg is None:
                alg = self._repair_nested(ph, dsub, lmap, dhier,
                                          sub_records)
            if alg is None:
                # pipeline=False keeps any nested (pods-of-pods)
                # re-synthesis in the sequential regime, whose per-rack
                # phases are registry-cacheable: the damaged pod's
                # undamaged racks hit the plans cached at plan() time
                alg = dhier._synthesize_local(
                    dsub.topology, list(ph.algorithm.conditions),
                    kind=kind, cacheable=True, replicate=True,
                    pipeline=False)
            new_phases.append(replace(
                ph, algorithm=alg, topology=dsub.topology,
                node_map=list(dsub.nodes), link_map=list(dsub.links)))
            resynth += 1
        alg = deng.synthesize_plan(PhasePlan(
            new_phases, list(record.conditions), name=record.name))
        return alg, kept, resynth

    def _repair_nested(self, ph, dsub, lmap: dict, dhier, sub_records: tuple):
        """Recursive repair of one damaged pods-of-pods phase: when the
        phase's algorithm is the result of a nested composition captured
        at plan() time, re-enter :meth:`_repair_record` one level down —
        in the pod's local coordinates — so only the damaged rack's
        schedule re-synthesizes and the pod's other racks survive
        verbatim. Returns None (caller falls back to whole-phase
        re-synthesis) when no nested record matches: registry-hit pods
        share the canonical pod's algorithm object, so the match is by
        identity and stays exact across isomorphic pods."""
        nested = next((pl for res, pl in sub_records
                       if res is ph.algorithm), None)
        if nested is None:
            return None
        # the phase's link ids -> the degraded pod sub-topology's local
        # ids, composed through the parent map (a link absent from either
        # step is dead in the pod's surviving fabric)
        dsub_pos = {g: i for i, g in enumerate(dsub.links)}
        nlmap = {}
        for i, g in enumerate(ph.link_map):
            dg = lmap.get(g)
            if dg is not None and dg in dsub_pos:
                nlmap[i] = dsub_pos[dg]
        ndhier = dhier._nested_for(dsub.topology)
        repaired = self._repair_record(
            nested, dtopo=dsub.topology, deng=ndhier.engine, dhier=ndhier,
            lmap=nlmap, dead=set(), sub_records=sub_records)
        if repaired is None:
            return None
        return repaired[0]

    def _patch_phase(self, ph, dsub, dlink: dict, dead: set,
                     deng: SynthesisEngine):
        """Chunk-granular repair of one damaged phase: keep every chunk
        whose scheduled transfers avoid the dead hardware — removing load
        never invalidates the survivors' canonical timing — and re-route
        only the chunks that crossed it, searched on a TEN preloaded with
        the kept schedule (the engine's ``preload=`` hook). Orders of
        magnitude fewer searches than re-synthesizing the phase when one
        link died out of hundreds.

        Returns None when the phase is outside what the splice can
        express — reduce-flagged schedules time their combine tree
        globally, and non-:class:`Condition` rows (reductions) need their
        kind-specific synthesis — and the caller falls back to whole-phase
        re-synthesis."""
        old = ph.algorithm
        cols = old.columns
        if bool(cols.reduce.any()) or not all(
                isinstance(c, Condition) for c in old.conditions):
            return None
        # old-sub link id -> degraded-sub link id (through global ids;
        # dead links map to -1)
        dsub_pos = {g: i for i, g in enumerate(dsub.links)}
        lmap = np.full(len(ph.link_map), -1, np.int64)
        for l_old, g in enumerate(ph.link_map):
            dg = dlink.get(g)
            if dg is not None and dg in dsub_pos:
                lmap[l_old] = dsub_pos[dg]
        bad = lmap[cols.link] < 0
        dead_local = [i for i, n in enumerate(ph.node_map) if n in dead]
        if dead_local:
            dl = np.asarray(dead_local, np.int64)
            bad |= np.isin(cols.src, dl) | np.isin(cols.dst, dl)
        damaged = np.unique(cols.chunk[bad])
        n_chunks = len({c.chunk for c in old.conditions})
        if n_chunks and len(damaged) > 0.25 * n_chunks:
            # most chunks crossed the dead hardware (a multicast phase's
            # trees visit every member, so one dead link can taint nearly
            # all of them): per-chunk re-search on the congested composed
            # view costs more than nested re-synthesis, whose per-rack
            # pieces registry-hit — let the caller take that path
            return None
        keep = ~np.isin(cols.chunk, damaged)
        kept = TransferColumns(
            cols.chunk[keep], lmap[cols.link[keep]].astype(np.int32),
            cols.src[keep], cols.dst[keep], cols.start[keep],
            cols.end[keep], cols.reduce[keep])
        dmg = {int(c) for c in damaged}
        conds_d = [c for c in old.conditions if c.chunk in dmg]
        if len(conds_d) != len(dmg):
            # a damaged chunk with no condition row of its own (composed
            # provenance): the splice cannot re-derive its requirement
            return None
        if conds_d:
            pre = CollectiveAlgorithm(dsub.topology, [], kept, name="kept")
            newalg = deng.synthesize(conds_d, preload=pre,
                                     topology=dsub.topology, replicate=True,
                                     name=old.name)
            cols_out = TransferColumns.concat([kept, newalg.columns])
        else:
            cols_out = kept
        return CollectiveAlgorithm(dsub.topology, list(old.conditions),
                                   cols_out, name=old.name)
