"""CollectiveRequest: one frozen value object per collective call.

The engine's named collectives historically grew a kwarg per tuning knob
(``bytes=``, ``chunks_per_npu=``/``chunks_per_pair=``, ``pipelined=``,
``hierarchy=``) plus two engine-level settings (``gateway_strategy``,
``sketch``) that silently changed what the same call meant on different
engines. :class:`CollectiveRequest` folds all of it into one frozen,
validated dataclass:

* ``SynthesisEngine.collective(request)`` is the primary entry point;
  ``MeshCollectivePlanner.algorithm(request, ...)`` and
  ``PlanService.plan(topo, axis_sizes, request, ...)`` accept the same
  object. The registry route params derive from the request
  (:meth:`CollectiveRequest.registry_params`), reproducing the legacy
  tuples bit-for-bit so pre-existing cache entries keep serving.
* The legacy per-call kwargs survive as thin shims on the named methods;
  explicitly passing one emits :class:`PCCLDeprecationWarning` (escalated
  to an error for ``repro``-internal call sites by the pytest config).
* ``ids=`` (the caller's chunk-id allocator) stays a call-site argument —
  it is identity-bearing mutable state, not a description of the
  collective, so it never belongs in the frozen request.

``chunks`` is the per-NPU chunk count for the gather/reduce-scatter family
and the per-pair count for all_to_all — the one knob the legacy API spelled
two ways (``chunks_per_npu``/``chunks_per_pair``).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace

__all__ = [
    "COLLECTIVE_KINDS",
    "CollectiveRequest",
    "PCCLDeprecationWarning",
]

COLLECTIVE_KINDS = (
    "all_gather", "all_to_all", "reduce", "reduce_scatter", "all_reduce",
)

# distinguishes "kwarg left at default" from "kwarg explicitly passed" in
# the legacy shims, so bare eng.all_gather(group) stays warning-free sugar
_UNSET = object()


class PCCLDeprecationWarning(DeprecationWarning):
    """Deprecation of the per-call kwarg API in favour of
    :class:`CollectiveRequest`. A dedicated subclass so the test suite can
    escalate exactly PCCL's own deprecations to errors without tripping
    over third-party ones."""


@dataclass(frozen=True)
class CollectiveRequest:
    """A complete, immutable description of one collective synthesis.

    ``group`` may be left empty when a layer upstream fills it in (e.g.
    ``MeshCollectivePlanner`` deriving it from a mesh axis) — see
    :meth:`with_group`. ``gateway_strategy``/``sketch`` of ``None`` mean
    "inherit the engine's configuration"; setting either makes the engine
    synthesize through a variant configured accordingly.
    """

    kind: str
    group: tuple = ()
    bytes: float = 1.0
    chunks: int = 1  # per-NPU (gather family) / per-pair (all_to_all)
    root: int | None = None  # reduce only
    hierarchy: str = "auto"
    pipelined: bool = False  # all_reduce flat route only
    gateway_strategy: str | None = None  # None = engine default
    sketch: object | None = None  # CommSketch | None; None = engine default

    def __post_init__(self):
        if self.kind not in COLLECTIVE_KINDS:
            raise ValueError(
                f"kind={self.kind!r} not in {COLLECTIVE_KINDS}")
        object.__setattr__(
            self, "group", tuple(int(n) for n in self.group))
        object.__setattr__(self, "bytes", float(self.bytes))
        object.__setattr__(self, "chunks", int(self.chunks))
        if self.bytes <= 0.0:
            raise ValueError(f"bytes={self.bytes} must be positive")
        if self.chunks < 1:
            raise ValueError(f"chunks={self.chunks} must be >= 1")
        if self.hierarchy not in ("auto", "always", "never"):
            raise ValueError(
                f"hierarchy={self.hierarchy!r} not in auto/always/never")
        if self.kind == "reduce":
            if self.root is None:
                raise ValueError("reduce needs root=")
            object.__setattr__(self, "root", int(self.root))
            if self.group and self.root not in self.group:
                raise ValueError(
                    f"root {self.root} not in group")
        elif self.root is not None:
            raise ValueError(f"root= only applies to reduce, not {self.kind}")
        if self.pipelined and self.kind != "all_reduce":
            raise ValueError(
                f"pipelined= only applies to all_reduce, not {self.kind}")
        if self.sketch is not None and not hasattr(self.sketch, "fingerprint"):
            raise TypeError("sketch must be a CommSketch (needs fingerprint())")

    def with_group(self, group) -> "CollectiveRequest":
        """This request bound to a concrete process group."""
        return replace(self, group=tuple(int(n) for n in group))

    def registry_params(self, route) -> tuple:
        """The registry key's params tuple — bit-identical to what the
        legacy kwarg API produced, so plans cached before the redesign (and
        across old/new call forms) keep serving.

        ``route`` is the resolved hierarchical-route tuple from
        ``SynthesisEngine._route_hierarchical`` (unused for reduce, which
        never routes hierarchically and keys on the root's position)."""
        if self.kind == "reduce":
            return (self.bytes, self.group.index(self.root))
        if self.kind == "all_reduce":
            return (self.bytes, self.pipelined, route)
        # all_gather / all_to_all / reduce_scatter
        return (self.bytes, self.chunks, route)

    def fingerprint(self) -> str:
        """Stable identity for memo keys (plan repair records, service
        caches). Not the registry key — the registry canonicalizes groups
        and adds the topology fingerprint itself."""
        sk = self.sketch.fingerprint() if self.sketch is not None else None
        payload = repr((self.kind, self.group, self.bytes, self.chunks,
                        self.root, self.hierarchy, self.pipelined,
                        self.gateway_strategy, sk))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]
