"""Back-compat synthesis front-ends (paper §4.4, Algorithm 3; §4.5 Fig. 8).

The synthesis loop itself lives in :class:`repro.core.engine.SynthesisEngine`,
which owns TEN lifecycle, int/cont mode selection, condition ordering, and
commit — and can route named collectives through an
:class:`repro.core.registry.AlgorithmRegistry` so isomorphic process groups
share one cached plan. The ``synthesize*`` functions below are thin wrappers
that build a throwaway engine per call; they keep every historical signature
working. Pass ``registry=`` to opt into caching from these wrappers too.
"""

from __future__ import annotations

from repro.core.algorithm import CollectiveAlgorithm
from repro.core.conditions import ChunkIds, Condition
from repro.core.engine import SynthesisEngine, order_conditions
from repro.core.request import CollectiveRequest
from repro.topology.topology import Topology

__all__ = [
    "order_conditions",
    "synthesize",
    "synthesize_all_gather",
    "synthesize_all_reduce",
    "synthesize_all_to_all",
    "synthesize_joint",
    "synthesize_reduce",
    "synthesize_reduce_scatter",
]


def synthesize(
    topo: Topology,
    conds: list[Condition],
    *,
    preload: CollectiveAlgorithm | None = None,
    mode: str = "auto",
    name: str = "pccl",
) -> CollectiveAlgorithm:
    """Paper Algorithm 3. `preload`'s transfers are committed into the TEN
    first (used to compose All-Reduce phases without link conflicts)."""
    return SynthesisEngine(topo).synthesize(
        conds, preload=preload, mode=mode, name=name
    )


def synthesize_all_gather(topo, group, *, bytes=1.0, chunks_per_npu=1,
                          ids=None, registry=None, hierarchy="auto"):
    req = CollectiveRequest("all_gather", group=tuple(group), bytes=bytes,
                            chunks=chunks_per_npu, hierarchy=hierarchy)
    return SynthesisEngine(topo, registry=registry).collective(req, ids=ids)


def synthesize_all_to_all(topo, group, *, bytes=1.0, chunks_per_pair=1,
                          ids=None, registry=None, hierarchy="auto"):
    req = CollectiveRequest("all_to_all", group=tuple(group), bytes=bytes,
                            chunks=chunks_per_pair, hierarchy=hierarchy)
    return SynthesisEngine(topo, registry=registry).collective(req, ids=ids)


def synthesize_reduce(
    topo: Topology, group: list[int], root: int, *,
    bytes: float = 1.0, ids: ChunkIds | None = None, registry=None,
) -> CollectiveAlgorithm:
    req = CollectiveRequest("reduce", group=tuple(group), root=root,
                            bytes=bytes)
    return SynthesisEngine(topo, registry=registry).collective(req, ids=ids)


def synthesize_reduce_scatter(
    topo: Topology, group: list[int], *,
    bytes: float = 1.0, chunks_per_npu: int = 1, ids: ChunkIds | None = None,
    registry=None, hierarchy: str = "auto",
) -> CollectiveAlgorithm:
    req = CollectiveRequest("reduce_scatter", group=tuple(group),
                            bytes=bytes, chunks=chunks_per_npu,
                            hierarchy=hierarchy)
    return SynthesisEngine(topo, registry=registry).collective(req, ids=ids)


def synthesize_all_reduce(
    topo: Topology, group: list[int], *,
    bytes: float = 1.0, ids: ChunkIds | None = None, pipelined: bool = False,
    registry=None, hierarchy: str = "auto",
) -> CollectiveAlgorithm:
    req = CollectiveRequest("all_reduce", group=tuple(group), bytes=bytes,
                            pipelined=pipelined, hierarchy=hierarchy)
    return SynthesisEngine(topo, registry=registry).collective(req, ids=ids)


def synthesize_joint(
    topo: Topology,
    groups: list[tuple[str, list[Condition]]],
    *,
    name: str = "pccl_joint",
) -> CollectiveAlgorithm:
    """Jointly synthesize several process groups' collectives over one shared
    TEN (paper §6.4, Fig. 15). Chunk ids across groups must be unique — use a
    shared ChunkIds allocator."""
    return SynthesisEngine(topo).synthesize_joint(groups, name=name)
