"""PCCL synthesis loop (paper §4.4, Algorithm 3) and reduction collectives (§4.5).

``synthesize`` is the paper's Algorithm 3: order conditions by descending
max-shortest-path distance (longest-haul chunks claim network resources
first, heuristically maximizing utilization, as in TACCL), then run BFS
pathfinding per condition and commit the pruned paths' link occupancy into
the shared TEN so later chunks route around them — congestion-free by
construction.

Reduction collectives are synthesized by reversing non-reduction algorithms
(paper Fig. 8): Reduce = reverse(Broadcast), Reduce-Scatter =
reverse(All-Gather), All-Reduce = Reduce-Scatter ∘ All-Gather. Our All-Reduce
additionally supports chunk-level pipelining (the All-Gather of a chunk is
released the moment its Reduce-Scatter completes) — a beyond-paper
optimization, off by default for paper fidelity.
"""

from __future__ import annotations

import heapq
from dataclasses import replace

from repro.core import conditions as cnd
from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.core.conditions import ChunkIds, Condition, ReduceCondition
from repro.core.pathfinding import PathResult, bfs_cont, bfs_int
from repro.core.ten import TEN
from repro.topology.topology import Topology


# ---------------------------------------------------------------------------
# Distances for condition ordering (Algorithm 3, lines 1-7)
# ---------------------------------------------------------------------------

class _DistanceCache:
    """Per-source shortest-path times on the static topology, cached.

    Homogeneous graphs use hop counts; heterogeneous use alpha-beta link
    times for the given chunk size (Dijkstra).
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        self.homog = topo.homogeneous()
        self._cache: dict = {}

    def dist(self, src: int, chunk_bytes: float) -> list[float]:
        key = (src, None if self.homog else chunk_bytes)
        got = self._cache.get(key)
        if got is not None:
            return got
        topo = self.topo
        if self.homog:
            d = [float(x) for x in topo.hop_distances_from(src)]
            d = [x if x >= 0 else float("inf") for x in d]
        else:
            d = [float("inf")] * topo.num_nodes
            d[src] = 0.0
            heap = [(0.0, src)]
            while heap:
                du, u = heapq.heappop(heap)
                if du > d[u]:
                    continue
                for link in topo.out_links(u):
                    alt = du + link.transfer_time(chunk_bytes)
                    if alt < d[link.dst]:
                        d[link.dst] = alt
                        heapq.heappush(heap, (alt, link.dst))
        self._cache[key] = d
        return d

    def condition_dist(self, c: Condition) -> float:
        d = self.dist(c.src, c.bytes)
        return max((d[dst] for dst in c.remote_dests), default=0.0)


def order_conditions(topo: Topology, conds: list[Condition]) -> list[Condition]:
    """Sort descending by max shortest-path distance (Algorithm 3 line 7);
    deterministic tie-break on (bytes, chunk id)."""
    cache = _DistanceCache(topo)
    return sorted(
        conds, key=lambda c: (-cache.condition_dist(c), -c.bytes, c.chunk)
    )


# ---------------------------------------------------------------------------
# Non-reduction synthesis (Algorithm 3)
# ---------------------------------------------------------------------------

def _use_int_mode(topo: Topology, conds: list[Condition]) -> bool:
    if not topo.homogeneous() or not conds:
        return False
    b0 = conds[0].bytes
    if any(c.bytes != b0 for c in conds):
        return False
    if any(c.release != int(c.release) for c in conds):
        return False
    # unit transfer time required for the integer TEN
    link = topo.links[0] if topo.links else None
    return link is None or link.transfer_time(b0) == 1.0


def synthesize(
    topo: Topology,
    conds: list[Condition],
    *,
    preload: CollectiveAlgorithm | None = None,
    mode: str = "auto",
    name: str = "pccl",
) -> CollectiveAlgorithm:
    """Paper Algorithm 3. `preload`'s transfers are committed into the TEN
    first (used to compose All-Reduce phases without link conflicts)."""
    ten = TEN(topo)
    int_mode = mode == "int" or (mode == "auto" and _use_int_mode(topo, conds))
    sizes = {c.chunk: c.bytes for c in conds}
    if preload is not None:
        for t in preload.transfers:
            if int_mode:
                ten.commit_int(t.link, int(t.start))
            else:
                ten.commit(t.link, t.start, t.end)
        for c in preload.conditions:
            sizes.setdefault(c.chunk, c.bytes)

    ordered = order_conditions(topo, conds)
    transfers: list[Transfer] = []
    for c in ordered:
        result: PathResult = bfs_int(ten, c) if int_mode else bfs_cont(ten, c)
        _commit(ten, topo, result, int_mode)
        transfers.extend(result.transfers)
    return CollectiveAlgorithm(topo, list(conds), transfers, name=name)


def _commit(ten: TEN, topo: Topology, result: PathResult, int_mode: bool) -> None:
    # occupy links of retained paths only (paper Fig. 6e / Fig. 7)
    last_send_end: dict[int, float] = {}
    for t in result.transfers:
        if int_mode:
            ten.commit_int(t.link, int(t.start))
        else:
            ten.commit(t.link, t.start, t.end)
        if topo.is_switch(t.src):
            last_send_end[t.src] = max(last_send_end.get(t.src, 0.0), t.end)
    # switch residency: arrival .. last retained forward
    for t in result.transfers:
        if topo.is_switch(t.dst):
            ten.commit_residency(
                t.dst, t.end, max(last_send_end.get(t.dst, t.end), t.end)
            )


# ---------------------------------------------------------------------------
# Reduction collectives via reversal (paper §4.5, Fig. 8)
# ---------------------------------------------------------------------------

def _reverse_algorithm(
    alg: CollectiveAlgorithm,
    fwd_topo: Topology,
    reduce_conds: list[ReduceCondition],
) -> CollectiveAlgorithm:
    """Reverse a (broadcast/all-gather style) algorithm synthesized on the
    reversed topology into a reduction algorithm on the forward topology.

    Link k of reversed(topo) is link k of topo with endpoints swapped (by
    construction), so link ids carry over directly. A transfer at [s, e) maps
    to [T - e, T - s): in-trees become out-trees and causality is preserved
    (child partials arrive before the parent forwards its own partial).
    """
    T = max((t.end for t in alg.transfers), default=0.0)
    base = min((c.release for c in reduce_conds), default=0.0)
    rev = [
        Transfer(t.chunk, t.link, t.dst, t.src, base + T - t.end, base + T - t.start,
                 reduce=True)
        for t in alg.transfers
    ]
    return CollectiveAlgorithm(fwd_topo, list(reduce_conds), rev, name=alg.name)


def synthesize_reduce(
    topo: Topology, group: list[int], root: int, *,
    bytes: float = 1.0, ids: ChunkIds | None = None,
) -> CollectiveAlgorithm:
    ids = ids or ChunkIds()
    rconds = cnd.reduce(group, root, ids=ChunkIds(0), bytes=bytes)
    rconds = [replace(r, chunk=ids.next()) for r in rconds]
    rev_topo = topo.reversed()
    bcast = [
        Condition(r.chunk, root, r.srcs, bytes=r.bytes, tag="rev_bcast")
        for r in rconds
    ]
    alg = synthesize(rev_topo, bcast, name="pccl_reduce")
    return _reverse_algorithm(alg, topo, rconds)


def synthesize_reduce_scatter(
    topo: Topology, group: list[int], *,
    bytes: float = 1.0, chunks_per_npu: int = 1, ids: ChunkIds | None = None,
) -> CollectiveAlgorithm:
    ids = ids or ChunkIds()
    rconds = [
        replace(r, chunk=ids.next())
        for r in cnd.reduce_scatter(group, ids=ChunkIds(0), bytes=bytes,
                                    chunks_per_npu=chunks_per_npu)
    ]
    rev_topo = topo.reversed()
    ag = [
        Condition(r.chunk, next(iter(r.dests)), r.srcs, bytes=r.bytes, tag="rev_ag")
        for r in rconds
    ]
    alg = synthesize(rev_topo, ag, name="pccl_reduce_scatter")
    return _reverse_algorithm(alg, topo, rconds)


def synthesize_all_reduce(
    topo: Topology, group: list[int], *,
    bytes: float = 1.0, ids: ChunkIds | None = None, pipelined: bool = False,
) -> CollectiveAlgorithm:
    """All-Reduce = Reduce-Scatter then All-Gather (paper §4.5). Each NPU in
    the group owns one shard-chunk. With ``pipelined=True`` (beyond-paper),
    each chunk's All-Gather is released at that chunk's Reduce-Scatter
    completion instead of the global Reduce-Scatter makespan."""
    ids = ids or ChunkIds()
    group = list(group)
    rs = synthesize_reduce_scatter(topo, group, bytes=bytes, ids=ids)
    # per-chunk completion time of the reduce-scatter phase
    owner = {c.chunk: next(iter(c.dests)) for c in rs.conditions}
    done: dict[int, float] = {c.chunk: 0.0 for c in rs.conditions}
    for t in rs.transfers:
        done[t.chunk] = max(done[t.chunk], t.end)
    rs_makespan = max(done.values(), default=0.0)

    ag_conds = [
        Condition(
            c.chunk,
            owner[c.chunk],
            frozenset(group),
            bytes=bytes,
            release=(done[c.chunk] if pipelined else rs_makespan),
            tag="allreduce_ag",
        )
        for c in rs.conditions
    ]
    ag = synthesize(topo, ag_conds, preload=rs, name="pccl_all_reduce")
    ar_conds = [
        ReduceCondition(c.chunk, frozenset(group), frozenset(group), bytes=bytes)
        for c in rs.conditions
    ]
    return CollectiveAlgorithm(
        topo, ar_conds, rs.transfers + ag.transfers, name="pccl_all_reduce"
    )


# ---------------------------------------------------------------------------
# Convenience front-ends
# ---------------------------------------------------------------------------

def synthesize_all_gather(topo, group, *, bytes=1.0, chunks_per_npu=1, ids=None):
    conds = cnd.all_gather(list(group), ids=ids or ChunkIds(), bytes=bytes,
                           chunks_per_npu=chunks_per_npu)
    return synthesize(topo, conds, name="pccl_all_gather")


def synthesize_all_to_all(topo, group, *, bytes=1.0, chunks_per_pair=1, ids=None):
    conds = cnd.all_to_all(list(group), ids=ids or ChunkIds(), bytes=bytes,
                           chunks_per_pair=chunks_per_pair)
    return synthesize(topo, conds, name="pccl_all_to_all")


def synthesize_joint(
    topo: Topology,
    groups: list[tuple[str, list[Condition]]],
    *,
    name: str = "pccl_joint",
) -> CollectiveAlgorithm:
    """Jointly synthesize several process groups' collectives over one shared
    TEN (paper §6.4, Fig. 15). Chunk ids across groups must be unique — use a
    shared ChunkIds allocator."""
    all_conds: list[Condition] = []
    for tag, conds in groups:
        all_conds.extend(replace(c, tag=tag) for c in conds)
    seen: set[int] = set()
    for c in all_conds:
        if c.chunk in seen:
            raise ValueError(f"duplicate chunk id {c.chunk} across process groups")
        seen.add(c.chunk)
    return synthesize(topo, all_conds, name=name)
