"""BFS pathfinding over the TEN (paper §4.3, Algorithm 2) — batched frontier.

Given one condition (chunk, src, dests), find timed store-and-forward paths
from src to every destination, over links not yet occupied by previously
scheduled chunks. Three entry points:

* ``bfs_int``: the homogeneous synchronous TEN search, reformulated as a
  batched event frontier over the topology's CSR arrays and the TEN's
  occupancy bitmap. Because link occupancy is frozen for the duration of one
  search (paths commit only after the BFS returns), every edge's next free
  send slot is computable exactly, once, from the per-link occupancy masks —
  so instead of re-scanning the whole frontier at every timestep (most of
  which commit nothing), the search processes one monotone heap of edge
  events keyed ``(timestep, parent visit order, edge index)``. That key
  reproduces the reference implementation's frontier scan order exactly, so
  claims — and therefore transfers, arrivals, and makespans — are
  bit-identical to ``bfs_int_ref`` (enforced by the differential test
  suite). On switch-free topologies the search additionally prunes events
  that provably cannot influence any retained path: a greedy
  store-and-forward probe yields an upper bound on every destination's
  arrival, and an admissible hop-distance heuristic discards events beyond
  it (the bound argument is spelled out above ``_probe``).
* ``bfs_int_ref``: the original per-timestep frontier scan, kept verbatim as
  the reference for differential testing.
* ``bfs_cont``: the heterogeneous generalization (paper §4.6) — earliest-
  arrival search where each link candidate carries its alpha-beta transfer
  time and links have busy *intervals*; with all-equal link times it visits
  nodes in the same order as ``bfs_int``.

All return the *pruned* transfer set: the BFS may visit more nodes than
requested (paper Fig. 6d), and only edges on some src->dest path are retained
(Fig. 6e) — including through out-of-process-group NPUs, which is where the
paper's process-group awareness comes from.

Switch handling (paper §4.7): visiting a full switch is skipped until its
buffer drains; non-multicast switches serialize their egress (one next
neighbor per step, "visits next nodes one by one"). Switched topologies take
the general event loop — serialized egress consumes a per-step budget, so
the search-bound and push-elision optimizations (which assume an edge's fire
time is competition-independent) stay off.
"""

from __future__ import annotations

import heapq
import operator
from dataclasses import dataclass

from repro.core.algorithm import Transfer
from repro.core.conditions import Condition
from repro.core.ten import TEN

_EPS = 1e-9

# destinations-per-condition cap for the search bound: beyond this many
# probes the heuristic costs more than the flood it avoids
_MAX_BOUND_DESTS = 4


@dataclass(slots=True)
class PathResult:
    """Pruned transfers + chunk arrival time at every retained node."""

    transfers: list[Transfer]
    arrivals: dict[int, float]  # node -> arrival time (retained nodes only)
    reached: dict[int, float]  # dest -> arrival time


def _prune(
    chunk: int,
    src: int,
    dests: frozenset[int],
    pred: dict[int, tuple[int, int, float, float]],
    visited: dict[int, float],
) -> PathResult:
    """Keep only edges on some src->dest path (paper Fig. 6e)."""
    keep: dict[tuple[int, float], Transfer] = {}
    arrivals: dict[int, float] = {src: visited[src]}
    reached: dict[int, float] = {}
    for dest in dests:
        if dest == src:
            reached[dest] = visited[src]
            continue
        if dest not in visited:
            raise AssertionError(f"chunk {chunk}: BFS did not reach dest {dest}")
        reached[dest] = visited[dest]
        node = dest
        while node != src:
            u, link, s, e = pred[node]
            key = (link, s)
            if key not in keep:
                keep[key] = Transfer(chunk, link, u, node, s, e)
            arrivals[node] = e
            node = u
    transfers = sorted(keep.values(), key=lambda t: (t.start, t.link))
    return PathResult(transfers, arrivals, reached)


# ---------------------------------------------------------------------------
# Per-topology scratch for the event search (epoch-stamped, so no per-call
# clearing): visit times/preds plus the best-pushed-slot elision table.
# ---------------------------------------------------------------------------

class _Scratch:
    """Per-topology search scratch, epoch-stamped so a new search costs one
    counter bump instead of O(n) clears. All cells hold machine-word ints
    (epoch stamps live in their own tables: mixing them into value cells
    would push every store/compare into multi-digit bigint arithmetic).
    ``pred_e`` needs no stamp of its own — it is written iff ``vis_e`` is."""

    __slots__ = ("epoch", "vis_t", "vis_e", "pred_e", "best", "best_e")

    def __init__(self, n: int):
        self.epoch = 0
        self.vis_t = [0] * n  # claim timestep (arrival)
        self.vis_e = [0] * n  # epoch stamp for vis_t/pred_e
        self.pred_e = [0] * n  # predecessor edge index
        self.best = [0] * n  # smallest pushed event key per node
        self.best_e = [0] * n  # epoch stamp for best


def _scratch_for(topo) -> _Scratch:
    sc = getattr(topo, "_bfs_scratch", None)
    if sc is None or len(sc.vis_t) != topo.num_nodes:
        sc = topo._bfs_scratch = _Scratch(topo.num_nodes)
    return sc


def _probe(adjh, hrow, masks, mask_bl, src: int, t0: int) -> int:
    """Store-and-forward arrival bound: walk greedy shortest paths to the
    destination (descending hop distance, earliest-free link at every hop),
    one walk per distinct first hop, keeping the best arrival.
    ``adjh``/``hrow`` are the per-destination folded adjacency and hop row
    from ``_adjh_for``. Returns -1 when the destination is unreachable from
    ``src``.

    The returned time T_ub is a valid upper bound on the BFS arrival at the
    destination, and — because on switch-free topologies an edge's fire time
    does not depend on claim competition — every node on a retained path,
    every claim competitor of such a node, and (inductively) all their
    ancestors v satisfy ``claim(v) + hop(v, dest) <= T_ub``. Events outside
    that set can be dropped without changing the pruned output.
    """
    h0 = hrow[src]
    if h0 < 0:
        return -1
    best = -1
    for _, w0, lk0, hw0 in adjh[src]:
        if hw0 != h0 - 1:
            continue
        if mask_bl[lk0] <= t0:
            t = t0 + 1
        else:
            m = masks[lk0] >> t0
            t = t0 + (~m & (m + 1)).bit_length()
        v = w0
        h = h0 - 1
        while h > 0:
            # among hop-descending neighbors, follow the earliest-free link
            bt = -1
            bw = -1
            for _, w, lk, hw in adjh[v]:
                if hw == h - 1:
                    if mask_bl[lk] <= t:
                        bt, bw = t, w
                        break  # can't do better than sending now
                    m = masks[lk] >> t
                    nf = t + (~m & (m + 1)).bit_length() - 1
                    if bt < 0 or nf < bt:
                        bt, bw = nf, w
            if bw < 0:  # pragma: no cover - descent exists while h > 0
                return -1
            t = bt + 1
            v = bw
            h -= 1
            if best >= 0 and t >= best:
                break  # already no better than a previous walk
        else:
            if best < 0 or t < best:
                best = t
    return best


def _adjh_for(topo, csr, dest: int):
    """Per-destination hop row + adjacency rows with the heuristic folded
    in: ``rows[v] = ((edge_idx, dst, link_id, hop(dst, dest)), ...)``, edges
    whose head cannot reach ``dest`` dropped. Cached per
    topology+destination — in an All-to-All every destination's rows are
    reused by every source."""
    cache = getattr(topo, "_adjh_rows", None)
    if cache is None:
        cache = topo._adjh_rows = {}
    got = cache.get(dest)
    if got is None:
        hrow = topo.hop_distances_to(dest)
        got = (hrow, tuple(
            tuple((i, w, lk, hrow[w]) for i, w, lk in row if hrow[w] >= 0)
            for row in csr.adj
        ))
        cache[dest] = got
    return got


def bfs_int(ten: TEN, cond: Condition, max_steps: int | None = None) -> PathResult:
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    if not dests:
        return PathResult([], {src: cond.release}, {src: cond.release})
    csr = topo.csr()
    n = topo.num_nodes
    t0 = int(cond.release)
    if max_steps is None:
        # Links become free after the committed horizon, so any connected
        # destination is reachable within horizon + |V| steps.
        max_steps = int(ten.horizon()) + n + t0 + 4
    if csr.constrained_switch:
        # Only finite buffers / serialized egress invalidate the bound and
        # elision optimizations; unlimited multicast switches (DCI/spine
        # fabrics) behave exactly like NPUs in the search, so they stay on
        # the fast path below — the switched loop's special branches would
        # never fire for them (see the no-op guards in _bfs_int_switched).
        return _bfs_int_switched(ten, cond, csr, t0, max_steps)

    masks = ten._masks
    mask_bl = ten._mask_bl
    adj = csr.adj
    edge_dst = csr.edge_dst
    E = len(edge_dst)
    # shift-packed event key: (timestep << tb) | (visit order << eb) | edge
    eb = max(1, (E - 1).bit_length())
    emask = (1 << eb) - 1
    tb = eb + n.bit_length()

    sc = _scratch_for(topo)
    ep = sc.epoch = sc.epoch + 1
    vis_t, vis_e = sc.vis_t, sc.vis_e
    pred_e = sc.pred_e
    best, best_e = sc.best, sc.best_e

    vis_e[src] = ep
    vis_t[src] = t0
    heap: list[int] = []
    push = heapq.heappush
    pop = heapq.heappop
    nseq = 1

    if len(dests) == 1:
        # hot path: single destination, bound from the greedy probe, per-
        # destination adjacency rows with the heuristic folded in
        (the_dest,) = dests
        hrow, adjh = _adjh_for(topo, csr, the_dest)
        t_ub = _probe(adjh, hrow, masks, mask_bl, src, t0)
        if t_ub >= 0:
            for i, w, lk, hw in adjh[src]:
                if w == src:
                    continue
                if mask_bl[lk] <= t0:
                    nf = t0
                else:
                    m = masks[lk] >> t0
                    nf = t0 + (~m & (m + 1)).bit_length() - 1
                if nf + hw + 1 > t_ub:
                    continue
                key = (nf << tb) | i
                best_e[w] = ep
                best[w] = key
                push(heap, key)
            while True:
                if not heap:
                    raise AssertionError(
                        f"chunk {cond.chunk}: unreachable dests {[the_dest]}"
                    )
                key = pop(heap)
                v = edge_dst[key & emask]
                if vis_e[v] == ep:
                    continue
                t = key >> tb
                if t > max_steps:
                    raise AssertionError(
                        f"chunk {cond.chunk}: unreachable dests {[the_dest]}"
                    )
                t1 = t + 1
                vis_e[v] = ep
                vis_t[v] = t1
                pred_e[v] = key & emask
                if v == the_dest:
                    break
                seq_i = nseq << eb
                nseq += 1
                for i, w, lk, hw in adjh[v]:
                    if vis_e[w] == ep:
                        continue
                    if t1 + hw + 1 > t_ub:
                        continue  # cheap reject: nf >= t1 already overshoots
                    if mask_bl[lk] <= t1:
                        nf = t1
                    else:
                        m = masks[lk] >> t1
                        nf = t1 + (~m & (m + 1)).bit_length() - 1
                    if nf + hw + 1 > t_ub:
                        continue
                    key = (nf << tb) | seq_i | i
                    if best_e[w] == ep:
                        if key > best[w]:
                            # a smaller-keyed event to w is already pending;
                            # it pops first and (claims w | finds w visited)
                            # either way, so this event can only ever pop
                            # onto a visited node
                            continue
                    else:
                        best_e[w] = ep
                    best[w] = key
                    push(heap, key)
            return _prune_scratch(cond.chunk, src, dests, sc, ep, t0, csr)
        remaining = None  # unreachable by probe: fall through unbounded
    else:
        remaining = set(dests)

    # general switch-free path: multiple destinations (bounded when few) or
    # an unreachable-destination probe (unbounded; the search will raise)
    hmin = None
    t_ub = -1
    if remaining is not None and len(dests) <= _MAX_BOUND_DESTS:
        t_ub = 0
        rows = []
        for d in dests:
            hrow, adjh = _adjh_for(topo, csr, d)
            pb = _probe(adjh, hrow, masks, mask_bl, src, t0)
            if pb < 0:
                t_ub = -1
                break
            if pb > t_ub:
                t_ub = pb
            rows.append(hrow)
        if t_ub >= 0:
            hmin = [
                min((r[v] for r in rows if r[v] >= 0), default=-1)
                for v in range(n)
            ]

    for i, w, lk in adj[src]:
        if w == src:
            continue
        if mask_bl[lk] <= t0:
            nf = t0
        else:
            m = masks[lk] >> t0
            nf = t0 + (~m & (m + 1)).bit_length() - 1
        if t_ub >= 0:
            h = hmin[w]
            if h < 0 or nf + h + 1 > t_ub:
                continue
        key = (nf << tb) | i
        best_e[w] = ep
        best[w] = key
        push(heap, key)

    single = remaining is None
    if single:
        (the_dest,) = dests
    else:
        the_dest = -1

    while True:
        if not heap:
            left = [the_dest] if single else sorted(remaining)
            raise AssertionError(f"chunk {cond.chunk}: unreachable dests {left}")
        key = pop(heap)
        v = edge_dst[key & emask]
        if vis_e[v] == ep:
            continue
        t = key >> tb
        if t > max_steps:
            left = [the_dest] if single else sorted(remaining)
            raise AssertionError(f"chunk {cond.chunk}: unreachable dests {left}")
        t1 = t + 1
        vis_e[v] = ep
        vis_t[v] = t1
        pred_e[v] = key & emask
        if single:
            if v == the_dest:
                break
        else:
            remaining.discard(v)
            if not remaining:
                break
        seq_i = nseq << eb
        nseq += 1
        for i, w, lk in adj[v]:
            if vis_e[w] == ep:
                continue
            if t_ub >= 0:
                h = hmin[w]
                if h < 0 or t1 + h + 1 > t_ub:
                    continue
            if mask_bl[lk] <= t1:
                nf = t1
            else:
                m = masks[lk] >> t1
                nf = t1 + (~m & (m + 1)).bit_length() - 1
            if t_ub >= 0 and nf + h + 1 > t_ub:
                continue
            key = (nf << tb) | seq_i | i
            if best_e[w] == ep:
                if key > best[w]:
                    continue
            else:
                best_e[w] = ep
            best[w] = key
            push(heap, key)

    return _prune_scratch(cond.chunk, src, dests, sc, ep, t0, csr)


def _bfs_int_switched(
    ten: TEN, cond: Condition, csr, t0: int, max_steps: int
) -> PathResult:
    """General event loop for topologies with switches: identical ordering,
    plus per-step serialized-egress budgets and buffer-occupancy rechecks
    (both of which force event re-pushes, so the switch-free elisions are
    invalid here)."""
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    masks = ten._masks
    mask_bl = ten._mask_bl
    adj = csr.adj
    edge_dst = csr.edge_dst
    edge_src = csr.edge_src
    edge_link = csr.edge_link
    is_switch = csr.is_switch
    serial = csr.serial_switch
    n = topo.num_nodes
    E = len(edge_dst)
    eb = max(1, (E - 1).bit_length())
    emask = (1 << eb) - 1
    tb = eb + n.bit_length()

    sc = _scratch_for(topo)
    ep = sc.epoch = sc.epoch + 1
    vis_t, vis_e = sc.vis_t, sc.vis_e
    pred_e = sc.pred_e

    vis_e[src] = ep
    vis_t[src] = t0
    heap: list[int] = []
    push = heapq.heappush
    pop = heapq.heappop
    sent_at: dict[int, int] = {}
    remaining = set(dests)
    nseq = 1

    for i, w, lk in adj[src]:
        if w == src:
            continue
        if mask_bl[lk] <= t0:
            nf = t0
        else:
            m = masks[lk] >> t0
            nf = t0 + (~m & (m + 1)).bit_length() - 1
        push(heap, (nf << tb) | i)

    while remaining:
        if not heap:
            raise AssertionError(
                f"chunk {cond.chunk}: unreachable dests {sorted(remaining)}"
            )
        key = pop(heap)
        e = key & emask
        v = edge_dst[e]
        if vis_e[v] == ep:
            continue
        t = key >> tb
        if t > max_steps:
            raise AssertionError(
                f"chunk {cond.chunk}: unreachable dests {sorted(remaining)}"
            )
        u = edge_src[e]
        if serial[u] and sent_at.get(u) == t:
            # serialized egress: one send per step; retry from the next one
            t1 = t + 1
            lk = edge_link[e]
            if mask_bl[lk] <= t1:
                nf = t1
            else:
                m = masks[lk] >> t1
                nf = t1 + (~m & (m + 1)).bit_length() - 1
            push(heap, (nf << tb) | (key & ~(-1 << tb)))
            continue
        if is_switch[v] and not ten.buffer_has_room(v, t + 1):
            # paper §4.7: skip a full switch until its buffer drains. No
            # residency ends before the next drop, so occupancy cannot fall
            # earlier — the retry slot is exact, not a heuristic.
            d = ten.next_drop_after(v, t + 1)
            if d == float("inf"):
                continue  # permanently full via this edge
            tt = max(t + 1, -int(-(d - 1 - _EPS) // 1))
            lk = edge_link[e]
            if mask_bl[lk] <= tt:
                nf = tt
            else:
                m = masks[lk] >> tt
                nf = tt + (~m & (m + 1)).bit_length() - 1
            push(heap, (nf << tb) | (key & ~(-1 << tb)))
            continue
        if serial[u]:
            sent_at[u] = t
        t1 = t + 1
        vis_e[v] = ep
        vis_t[v] = t1
        pred_e[v] = e
        remaining.discard(v)
        if not remaining:
            break
        seq_i = nseq << eb
        nseq += 1
        for i, w, lk in adj[v]:
            if vis_e[w] == ep:
                continue
            if mask_bl[lk] <= t1:
                nf = t1
            else:
                m = masks[lk] >> t1
                nf = t1 + (~m & (m + 1)).bit_length() - 1
            push(heap, (nf << tb) | seq_i | i)

    return _prune_scratch(cond.chunk, src, dests, sc, ep, t0, csr)


def _prune_scratch(
    chunk: int, src: int, dests: frozenset[int], sc: _Scratch, ep: int,
    t0: int, csr,
) -> PathResult:
    """`_prune` over the epoch-stamped scratch arrays (identical output)."""
    vis_t, vis_e = sc.vis_t, sc.vis_e
    pred_e = sc.pred_e
    edge_src = csr.edge_src
    edge_link = csr.edge_link
    arrivals: dict[int, float] = {src: float(t0)}
    if len(dests) == 1:
        # single destination: the retained set is one chain with strictly
        # decreasing starts — build it back-to-front, no dedup or sort needed
        (dest,) = dests
        if dest == src:
            return PathResult([], arrivals, {dest: float(t0)})
        if vis_e[dest] != ep:
            raise AssertionError(f"chunk {chunk}: BFS did not reach dest {dest}")
        reached = {dest: float(vis_t[dest])}
        transfers: list[Transfer] = []
        node = dest
        while node != src:
            end = float(vis_t[node])
            e = pred_e[node]
            u = edge_src[e]
            transfers.append(
                Transfer(chunk, edge_link[e], u, node, end - 1.0, end)
            )
            arrivals[node] = end
            node = u
        transfers.reverse()
        return PathResult(transfers, arrivals, reached)
    keep: dict[tuple[int, float], Transfer] = {}
    reached = {}
    for dest in dests:
        if dest == src:
            reached[dest] = float(t0)
            continue
        if vis_e[dest] != ep:
            raise AssertionError(f"chunk {chunk}: BFS did not reach dest {dest}")
        reached[dest] = float(vis_t[dest])
        node = dest
        while node != src:
            end = vis_t[node]
            e = pred_e[node]
            link = edge_link[e]
            key = (link, float(end - 1))
            if key not in keep:
                keep[key] = Transfer(chunk, link, edge_src[e], node,
                                     float(end - 1), float(end))
            arrivals[node] = float(end)
            node = edge_src[e]
    transfers = sorted(keep.values(), key=operator.attrgetter("start", "link"))
    return PathResult(transfers, arrivals, reached)


# ---------------------------------------------------------------------------
# Reference per-timestep frontier scan (kept for differential testing)
# ---------------------------------------------------------------------------

def bfs_int_ref(
    ten: TEN, cond: Condition, max_steps: int | None = None
) -> PathResult:
    """The original Algorithm 2 loop: expand the whole frontier one timestep
    at a time, in active-list order. ``bfs_int`` must match it bit-for-bit;
    tests/test_pathfinding_diff.py enforces that on random topologies and
    TEN states."""
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    if not dests:
        return PathResult([], {src: cond.release}, {src: cond.release})

    t = int(cond.release)
    visited: dict[int, float] = {src: float(t)}
    pred: dict[int, tuple[int, int, float, float]] = {}
    active: list[int] = [src]
    remaining = set(dests)
    if max_steps is None:
        max_steps = int(ten.horizon()) + topo.num_nodes + int(cond.release) + 4

    while remaining:
        if t > max_steps:
            raise AssertionError(
                f"chunk {cond.chunk}: unreachable dests {sorted(remaining)}"
            )
        next_active: list[int] = []
        newly: list[int] = []
        for u in active:
            node_u = topo.nodes[u]
            is_sw = ten.topology.is_switch(u)
            budget = 1 if (is_sw and not node_u.multicast) else None
            sent = 0
            has_unvisited = False
            for link in topo.out_links(u):
                v = link.dst
                if v in visited:
                    continue
                has_unvisited = True
                if budget is not None and sent >= budget:
                    break
                if not ten.free_int(link.id, t):
                    continue
                if topo.is_switch(v) and not ten.buffer_has_room(v, t + 1):
                    continue  # paper §4.7: skip full switch at this timestep
                visited[v] = float(t + 1)
                pred[v] = (u, link.id, float(t), float(t + 1))
                newly.append(v)
                remaining.discard(v)
                sent += 1
                if not remaining:
                    break
            if not remaining:
                break
            if has_unvisited:
                next_active.append(u)  # may still expand later
        active = next_active + newly
        t += 1

    return _prune(cond.chunk, src, dests, pred, visited)


# ---------------------------------------------------------------------------
# Heterogeneous earliest-arrival search (paper §4.6)
# ---------------------------------------------------------------------------

def bfs_cont(ten: TEN, cond: Condition, max_time: float | None = None) -> PathResult:
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    if not dests:
        return PathResult([], {src: cond.release}, {src: cond.release})

    if max_time is None:
        slowest = max(
            (l.transfer_time(cond.bytes) for l in topo.links), default=1.0
        )
        max_time = ten.horizon() + cond.release + slowest * (topo.num_nodes + 4)

    visited: dict[int, float] = {}
    pred: dict[int, tuple[int, int, float, float]] = {}
    remaining = set(dests)
    counter = 0
    heap: list[tuple[float, int, int, int, float, float]] = []
    # entry: (arrival, counter, from_node, link_id, start, end)

    # Non-multicast switches serialize egress: iterate their out-links one at
    # a time ("visits next nodes one by one", §4.7).
    serial_state: dict[int, tuple[int, float]] = {}  # switch -> (next link idx, t_free)

    def push_candidate(u: int, link, t_ready: float) -> None:
        nonlocal counter
        dur = link.transfer_time(cond.bytes)
        start = ten.earliest_free(link.id, t_ready, dur)
        end = start + dur
        v = link.dst
        # full-buffer switches delay the send until room exists on arrival
        if topo.is_switch(v):
            guard = 0
            while not ten.buffer_has_room(v, end):
                drop = ten.next_drop_after(v, end)
                if drop == float("inf") or end > max_time:
                    return  # permanently full: candidate abandoned
                start = ten.earliest_free(link.id, max(t_ready, drop - dur), dur)
                end = start + dur
                guard += 1
                if guard > 10000:
                    raise AssertionError("switch buffer search did not converge")
        if end > max_time:
            return
        counter += 1
        heapq.heappush(heap, (end, counter, u, link.id, start, end))

    def expand(u: int, t_arrive: float) -> None:
        node_u = topo.nodes[u]
        if topo.is_switch(u) and not node_u.multicast:
            serial_state[u] = (0, t_arrive)
            push_next_serial(u)
        else:
            for link in topo.out_links(u):
                if link.dst not in visited:
                    push_candidate(u, link, t_arrive)

    def push_next_serial(u: int) -> None:
        idx, t_free = serial_state[u]
        outs = topo.out_links(u)
        while idx < len(outs):
            link = outs[idx]
            serial_state[u] = (idx + 1, t_free)
            if link.dst not in visited:
                push_candidate(u, link, t_free)
                return
            idx += 1
        serial_state[u] = (idx, t_free)

    visited[src] = cond.release
    expand(src, cond.release)

    while remaining and heap:
        end, _, u, link_id, start, t_end = heapq.heappop(heap)
        link = topo.links[link_id]
        v = link.dst
        if topo.is_switch(u) and not topo.nodes[u].multicast:
            # serialized egress: this send (whether used or not) defines when
            # the next one may be attempted only if it was actually taken;
            # if v was visited meanwhile, try the next out-link immediately.
            if v in visited:
                push_next_serial(u)
                continue
            visited[v] = t_end
            pred[v] = (u, link_id, start, t_end)
            remaining.discard(v)
            idx, _ = serial_state[u]
            serial_state[u] = (idx, t_end)  # egress busy until this send ends
            push_next_serial(u)
            expand(v, t_end)
        else:
            if v in visited:
                continue
            visited[v] = t_end
            pred[v] = (u, link_id, start, t_end)
            remaining.discard(v)
            expand(v, t_end)

    if remaining:
        raise AssertionError(
            f"chunk {cond.chunk}: unreachable dests {sorted(remaining)} "
            f"within horizon {max_time}"
        )
    return _prune(cond.chunk, src, dests, pred, visited)
