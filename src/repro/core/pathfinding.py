"""BFS pathfinding over the TEN (paper §4.3, Algorithm 2).

Given one condition (chunk, src, dests), find timed store-and-forward paths
from src to every destination, over links not yet occupied by previously
scheduled chunks. Two modes:

* ``bfs_int``: the paper's homogeneous, synchronous TEN — discrete unit
  timesteps, frontier expansion per timestep, exactly Algorithm 2 + Fig. 6.
* ``bfs_cont``: the heterogeneous generalization (paper §4.6) — earliest-
  arrival search where each link candidate carries its alpha-beta transfer
  time and links have busy *intervals*; with all-equal link times it visits
  nodes in the same order as ``bfs_int``.

Both return the *pruned* transfer set: the BFS may visit more nodes than
requested (paper Fig. 6d), and only edges on some src->dest path are retained
(Fig. 6e) — including through out-of-process-group NPUs, which is where the
paper's process-group awareness comes from.

Switch handling (paper §4.7): visiting a full switch is skipped until its
buffer drains; non-multicast switches serialize their egress (one next
neighbor per step, "visits next nodes one by one").
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

from repro.core.algorithm import Transfer
from repro.core.conditions import Condition
from repro.core.ten import TEN

_EPS = 1e-9


@dataclass
class PathResult:
    """Pruned transfers + chunk arrival time at every retained node."""

    transfers: list[Transfer]
    arrivals: dict[int, float]  # node -> arrival time (retained nodes only)
    reached: dict[int, float]  # dest -> arrival time


def _prune(
    chunk: int,
    src: int,
    dests: frozenset[int],
    pred: dict[int, tuple[int, int, float, float]],
    visited: dict[int, float],
) -> PathResult:
    """Keep only edges on some src->dest path (paper Fig. 6e)."""
    keep: dict[tuple[int, float], Transfer] = {}
    arrivals: dict[int, float] = {src: visited[src]}
    reached: dict[int, float] = {}
    for dest in dests:
        if dest == src:
            reached[dest] = visited[src]
            continue
        if dest not in visited:
            raise AssertionError(f"chunk {chunk}: BFS did not reach dest {dest}")
        reached[dest] = visited[dest]
        node = dest
        while node != src:
            u, link, s, e = pred[node]
            key = (link, s)
            if key not in keep:
                keep[key] = Transfer(chunk, link, u, node, s, e)
            arrivals[node] = e
            node = u
    transfers = sorted(keep.values(), key=lambda t: (t.start, t.link))
    return PathResult(transfers, arrivals, reached)


# ---------------------------------------------------------------------------
# Homogeneous synchronous BFS (Algorithm 2)
# ---------------------------------------------------------------------------

def bfs_int(ten: TEN, cond: Condition, max_steps: int | None = None) -> PathResult:
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    if not dests:
        return PathResult([], {src: cond.release}, {src: cond.release})

    t = int(cond.release)
    visited: dict[int, float] = {src: float(t)}
    pred: dict[int, tuple[int, int, float, float]] = {}
    active: list[int] = [src]
    remaining = set(dests)
    if max_steps is None:
        # Links become free after the committed horizon, so any connected
        # destination is reachable within horizon + |V| steps.
        max_steps = int(ten.horizon()) + topo.num_nodes + int(cond.release) + 4

    while remaining:
        if t > max_steps:
            raise AssertionError(
                f"chunk {cond.chunk}: unreachable dests {sorted(remaining)}"
            )
        next_active: list[int] = []
        newly: list[int] = []
        for u in active:
            node_u = topo.nodes[u]
            is_sw = ten.topology.is_switch(u)
            budget = 1 if (is_sw and not node_u.multicast) else None
            sent = 0
            has_unvisited = False
            for link in topo.out_links(u):
                v = link.dst
                if v in visited:
                    continue
                has_unvisited = True
                if budget is not None and sent >= budget:
                    break
                if not ten.free_int(link.id, t):
                    continue
                if topo.is_switch(v) and not ten.buffer_has_room(v, t + 1):
                    continue  # paper §4.7: skip full switch at this timestep
                visited[v] = float(t + 1)
                pred[v] = (u, link.id, float(t), float(t + 1))
                newly.append(v)
                remaining.discard(v)
                sent += 1
                if not remaining:
                    break
            if not remaining:
                break
            if has_unvisited:
                next_active.append(u)  # may still expand later
        active = next_active + newly
        t += 1

    return _prune(cond.chunk, src, dests, pred, visited)


# ---------------------------------------------------------------------------
# Heterogeneous earliest-arrival search (paper §4.6)
# ---------------------------------------------------------------------------

def bfs_cont(ten: TEN, cond: Condition, max_time: float | None = None) -> PathResult:
    topo = ten.topology
    src = cond.src
    dests = cond.remote_dests
    if not dests:
        return PathResult([], {src: cond.release}, {src: cond.release})

    if max_time is None:
        slowest = max(
            (l.transfer_time(cond.bytes) for l in topo.links), default=1.0
        )
        max_time = ten.horizon() + cond.release + slowest * (topo.num_nodes + 4)

    visited: dict[int, float] = {}
    pred: dict[int, tuple[int, int, float, float]] = {}
    remaining = set(dests)
    counter = 0
    heap: list[tuple[float, int, int, int, float, float]] = []
    # entry: (arrival, counter, from_node, link_id, start, end)

    # Non-multicast switches serialize egress: iterate their out-links one at
    # a time ("visits next nodes one by one", §4.7).
    serial_state: dict[int, tuple[int, float]] = {}  # switch -> (next link idx, t_free)

    def push_candidate(u: int, link, t_ready: float) -> None:
        nonlocal counter
        dur = link.transfer_time(cond.bytes)
        start = ten.earliest_free(link.id, t_ready, dur)
        end = start + dur
        v = link.dst
        # full-buffer switches delay the send until room exists on arrival
        if topo.is_switch(v):
            guard = 0
            while not ten.buffer_has_room(v, end):
                drop = ten.next_drop_after(v, end)
                if drop == float("inf") or end > max_time:
                    return  # permanently full: candidate abandoned
                start = ten.earliest_free(link.id, max(t_ready, drop - dur), dur)
                end = start + dur
                guard += 1
                if guard > 10000:
                    raise AssertionError("switch buffer search did not converge")
        if end > max_time:
            return
        counter += 1
        heapq.heappush(heap, (end, counter, u, link.id, start, end))

    def expand(u: int, t_arrive: float) -> None:
        node_u = topo.nodes[u]
        if topo.is_switch(u) and not node_u.multicast:
            serial_state[u] = (0, t_arrive)
            push_next_serial(u)
        else:
            for link in topo.out_links(u):
                if link.dst not in visited:
                    push_candidate(u, link, t_arrive)

    def push_next_serial(u: int) -> None:
        idx, t_free = serial_state[u]
        outs = topo.out_links(u)
        while idx < len(outs):
            link = outs[idx]
            serial_state[u] = (idx + 1, t_free)
            if link.dst not in visited:
                push_candidate(u, link, t_free)
                return
            idx += 1
        serial_state[u] = (idx, t_free)

    visited[src] = cond.release
    expand(src, cond.release)

    while remaining and heap:
        end, _, u, link_id, start, t_end = heapq.heappop(heap)
        link = topo.links[link_id]
        v = link.dst
        if topo.is_switch(u) and not topo.nodes[u].multicast:
            # serialized egress: this send (whether used or not) defines when
            # the next one may be attempted only if it was actually taken;
            # if v was visited meanwhile, try the next out-link immediately.
            if v in visited:
                push_next_serial(u)
                continue
            visited[v] = t_end
            pred[v] = (u, link_id, start, t_end)
            remaining.discard(v)
            idx, _ = serial_state[u]
            serial_state[u] = (idx, t_end)  # egress busy until this send ends
            push_next_serial(u)
            expand(v, t_end)
        else:
            if v in visited:
                continue
            visited[v] = t_end
            pred[v] = (u, link_id, start, t_end)
            remaining.discard(v)
            expand(v, t_end)

    if remaining:
        raise AssertionError(
            f"chunk {cond.chunk}: unreachable dests {sorted(remaining)} "
            f"within horizon {max_time}"
        )
    return _prune(cond.chunk, src, dests, pred, visited)
