"""Condition-based collective representation (paper §4.1, Fig. 5).

Preconditions/postconditions are NPU-centric; PCCL's *condition* view is
chunk-centric: each condition names a chunk, the NPU that initially holds it,
and the set of NPUs that must hold it afterwards. Reduction collectives are
described by :class:`ReduceCondition` — a chunk assembled from per-NPU
contributions — and are synthesized by reversing the corresponding
non-reduction algorithm (paper §4.5).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from functools import cached_property


@dataclass(frozen=True)
class Condition:
    """One chunk's journey: src NPU -> every NPU in dests.

    bytes sizes the chunk for alpha-beta timing; release is the earliest time
    the chunk may leave its source (used to compose phases, e.g. All-Reduce =
    Reduce-Scatter then All-Gather).
    """

    chunk: int
    src: int
    dests: frozenset[int]
    bytes: float = 1.0
    release: float = 0.0
    tag: str = ""

    def __post_init__(self):
        if type(self.dests) is not frozenset:
            object.__setattr__(self, "dests", frozenset(self.dests))
        if not self.dests:
            raise ValueError(f"chunk {self.chunk}: empty destination set")

    @cached_property
    def remote_dests(self) -> frozenset[int]:
        return self.dests - {self.src}


@dataclass(frozen=True)
class ReduceCondition:
    """A reduced chunk: contributions from every NPU in srcs, combined
    (associative/commutative op, e.g. add) and delivered to every NPU in dests."""

    chunk: int
    srcs: frozenset[int]
    dests: frozenset[int]
    bytes: float = 1.0
    release: float = 0.0
    tag: str = ""

    def __post_init__(self):
        object.__setattr__(self, "srcs", frozenset(self.srcs))
        object.__setattr__(self, "dests", frozenset(self.dests))
        if not self.srcs or not self.dests:
            raise ValueError(f"chunk {self.chunk}: empty srcs/dests")


class ChunkIds:
    """Dense unique chunk-id allocator, shared across process groups so that a
    joint synthesis over several concurrent collectives never aliases chunks.

    ``split()`` hands out child allocators that draw from the *same*
    underlying counter, so independent condition builders (one per process
    group) can be composed into a joint synthesis without hand-threading a
    single allocator through every call site — the classic collision footgun
    that ``SynthesisEngine.synthesize_joint`` rejects with a ``ValueError``.
    """

    def __init__(self, start: int = 0):
        self._counter = itertools.count(start)

    def next(self) -> int:
        return next(self._counter)

    def split(self, k: int = 2) -> "list[ChunkIds]":
        """``k`` child allocators sharing this allocator's counter: ids drawn
        from any child (or from ``self``) are globally unique."""
        if k < 1:
            raise ValueError(f"cannot split into {k} allocators")
        children = []
        for _ in range(k):
            child = ChunkIds.__new__(ChunkIds)
            child._counter = self._counter
            children.append(child)
        return children


# ---------------------------------------------------------------------------
# Collective pattern builders (paper Fig. 1 / Fig. 5). `group` is the process
# group: an ordered list of NPU ids. Chunk ids come from `ids` so multiple
# collectives can be synthesized jointly (paper §6.4, Fig. 15).
# ---------------------------------------------------------------------------

def broadcast(group: list[int], root: int, ids: ChunkIds | None = None,
              bytes: float = 1.0, tag: str = "bcast") -> list[Condition]:
    ids = ids or ChunkIds()
    return [Condition(ids.next(), root, frozenset(group), bytes, tag=tag)]


def multicast(src: int, dests: list[int], ids: ChunkIds | None = None,
              bytes: float = 1.0, tag: str = "mcast") -> list[Condition]:
    ids = ids or ChunkIds()
    return [Condition(ids.next(), src, frozenset(dests), bytes, tag=tag)]


def point_to_point(src: int, dst: int, ids: ChunkIds | None = None,
                   bytes: float = 1.0, tag: str = "p2p") -> list[Condition]:
    ids = ids or ChunkIds()
    return [Condition(ids.next(), src, frozenset([dst]), bytes, tag=tag)]


def scatter(group: list[int], root: int, ids: ChunkIds | None = None,
            bytes: float = 1.0, tag: str = "scatter") -> list[Condition]:
    ids = ids or ChunkIds()
    return [
        Condition(ids.next(), root, frozenset([dst]), bytes, tag=tag)
        for dst in group
        if dst != root
    ]


def gather(group: list[int], root: int, ids: ChunkIds | None = None,
           bytes: float = 1.0, tag: str = "gather") -> list[Condition]:
    ids = ids or ChunkIds()
    return [
        Condition(ids.next(), src, frozenset([root]), bytes, tag=tag)
        for src in group
        if src != root
    ]


def all_gather(group: list[int], ids: ChunkIds | None = None,
               bytes: float = 1.0, chunks_per_npu: int = 1,
               tag: str = "allgather") -> list[Condition]:
    ids = ids or ChunkIds()
    dests = frozenset(group)
    return [
        Condition(ids.next(), src, dests, bytes, tag=tag)
        for src in group
        for _ in range(chunks_per_npu)
    ]


def all_to_all(group: list[int], ids: ChunkIds | None = None,
               bytes: float = 1.0, chunks_per_pair: int = 1,
               tag: str = "alltoall") -> list[Condition]:
    ids = ids or ChunkIds()
    return [
        Condition(ids.next(), src, frozenset([dst]), bytes, tag=tag)
        for src in group
        for dst in group
        if src != dst
        for _ in range(chunks_per_pair)
    ]


def all_to_allv(group: list[int], counts: dict[tuple[int, int], int] | list[list[int]],
                ids: ChunkIds | None = None, bytes: float = 1.0,
                tag: str = "alltoallv") -> list[Condition]:
    """All-to-Allv: counts[(i, j)] (or counts[i][j] by group index) chunks from
    NPU i to NPU j. MoE expert-parallel dispatch is exactly this pattern."""
    ids = ids or ChunkIds()
    conds: list[Condition] = []
    if isinstance(counts, list):
        counts = {
            (group[i], group[j]): counts[i][j]
            for i in range(len(group))
            for j in range(len(group))
        }
    for (src, dst), k in sorted(counts.items()):
        if src == dst:
            continue
        for _ in range(k):
            conds.append(Condition(ids.next(), src, frozenset([dst]), bytes, tag=tag))
    return conds


def reduce(group: list[int], root: int, ids: ChunkIds | None = None,
           bytes: float = 1.0, tag: str = "reduce") -> list[ReduceCondition]:
    ids = ids or ChunkIds()
    return [ReduceCondition(ids.next(), frozenset(group), frozenset([root]), bytes, tag=tag)]


def reduce_scatter(group: list[int], ids: ChunkIds | None = None,
                   bytes: float = 1.0, chunks_per_npu: int = 1,
                   tag: str = "reducescatter") -> list[ReduceCondition]:
    ids = ids or ChunkIds()
    srcs = frozenset(group)
    return [
        ReduceCondition(ids.next(), srcs, frozenset([owner]), bytes, tag=tag)
        for owner in group
        for _ in range(chunks_per_npu)
    ]


def all_reduce(group: list[int], ids: ChunkIds | None = None,
               bytes: float = 1.0, chunks_per_npu: int = 1,
               tag: str = "allreduce") -> list[ReduceCondition]:
    ids = ids or ChunkIds()
    srcs = frozenset(group)
    dests = frozenset(group)
    return [
        ReduceCondition(ids.next(), srcs, dests, bytes, tag=tag)
        for _ in group
        for _ in range(chunks_per_npu)
    ]


def with_release(conds: list[Condition], release: float) -> list[Condition]:
    return [replace(c, release=release) for c in conds]


def gather_view(rconds: list[ReduceCondition],
                tag: str = "rev_gather") -> list[Condition]:
    """The broadcast/gather dual of single-destination reduce conditions.

    PCCL synthesizes reductions by reversal (paper §4.5): a chunk reduced
    from ``srcs`` onto one root is the time-reversal of that chunk being
    multicast from the root to ``srcs`` on the link-reversed fabric. This
    helper produces those dual conditions — chunk ids carry over, so the
    reversed schedule maps back onto the reduce conditions positionally.
    Both the flat engine internals and the hierarchical pipeline share it.
    """
    out = []
    for r in rconds:
        if len(r.dests) != 1:
            raise ValueError(
                f"chunk {r.chunk}: gather_view needs a single reduction "
                f"root, got dests={sorted(r.dests)}"
            )
        out.append(Condition(r.chunk, next(iter(r.dests)), r.srcs,
                             bytes=r.bytes, tag=tag))
    return out
