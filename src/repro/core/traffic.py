"""Inter-pod traffic engineering: min-max link-load gateway assignment.

The hierarchical pipeline in :mod:`repro.core.hierarchy` decomposes a
pod-spanning collective into intra / inter / scatter phases; every
cross-pod chunk must be pinned to an (egress gateway, ingress gateway,
boundary path) triple before the phases are synthesized. Round-robin
cycling balances *counts*, which is optimal only when every boundary link
has equal timing — on asymmetric DCI fabrics (skewed uplink counts or
non-uniform uplink bandwidths) it leaves the slow links hot while fast
uplinks idle. This module treats the selection as a load-balancing
assignment over the boundary fabric (TACCL's routing sketch applied to
the pod graph; TE-CCL's per-chunk flow objective):

* the per-chunk inter-pod **demand matrix** is collected during
  decomposition and handed to :class:`TrafficEngineer`;
* each demand is assigned greedily to the candidate triple minimizing the
  resulting **maximum link busy-time** (load is accumulated in time
  units — ``transfer_time(bytes)`` per link — so a 4x-slower uplink
  saturates 4x earlier), with deterministic tie-breaks (path cost, then
  intra-pod distance, then gateway index) so plans are reproducible and
  registry-cacheable;
* small instances get an **exact refinement pass** (branch-and-bound over
  the per-demand candidate trees) that certifies the min-max optimum
  within the candidate space;
* the greedy result is **never worse than round-robin**: callers hand the
  legacy round-robin assignment to :meth:`TrafficEngineer.better_of`,
  which keeps whichever assignment has the lower modeled peak load.

:class:`CommSketch` carries operator constraints (TACCL-style
communication sketches) that act on the same assignment as hard
constraints: gateway affinities restrict the candidate gateways per pod,
node/link exclusions remove hardware (e.g. a storage plane) from the
boundary fabric entirely, and per-pod port caps bound how many distinct
gateways a pod may use. An unsatisfiable sketch raises
:class:`SketchInfeasibleError` — never a silent fallback to an
unconstrained plan.
"""

from __future__ import annotations

import hashlib
import heapq
from dataclasses import dataclass, field

from repro.core.errors import PCCLError
from repro.topology.topology import Topology

__all__ = ["CommSketch", "SketchInfeasibleError", "TrafficEngineer"]

# beyond this many demands the exact pass is skipped (greedy + local
# refinement only); below it, branch-and-bound runs with this node budget
_EXACT_MAX_DEMANDS = 24
_EXACT_NODE_BUDGET = 20000
# local-search refinement rounds (each round moves at most one demand off
# the bottleneck link; terminates early at a fixpoint)
_REFINE_ROUNDS = 64


class SketchInfeasibleError(PCCLError, ValueError):
    """A :class:`CommSketch` constraint cannot be satisfied on this fabric
    (affinity names a non-gateway, exclusions disconnect a pod pair, a port
    cap starves a demand). Deliberately NOT a ``HierarchyError``: the
    engine's auto route falls back to *flat* synthesis on ``HierarchyError``,
    which would silently ignore the sketch (the hard end of the
    :class:`repro.core.errors.PCCLError` fallback contract)."""


def _norm_pairs(mapping) -> tuple:
    """dict-or-pairs -> sorted ((key, normalized value), ...) tuple."""
    if mapping is None:
        return ()
    items = mapping.items() if hasattr(mapping, "items") else mapping
    out = []
    for k, v in items:
        if isinstance(v, (int, float)):
            out.append((int(k), int(v)))
        else:
            out.append((int(k), tuple(sorted(int(x) for x in v))))
    return tuple(sorted(out))


@dataclass(frozen=True)
class CommSketch:
    """Operator constraints on inter-pod gateway assignment (hard).

    ``gateway_affinity``
        ``{pod: iterable of gateway node ids}`` — the pod's egress/ingress
        traffic may only use these gateways. Ids are global (top-level
        fabric) node ids and must be actual gateways of that pod.
    ``exclude_nodes`` / ``exclude_links``
        Global node/link ids removed from the boundary fabric before any
        inter-pod routing — the "keep DP traffic off the storage plane"
        knob. Excluding a node drops every boundary link touching it.
    ``max_pod_ports``
        ``{pod: k}`` — the pod uses at most ``k`` distinct gateways across
        the whole assignment (a port/bandwidth cap). The engineer opens
        ports greedily and re-uses open ones once the cap is reached.

    Instances are immutable and order-normalized, so equal constraints
    always produce the same :meth:`fingerprint` — the registry key
    component that keeps sketch-constrained plans from ever being served
    to unconstrained requests (or vice versa).
    """

    gateway_affinity: tuple = ()
    exclude_nodes: frozenset = frozenset()
    exclude_links: frozenset = frozenset()
    max_pod_ports: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "gateway_affinity",
                           _norm_pairs(self.gateway_affinity))
        object.__setattr__(self, "max_pod_ports",
                           _norm_pairs(self.max_pod_ports))
        object.__setattr__(self, "exclude_nodes",
                           frozenset(int(n) for n in self.exclude_nodes))
        object.__setattr__(self, "exclude_links",
                           frozenset(int(l) for l in self.exclude_links))

    def allowed_gateways(self, pod: int) -> tuple[int, ...] | None:
        for p, gws in self.gateway_affinity:
            if p == pod:
                return gws
        return None

    def port_cap(self, pod: int) -> int | None:
        for p, k in self.max_pod_ports:
            if p == pod:
                return k
        return None

    @property
    def excludes_hardware(self) -> bool:
        return bool(self.exclude_nodes or self.exclude_links)

    def fingerprint(self) -> str:
        """Stable 16-hex digest of the normalized constraint set."""
        payload = repr((self.gateway_affinity,
                        tuple(sorted(self.exclude_nodes)),
                        tuple(sorted(self.exclude_links)),
                        self.max_pod_ports))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclass
class _Demand:
    """One assigned demand: chunk ``key`` from pod ``p`` to dest pods
    ``qs`` with its chosen egress/ingress and the boundary links its
    multicast tree occupies (bsub-local link ids, deduplicated)."""

    key: int
    p: int
    qs: tuple[int, ...]
    nbytes: float
    egress: int = -1
    ingress: dict = field(default_factory=dict)  # q -> gateway (global id)
    links: tuple[int, ...] = ()


class TrafficEngineer:
    """Greedy min-max link-load assigner over one boundary sub-topology.

    One instance per collective call: ``load`` accumulates the busy-time
    each boundary link would carry under the assignment so far. Canonical
    egress->ingress routes (deterministic Dijkstra by (time, hops, node))
    are memoized in ``route_cache``, which callers may share across calls
    — routes depend only on the fabric, not on the load state.
    """

    def __init__(self, sub: Topology, to_local: dict[int, int], *,
                 sketch: CommSketch | None = None,
                 route_cache: dict | None = None,
                 attach_egress: dict[int, tuple[float, float]] | None = None,
                 attach_ingress: dict[int, tuple[float, float]] | None = None):
        self.sub = sub
        self.to_local = to_local
        self.sketch = sketch
        # ``attach_*`` model gateway *attachment* serialization: per gateway
        # (global id), an (alpha, beta) for a virtual link standing in for
        # the gateway's pod-side fan-in (egress role) or fan-out (ingress
        # role) — without them the assigner would pile every chunk onto the
        # fastest uplink's gateway and the intra/scatter phases would
        # serialize behind that one node. Virtual links live past the real
        # ones in the shared load vector, so refinement, simulation and the
        # never-worse guarantee all see them.
        self._attach_ab: list[tuple[float, float]] = []
        self._veg = self._index_attach(attach_egress)
        self._vin = self._index_attach(attach_ingress)
        self.load = [0.0] * (sub.num_links + len(self._attach_ab))
        self._routes = route_cache if route_cache is not None else {}
        self._w_cache: dict[float, list[float]] = {}
        self._ports_used: dict[int, set[int]] = {}
        self._demands: list[_Demand] = []
        # per-demand candidate alternatives kept for refinement/exact:
        # key -> list of (egress, {q: ingress}, links tuple, cost)
        self._alts: dict[int, list] = {}

    def _index_attach(self, attach) -> dict[int, int]:
        idx = {}
        for g in sorted(attach or ()):
            idx[g] = self.sub.num_links + len(self._attach_ab)
            self._attach_ab.append(attach[g])
        return idx

    # -- geometry -----------------------------------------------------------

    def _weights(self, nbytes: float) -> list[float]:
        w = self._w_cache.get(nbytes)
        if w is None:
            w = [l.transfer_time(nbytes) for l in self.sub.links]
            w += [a + nbytes * b for a, b in self._attach_ab]
            self._w_cache[nbytes] = w
        return w

    def route(self, e: int, i: int) -> tuple[float, tuple[int, ...]] | None:
        """Canonical cheapest path egress ``e`` -> ingress ``i`` (global
        ids) over the boundary fabric: Dijkstra on per-hop transfer time
        for unit bytes, deterministic tie-break on (time, hops, node id),
        links relaxed in id order. Returns (cost, bsub-local link ids) or
        None when unreachable."""
        key = (e, i)
        got = self._routes.get(key)
        if got is not None:
            return got if got != () else None
        el, il = self.to_local.get(e), self.to_local.get(i)
        if el is None or il is None:
            self._routes[key] = ()
            return None
        if el == il:
            self._routes[key] = (0.0, ())
            return 0.0, ()
        sub = self.sub
        dist: dict[int, tuple[float, int]] = {el: (0.0, 0)}
        prev: dict[int, tuple[int, int]] = {}  # node -> (prev node, link)
        heap = [(0.0, 0, el)]
        while heap:
            d, h, u = heapq.heappop(heap)
            if (d, h) > dist.get(u, (float("inf"), 0)):
                continue
            if u == il:
                break
            for l in sub.out_links(u):
                nd, nh = d + l.transfer_time(1.0), h + 1
                cur = dist.get(l.dst)
                if cur is None or (nd, nh) < cur:
                    dist[l.dst] = (nd, nh)
                    prev[l.dst] = (u, l.id)
                    heapq.heappush(heap, (nd, nh, l.dst))
        if il not in dist:
            self._routes[key] = ()
            return None
        links = []
        u = il
        while u != el:
            u, lid = prev[u]
            links.append(lid)
        links.reverse()
        got = (dist[il][0], tuple(links))
        self._routes[key] = got
        return got

    # -- sketch-constrained candidate sets ----------------------------------

    def _cap_filter(self, pod: int, cands: list[int]) -> list[int]:
        cap = self.sketch.port_cap(pod) if self.sketch else None
        if cap is None:
            return cands
        used = self._ports_used.get(pod, set())
        if len(used) < cap:
            return cands
        out = [g for g in cands if g in used]
        if not out:
            raise SketchInfeasibleError(
                f"pod {pod}: max_pod_ports={cap} leaves no usable gateway "
                f"for this demand")
        return out

    def _mark_ports(self, pod: int, gw: int) -> None:
        if self.sketch and self.sketch.port_cap(pod) is not None:
            self._ports_used.setdefault(pod, set()).add(gw)

    # -- assignment ---------------------------------------------------------

    def assign(self, key: int, p: int, egress_cands: list[int],
               ingress_cands: dict[int, list[int]], nbytes: float,
               ingress_tie=None) -> tuple[int, dict[int, int]]:
        """Assign one demand (chunk ``key``, source pod ``p``, one ingress
        per destination pod) to the candidate tree minimizing the resulting
        peak link busy-time. ``ingress_tie(q, gw)`` optionally supplies a
        secondary objective (e.g. intra-pod distance to the final
        destination). Returns (egress, {dest pod: ingress}) and commits the
        tree's load."""
        w = self._weights(nbytes)
        load = self.load
        qs = sorted(ingress_cands)
        best = None  # (key, egress, {q: ingress}, links tuple, cost)
        alts = []
        for ei, e in enumerate(self._cap_filter(p, egress_cands)):
            picks: dict[int, int] = {}
            tree: set[int] = set()
            ve = self._veg.get(e)
            if ve is not None:
                tree.add(ve)
            cost = 0.0
            ok = True
            for q in qs:
                bq = None
                for ii, i in enumerate(self._cap_filter(q, ingress_cands[q])):
                    r = self.route(e, i)
                    if r is None:
                        continue
                    rc, links = r
                    vi = self._vin.get(i)
                    if vi is not None:
                        links = links + (vi,)
                    peak = 0.0
                    for l in links:
                        x = load[l] + w[l]
                        if x > peak:
                            peak = x
                    tie = ingress_tie(q, i) if ingress_tie else 0
                    k2 = (peak, rc, tie, ii)
                    if bq is None or k2 < bq[0]:
                        bq = (k2, i, links, rc)
                if bq is None:
                    ok = False
                    break
                picks[q] = bq[1]
                tree.update(bq[2])
                cost += bq[3]
            if not ok:
                continue
            links = tuple(sorted(tree))
            peak = 0.0
            for l in links:
                x = load[l] + w[l]
                if x > peak:
                    peak = x
            alts.append((e, dict(picks), links, cost))
            k2 = (peak, cost, ei)
            if best is None or k2 < best[0]:
                best = (k2, e, picks, links, cost)
        if best is None:
            if self.sketch is not None:
                raise SketchInfeasibleError(
                    f"demand {key} (pod {p} -> pods {qs}): no sketch-"
                    f"feasible (egress, ingress) assignment")
            raise ValueError(
                f"demand {key} (pod {p} -> pods {qs}): no boundary route")
        _, e, picks, links, cost = best
        for l in links:
            load[l] += w[l]
        self._mark_ports(p, e)
        for q, i in picks.items():
            self._mark_ports(q, i)
        self._demands.append(_Demand(key, p, tuple(qs), nbytes, e,
                                     dict(picks), links))
        self._alts[key] = alts
        return e, picks

    def peak(self) -> float:
        return max(self.load, default=0.0)

    def assignments(self) -> list[tuple[int, int, dict[int, int]]]:
        """[(key, egress, {dest pod: ingress})] in assignment order — the
        final state after any refinement/adoption pass."""
        return [(d.key, d.egress, dict(d.ingress)) for d in self._demands]

    # -- refinement ---------------------------------------------------------

    def refine(self) -> None:
        """Improve the greedy assignment in place: an exact branch-and-bound
        pass when the instance is small enough to certify, else bounded
        local search moving demands off the bottleneck link. Both are
        deterministic and only ever lower the peak load."""
        if not self._demands:
            return
        if self.sketch is not None and self.sketch.max_pod_ports:
            # alternatives were recorded against the port-usage state at
            # assign time; retargeting could open a port past the cap
            return
        if len(self._demands) <= _EXACT_MAX_DEMANDS and self._exact():
            return
        self._local_search()

    def _retarget(self, d: _Demand, alt) -> None:
        """Re-point demand ``d`` at alternative ``alt``, updating loads."""
        w = self._weights(d.nbytes)
        for l in d.links:
            self.load[l] -= w[l]
        e, picks, links, _ = alt
        for l in links:
            self.load[l] += w[l]
        d.egress, d.ingress, d.links = e, dict(picks), links

    def _local_search(self) -> None:
        for _ in range(_REFINE_ROUNDS):
            peak = self.peak()
            if peak <= 0.0:
                return
            hot = self.load.index(peak)
            moved = False
            for d in self._demands:
                if hot not in d.links:
                    continue
                w = self._weights(d.nbytes)
                for l in d.links:
                    self.load[l] -= w[l]
                best = None
                for alt in self._alts.get(d.key, ()):
                    apeak = max((self.load[l] + w[l] for l in alt[2]),
                                default=0.0)
                    k2 = (apeak, alt[3])
                    if best is None or k2 < best[0]:
                        best = (k2, alt)
                for l in d.links:
                    self.load[l] += w[l]
                if best is not None and best[0][0] < peak \
                        and max(self.load) < peak + 1e-12:
                    # strict improvement exists and the peak is this link's
                    self._retarget(d, best[1])
                    if self.peak() < peak - 1e-12:
                        moved = True
                        break
            if not moved:
                return

    def _exact(self) -> bool:
        """Branch-and-bound over the recorded per-demand alternatives:
        certifies the min-max optimum within the candidate space for small
        pod graphs. Returns False (leaving the greedy assignment) when the
        search space or node budget is exceeded."""
        demands = self._demands
        alt_lists = []
        space = 1
        for d in demands:
            alts = self._alts.get(d.key)
            if not alts:
                return False
            alt_lists.append(alts)
            space *= len(alts)
            if space > 1 << 20:
                return False
        # residual load not owned by any recorded demand (callers only ever
        # route through assign(), so this is normally all zeros)
        w_of = {d.key: self._weights(d.nbytes) for d in demands}
        residual = list(self.load)
        for d in demands:
            w = w_of[d.key]
            for l in d.links:
                residual[l] -= w[l]
        best_peak = self.peak()
        best_choice = None
        budget = [_EXACT_NODE_BUDGET]

        # order demands by fewest alternatives first (classic B&B heuristic)
        order = sorted(range(len(demands)),
                       key=lambda k: (len(alt_lists[k]), k))

        def dfs(pos: int, load: list[float], peak: float, choice: list):
            nonlocal best_peak, best_choice
            if budget[0] <= 0 or peak >= best_peak:
                return
            if pos == len(order):
                best_peak = peak
                best_choice = list(choice)
                return
            k = order[pos]
            d = demands[k]
            w = w_of[d.key]
            scored = []
            for ai, alt in enumerate(alt_lists[k]):
                p2 = peak
                for l in alt[2]:
                    x = load[l] + w[l]
                    if x > p2:
                        p2 = x
                scored.append((p2, alt[3], ai))
            scored.sort()
            for p2, _, ai in scored:
                if p2 >= best_peak:
                    break
                if budget[0] <= 0:
                    return
                budget[0] -= 1
                alt = alt_lists[k][ai]
                for l in alt[2]:
                    load[l] += w[l]
                choice.append((k, ai))
                dfs(pos + 1, load, p2, choice)
                choice.pop()
                for l in alt[2]:
                    load[l] -= w[l]

        dfs(0, list(residual), max(residual, default=0.0), [])
        if best_choice is None or budget[0] <= 0:
            return budget[0] > 0  # exhausted budget: keep greedy, unproven
        for k, ai in best_choice:
            self._retarget(demands[k], alt_lists[k][ai])
        return True

    # -- the never-worse-than-round-robin guarantee -------------------------

    def _alternative_for(self, d: _Demand, e: int, picks: dict):
        """Express a fixed (egress, ingress) choice for demand ``d`` as an
        alternative tuple, or None when some leg has no boundary route."""
        tree: set[int] = set()
        ve = self._veg.get(e)
        if ve is not None:
            tree.add(ve)
        cost = 0.0
        for q in d.qs:
            r = self.route(e, picks[q])
            if r is None:
                return None
            tree.update(r[1])
            vi = self._vin.get(picks[q])
            if vi is not None:
                tree.add(vi)
            cost += r[0]
        return (e, dict(picks), tuple(sorted(tree)), cost)

    def simulate(self, choices) -> float:
        """Peak link busy-time a fixed assignment would produce.
        ``choices`` is [(egress, {q: ingress})], aligned with the demands
        in assignment order — the legacy round-robin selection scored under
        the same load model."""
        load = [0.0] * len(self.load)
        for d, (e, picks) in zip(self._demands, choices):
            alt = self._alternative_for(d, e, picks)
            if alt is None:
                return float("inf")
            w = self._weights(d.nbytes)
            for l in alt[2]:
                load[l] += w[l]
        return max(load, default=0.0)

    def better_of(self, rr_choices) -> bool:
        """Adopt the round-robin assignment wholesale when its modeled peak
        is strictly lower than the engineered one — the anytime guarantee
        that TE never exceeds round-robin's max inter-pod link load even
        where greedy + refinement land in a bad local optimum.
        ``rr_choices`` aligns with the demands in assignment order.
        Returns True when the round-robin assignment was adopted."""
        if rr_choices is None or len(rr_choices) != len(self._demands):
            return False
        if self.simulate(rr_choices) >= self.peak() - 1e-12:
            return False
        for d, (e, picks) in zip(self._demands, rr_choices):
            alt = self._alternative_for(d, e, picks)
            if alt is not None:
                self._retarget(d, alt)
        return True
