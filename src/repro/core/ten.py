"""Time-Expanded Network (paper §2.6, §4.2) — array-backed.

The TEN fuses spatial topology with time. The paper presents it as a boolean
matrix ``TEN[t][s][d]`` for unit-timestep (homogeneous) networks, generalized
to alpha-beta continuous times for heterogeneous ones (paper §4.6, Fig. 9-10).

One structure covers both modes:

* **Integer fast path** (homogeneous, uniform chunk size): per-link occupancy
  is a growable numpy bitmap ``_bits[num_links, horizon]`` — exactly the
  paper's boolean TEN with the (src, dst) axis collapsed onto physical link
  ids.  ``busy_row``/``free_mask`` expose whole-timestep occupancy slices for
  vectorized frontier expansion, and a per-link Python-int mirror
  (``_masks``) answers the scalar hot-loop queries — ``free_int`` and the
  next-free-slot search in :func:`repro.core.pathfinding.bfs_int` — in a few
  word operations (``(~m) & (m + 1)`` isolates the lowest free slot).
* **Continuous intervals** (heterogeneous, §4.6): every link carries sorted
  disjoint busy intervals; "removing TEN links" (paper Fig. 7/10) =
  committing a busy interval.

TENs are reusable: :meth:`reset` clears all occupancy in O(allocated) without
reallocating, so :class:`repro.core.engine.SynthesisEngine` keeps one TEN per
topology across collectives instead of constructing one per call.

Switches (paper §4.7) additionally carry residency intervals (chunks
buffered) used to enforce finite buffer limits during pathfinding.
"""

from __future__ import annotations

import bisect
from collections import defaultdict

import numpy as np

from repro.topology.topology import Topology

_EPS = 1e-9
_INITIAL_HORIZON = 64


class TEN:
    def __init__(self, topology: Topology):
        self.topology = topology
        # per-link sorted, disjoint busy intervals [(start, end), ...]
        self._busy: list[list[tuple[float, float]]] = [
            [] for _ in range(topology.num_links)
        ]
        # per-switch committed chunk-residency intervals
        self._residency: dict[int, list[tuple[float, float]]] = defaultdict(list)
        # integer fast path: [num_links, capacity] occupancy bitmap plus a
        # per-link int mirror (bit t set = timestep t busy) for scalar queries
        self._cap = _INITIAL_HORIZON
        self._bits = np.zeros((topology.num_links, self._cap), dtype=bool)
        self._masks: list[int] = [0] * topology.num_links
        # bit_length of each mask, mirrored so the pathfinding inner loop
        # replaces a method call with a list index
        self._mask_bl: list[int] = [0] * topology.num_links
        # latest committed busy end, maintained incrementally by commit/
        # commit_int so horizon() is O(1) instead of rescanning every link
        self._horizon: float = 0.0

    def reset(self) -> None:
        """Clear all committed occupancy, keeping allocations. Re-syncs with
        the topology if links were added since construction."""
        n = self.topology.num_links
        if n != len(self._masks):
            self._busy = [[] for _ in range(n)]
            self._bits = np.zeros((n, self._cap), dtype=bool)
        else:
            for iv in self._busy:
                iv.clear()
            self._bits[:] = False
        self._masks = [0] * n
        self._mask_bl = [0] * n
        self._residency.clear()
        self._horizon = 0.0

    # ------------------------------------------------------------------
    # Continuous (heterogeneous) interface — paper §4.6
    # ------------------------------------------------------------------
    def earliest_free(self, link: int, t: float, dur: float) -> float:
        """Earliest start >= t such that [start, start+dur) avoids busy slots."""
        intervals = self._busy[link]
        start = t
        i = bisect.bisect_left(intervals, (start - _EPS, float("-inf")))
        # also consider the interval just before, which may cover `start`
        if i > 0 and intervals[i - 1][1] > start + _EPS:
            start = intervals[i - 1][1]
        while i < len(intervals):
            s, e = intervals[i]
            if start + dur <= s + _EPS:
                return start
            start = max(start, e)
            i += 1
        return start

    def commit(self, link: int, start: float, end: float) -> None:
        intervals = self._busy[link]
        i = bisect.bisect_left(intervals, (start, end))
        if i > 0 and intervals[i - 1][1] > start + _EPS:
            raise AssertionError(f"link {link}: overlap committing [{start},{end})")
        if i < len(intervals) and intervals[i][0] < end - _EPS:
            raise AssertionError(f"link {link}: overlap committing [{start},{end})")
        intervals.insert(i, (start, end))
        if end > self._horizon:
            self._horizon = end

    # ------------------------------------------------------------------
    # Integer fast path (homogeneous, uniform chunk size) — paper §4.2
    # ------------------------------------------------------------------
    def free_int(self, link: int, t: int) -> bool:
        return not (self._masks[link] >> t) & 1

    def earliest_free_int(self, link: int, t: int) -> int:
        """First timestep >= t with the link free: lowest zero bit of the
        occupancy mask at or above t."""
        m = self._masks[link] >> t
        low_zero = ~m & (m + 1)
        return t + low_zero.bit_length() - 1

    def commit_int(self, link: int, t: int) -> None:
        if (self._masks[link] >> t) & 1:
            raise AssertionError(f"link {link}: timestep {t} already occupied")
        if t >= self._cap:
            self._grow(t)
        self._bits[link, t] = True
        m = self._masks[link] | (1 << t)
        self._masks[link] = m
        self._mask_bl[link] = m.bit_length()
        if t + 1 > self._horizon:
            self._horizon = float(t + 1)

    def commit_int_many(self, transfers) -> None:
        """Bulk ``commit_int`` for a pruned path's transfers (one call per
        condition instead of one per transfer)."""
        masks = self._masks
        mask_bl = self._mask_bl
        bits = self._bits
        hi = self._horizon
        for tr in transfers:
            link = tr.link
            t = int(tr.start)
            if (masks[link] >> t) & 1:
                raise AssertionError(
                    f"link {link}: timestep {t} already occupied"
                )
            if t >= self._cap:
                self._grow(t)
                bits = self._bits
            bits[link, t] = True
            m = masks[link] | (1 << t)
            masks[link] = m
            mask_bl[link] = m.bit_length()
            if t + 1 > hi:
                hi = float(t + 1)
        self._horizon = hi

    def commit_int_cols(self, links: np.ndarray, starts: np.ndarray) -> None:
        """Columnar bulk commit: one vectorized pass for a whole preloaded
        schedule (phase composition commits millions of transfers here).
        ``starts`` are float timestamps on integer boundaries."""
        if not len(links):
            return
        t = starts.astype(np.int64)
        tmax = int(t.max())
        if tmax >= self._cap:
            self._grow(tmax)
        if self._bits[links, t].any():
            k = int(np.nonzero(self._bits[links, t])[0][0])
            raise AssertionError(
                f"link {links[k]}: timestep {int(t[k])} already occupied")
        # duplicates inside the batch would silently collapse under fancy
        # assignment — detect them the same way a serial commit would
        key = links.astype(np.int64) * (self._cap + 1) + t
        if len(np.unique(key)) != len(key):
            dup = np.sort(key)
            k = int(np.nonzero(dup[1:] == dup[:-1])[0][0])
            raise AssertionError(
                f"link {int(dup[k] // (self._cap + 1))}: timestep "
                f"{int(dup[k] % (self._cap + 1))} already occupied")
        self._bits[links, t] = True
        # rebuild the scalar mirrors only for the touched links
        for link in np.unique(links).tolist():
            m = int.from_bytes(
                np.packbits(self._bits[link], bitorder="little").tobytes(),
                "little")
            self._masks[link] = m
            self._mask_bl[link] = m.bit_length()
        if tmax + 1 > self._horizon:
            self._horizon = float(tmax + 1)

    def _grow(self, t: int) -> None:
        new_cap = max(self._cap * 2, t + 1)
        bits = np.zeros((self.topology.num_links, new_cap), dtype=bool)
        bits[:, : self._cap] = self._bits
        self._bits = bits
        self._cap = new_cap

    # -- vectorized occupancy views -------------------------------------
    def busy_row(self, t: int) -> np.ndarray:
        """Occupancy of every link at timestep ``t`` (bool[num_links])."""
        if t >= self._cap:
            return np.zeros(self.topology.num_links, dtype=bool)
        return self._bits[:, t]

    def free_mask(self, links: np.ndarray, t: int) -> np.ndarray:
        """Per-link freedom at timestep ``t`` for an int array of link ids."""
        if t >= self._cap:
            return np.ones(len(links), dtype=bool)
        return ~self._bits[links, t]

    # ------------------------------------------------------------------
    # Switch residency (buffer limits) — paper §4.7
    # ------------------------------------------------------------------
    def occupancy_at(self, switch: int, t: float) -> int:
        return sum(1 for s, e in self._residency[switch] if s - _EPS <= t < e - _EPS)

    def next_drop_after(self, switch: int, t: float) -> float:
        """Earliest residency end > t (inf if none)."""
        ends = [e for _, e in self._residency[switch] if e > t + _EPS]
        return min(ends) if ends else float("inf")

    def buffer_has_room(self, switch: int, t: float) -> bool:
        limit = self.topology.nodes[switch].buffer_limit
        return limit is None or self.occupancy_at(switch, t) < limit

    def commit_residency(self, switch: int, start: float, end: float) -> None:
        self._residency[switch].append((start, max(end, start)))

    # ------------------------------------------------------------------
    def horizon(self) -> float:
        """Latest committed busy end (safety bound for searches). Tracked
        incrementally at commit time — called once per BFS, so rescanning
        every link's intervals here was O(links) per pathfinding call."""
        return self._horizon
