"""Time-Expanded Network (paper §2.6, §4.2).

The TEN fuses spatial topology with time. The paper presents it as a boolean
matrix ``TEN[t][s][d]`` for unit-timestep (homogeneous) networks, generalized
to alpha-beta continuous times for heterogeneous ones (paper §4.6, Fig. 9-10).

We implement one structure covering both: every physical link carries a sorted
list of *busy intervals* committed by previously synthesized conditions. For a
homogeneous network with uniform chunk size this degenerates to the paper's
integer-timestep TEN (every interval is [k, k+1)), and a fast integer path is
provided. "Removing TEN links" (paper Fig. 7/10) = committing a busy interval:
any other chunk overlapping it is excluded, which is exactly the paper's rule
that a TEN link is occupied by at most one chunk.

Switches (paper §4.7) additionally carry residency intervals (chunks buffered)
used to enforce finite buffer limits during pathfinding.
"""

from __future__ import annotations

import bisect
from collections import defaultdict

from repro.topology.topology import Topology

_EPS = 1e-9


class TEN:
    def __init__(self, topology: Topology):
        self.topology = topology
        # per-link sorted, disjoint busy intervals [(start, end), ...]
        self._busy: list[list[tuple[float, float]]] = [
            [] for _ in range(topology.num_links)
        ]
        # per-switch committed chunk-residency intervals
        self._residency: dict[int, list[tuple[float, float]]] = defaultdict(list)
        # integer fast path: per-link set of occupied unit timesteps
        self._busy_int: list[set[int]] = [set() for _ in range(topology.num_links)]
        # latest committed busy end, maintained incrementally by commit/
        # commit_int so horizon() is O(1) instead of rescanning every link
        self._horizon: float = 0.0

    # ------------------------------------------------------------------
    # Continuous (heterogeneous) interface — paper §4.6
    # ------------------------------------------------------------------
    def earliest_free(self, link: int, t: float, dur: float) -> float:
        """Earliest start >= t such that [start, start+dur) avoids busy slots."""
        intervals = self._busy[link]
        start = t
        i = bisect.bisect_left(intervals, (start - _EPS, float("-inf")))
        # also consider the interval just before, which may cover `start`
        if i > 0 and intervals[i - 1][1] > start + _EPS:
            start = intervals[i - 1][1]
        while i < len(intervals):
            s, e = intervals[i]
            if start + dur <= s + _EPS:
                return start
            start = max(start, e)
            i += 1
        return start

    def commit(self, link: int, start: float, end: float) -> None:
        intervals = self._busy[link]
        i = bisect.bisect_left(intervals, (start, end))
        if i > 0 and intervals[i - 1][1] > start + _EPS:
            raise AssertionError(f"link {link}: overlap committing [{start},{end})")
        if i < len(intervals) and intervals[i][0] < end - _EPS:
            raise AssertionError(f"link {link}: overlap committing [{start},{end})")
        intervals.insert(i, (start, end))
        if end > self._horizon:
            self._horizon = end

    # ------------------------------------------------------------------
    # Integer fast path (homogeneous, uniform chunk size) — paper §4.2
    # ------------------------------------------------------------------
    def free_int(self, link: int, t: int) -> bool:
        return t not in self._busy_int[link]

    def earliest_free_int(self, link: int, t: int) -> int:
        busy = self._busy_int[link]
        while t in busy:
            t += 1
        return t

    def commit_int(self, link: int, t: int) -> None:
        if t in self._busy_int[link]:
            raise AssertionError(f"link {link}: timestep {t} already occupied")
        self._busy_int[link].add(t)
        if t + 1 > self._horizon:
            self._horizon = float(t + 1)

    # ------------------------------------------------------------------
    # Switch residency (buffer limits) — paper §4.7
    # ------------------------------------------------------------------
    def occupancy_at(self, switch: int, t: float) -> int:
        return sum(1 for s, e in self._residency[switch] if s - _EPS <= t < e - _EPS)

    def next_drop_after(self, switch: int, t: float) -> float:
        """Earliest residency end > t (inf if none)."""
        ends = [e for _, e in self._residency[switch] if e > t + _EPS]
        return min(ends) if ends else float("inf")

    def buffer_has_room(self, switch: int, t: float) -> bool:
        limit = self.topology.nodes[switch].buffer_limit
        return limit is None or self.occupancy_at(switch, t) < limit

    def commit_residency(self, switch: int, start: float, end: float) -> None:
        self._residency[switch].append((start, max(end, start)))

    # ------------------------------------------------------------------
    def horizon(self) -> float:
        """Latest committed busy end (safety bound for searches). Tracked
        incrementally at commit time — called once per BFS, so rescanning
        every link's intervals here was O(links) per pathfinding call."""
        return self._horizon
