"""PlanService: a multi-tenant facade over the algorithm registry.

A fleet runs many jobs against the same fabrics, and every job wants the
same working set of collectives — the (topology, process group, kind)
combinations induced by its mesh axes. The registry already dedupes the
synthesis work (canonicalization) and the disk cache already shares plans
across processes (atomic-rename ``.npz`` entries under ``PCCL_CACHE_DIR``);
the service adds the orchestration layer on top:

* **Planner memoization** — one :class:`MeshCollectivePlanner` per
  (topology, axis layout), so repeated ``plan()`` calls skip mesh/axes
  re-validation and share the planner's engine + TEN.
* **warm()/prefetch** — background-load a fleet's working set through the
  planner, either blocking (returns the registry stats delta) or async on
  a small thread pool (``block=False``; call :meth:`drain` before relying
  on the cache being hot). Thread safety comes from the registry's own
  lock, so warm workers and foreground lookups interleave freely.
* **repair()** — fault-aware incremental plan repair through a memoized
  per-topology :class:`repro.core.repair.PlanRepairer` sharing the same
  registry, with phase-hit/fallback/failure counters in the metrics.
* **metrics()** — hit/miss/disk-hit/eviction counters plus on-disk byte
  traffic, disk-tier eviction counters (``disk_evictions``/``disk_bytes``
  when the shared dir is size-capped via ``max_disk_bytes`` or
  ``PCCL_CACHE_MAX_BYTES``) and warm bookkeeping, for fleet dashboards.

The service lives in ``repro.core`` but imports ``repro.launch`` lazily —
only when a planner is first built — to keep the core layer import-clean.
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor

from repro.core.registry import AlgorithmRegistry, default_registry

_DEFAULT_KINDS = ("all_gather", "reduce_scatter")


class PlanService:
    """Shared plan cache + prefetch orchestration for one process.

    ``registry`` defaults to the process-wide :func:`default_registry`
    (which honors ``PCCL_CACHE_DIR``); pass ``cache_dir`` to pin a private
    registry to a specific shared directory instead.
    """

    def __init__(self, registry: AlgorithmRegistry | None = None, *,
                 cache_dir: str | None = None, max_entries: int = 256,
                 max_workers: int = 2, max_disk_bytes: int | None = None):
        if registry is None:
            if cache_dir is None:
                cache_dir = os.environ.get("PCCL_CACHE_DIR") or None
            registry = (AlgorithmRegistry(max_entries=max_entries,
                                          cache_dir=cache_dir,
                                          max_disk_bytes=max_disk_bytes)
                        if cache_dir is not None else default_registry())
        self.registry = registry
        self._lock = threading.Lock()
        self._planners: dict[tuple, object] = {}
        self._repairers: dict[int, object] = {}
        self._pool: ThreadPoolExecutor | None = None
        self._max_workers = max_workers
        self._pending: list[Future] = []
        self._warm_requested = 0
        self._warm_completed = 0
        self._warm_failed = 0
        self._repairs = 0
        self._repair_phase_hits = 0  # phase-local repairs served
        self._repair_fallbacks = 0  # fell back to cold degraded resynthesis
        self._repair_failures = 0  # FabricDegradedError raised

    # -- planners -----------------------------------------------------------

    def planner(self, topo, axis_sizes: dict[str, int]):
        """Memoized :class:`repro.launch.sharding.MeshCollectivePlanner`
        for ``(topo, axis_sizes)``, bound to this service's registry."""
        from repro.launch.sharding import MeshCollectivePlanner

        key = (id(topo), tuple(axis_sizes.items()))
        with self._lock:
            pl = self._planners.get(key)
            # id() can be recycled after GC; the identity check makes the
            # memo safe regardless
            if pl is not None and pl.topo is topo:
                return pl
            pl = MeshCollectivePlanner(topo, axis_sizes,
                                       registry=self.registry)
            self._planners[key] = pl
            return pl

    def plan(self, topo, axis_sizes: dict[str, int], kind, axis: str,
             group_index: int = 0, *, nbytes: float = 1.0, **kw):
        """One group's algorithm through the memoized planner — the main
        serving entry point. ``kind`` is a collective name or a
        :class:`repro.core.request.CollectiveRequest` (whose group the
        planner fills in from the axis)."""
        return self.planner(topo, axis_sizes).algorithm(
            kind, axis, group_index, nbytes=nbytes, **kw)

    def program(self, topo, axis_sizes: dict[str, int], kind, axis: str,
                group_index: int = 0, *, nbytes: float = 1.0,
                device_of_npu: dict[int, int] | None = None):
        """One group's executable ``(PpermuteProgram, BufferPlan)`` through
        the memoized planner — what ``repro.comms``' ``pccl_*`` primitives
        take via ``program=`` to run the collective inside shard_map.
        ``kind`` is a name or :class:`~repro.core.request.CollectiveRequest`,
        exactly as in :meth:`plan`."""
        return self.planner(topo, axis_sizes).program(
            kind, axis, group_index, nbytes=nbytes,
            device_of_npu=device_of_npu)

    # -- repair -------------------------------------------------------------

    def repairer(self, topo, *, pipeline: str | bool = "auto"):
        """Memoized :class:`repro.core.repair.PlanRepairer` for ``topo``,
        bound to this service's registry."""
        from repro.core.repair import PlanRepairer

        with self._lock:
            ent = self._repairers.get(id(topo))
            if ent is not None and ent.topology is topo \
                    and ent.pipeline == pipeline:
                return ent
            rp = PlanRepairer(topo, registry=self.registry,
                              pipeline=pipeline)
            self._repairers[id(topo)] = rp
            return rp

    def repair(self, topo, request, event, *, pipeline: str | bool = "auto",
               validate: str | None = "auto"):
        """Repair ``request`` on ``topo`` against a degradation ``event``
        (:class:`repro.core.repair.DegradationEvent`), planning it first
        when this service has no captured record yet. Returns the
        :class:`repro.core.repair.RepairResult`; counts phase-local repairs
        vs cold-resynthesis fallbacks vs loud failures in :meth:`metrics`
        (``repair_phase_hits`` / ``repair_fallbacks`` /
        ``repair_failures``)."""
        from repro.core.errors import FabricDegradedError

        rp = self.repairer(topo, pipeline=pipeline)
        if not rp.recorded(request):
            rp.plan(request)
        with self._lock:
            self._repairs += 1
        try:
            res = rp.repair(request, event, validate=validate)
        except FabricDegradedError:
            with self._lock:
                self._repair_failures += 1
            raise
        with self._lock:
            if res.strategy == "phases":
                self._repair_phase_hits += 1
            else:
                self._repair_fallbacks += 1
        return res

    # -- prefetch -----------------------------------------------------------

    def warm(self, topo, axis_sizes: dict[str, int],
             kinds=_DEFAULT_KINDS, *, nbytes: float = 1.0,
             block: bool = True):
        """Pre-populate the cache with every (axis, kind) group of the mesh.

        Blocking mode returns the registry stats dict (as
        ``MeshCollectivePlanner.warm`` does); ``block=False`` submits the
        same work to a background pool and returns a ``Future`` resolving
        to that dict. Either way the underlying registry absorbs the plans,
        so subsequent :meth:`plan` calls are hits.
        """
        pl = self.planner(topo, axis_sizes)
        self._warm_requested += 1

        def run() -> dict:
            try:
                stats = pl.warm(tuple(kinds), nbytes=nbytes)
            except Exception:
                with self._lock:
                    self._warm_failed += 1
                raise
            with self._lock:
                self._warm_completed += 1
            return stats

        if block:
            return run()
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="pccl-warm")
            fut = self._pool.submit(run)
            self._pending.append(fut)
            return fut

    def drain(self, timeout: float | None = None) -> None:
        """Wait for every outstanding background warm to finish."""
        with self._lock:
            pending, self._pending = self._pending, []
        for fut in pending:
            try:
                fut.result(timeout=timeout)
            except Exception:
                pass  # failure already counted; plans stay best-effort

    # -- observability ------------------------------------------------------

    def metrics(self) -> dict:
        """Registry counters + warm bookkeeping, one flat dict."""
        out = self.registry.stats.as_dict()
        with self._lock:
            out.update(
                entries=len(self.registry),
                planners=len(self._planners),
                warm_requested=self._warm_requested,
                warm_completed=self._warm_completed,
                warm_failed=self._warm_failed,
                repairs=self._repairs,
                repair_phase_hits=self._repair_phase_hits,
                repair_fallbacks=self._repair_fallbacks,
                repair_failures=self._repair_failures,
            )
        return out

    def close(self) -> None:
        """Shut the warm pool down (idempotent)."""
        with self._lock:
            pool, self._pool = self._pool, None
            self._pending = []
        if pool is not None:
            pool.shutdown(wait=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
