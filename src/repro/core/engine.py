"""SynthesisEngine: the single owner of the PCCL synthesis loop.

Historically every ``synthesize*`` front-end in :mod:`repro.core.synthesizer`
re-implemented the same lifecycle: build a TEN, pick int/cont mode, order
conditions, run BFS per condition, commit the pruned paths. The engine owns
that lifecycle in one place (paper §4.4, Algorithm 3) and adds two things the
front-ends could not:

* a per-topology distance cache shared across calls (condition ordering no
  longer recomputes shortest paths for every collective on the same fabric);
* an optional :class:`repro.core.registry.AlgorithmRegistry` hook — named
  collectives (all_gather, all_to_all, reductions) are fetched through the
  registry so isomorphic process groups reuse one synthesized, canonicalized
  plan instead of redoing the TEN/BFS work.

The ``synthesize*`` functions in ``synthesizer.py`` remain as thin wrappers
for backward compatibility; new code should hold a ``SynthesisEngine``.
"""

from __future__ import annotations

import heapq
from dataclasses import replace
from typing import Callable, Sequence

import numpy as np

from repro.core import conditions as cnd
from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.core.conditions import ChunkIds, Condition, ReduceCondition
from repro.core.pathfinding import PathResult, bfs_cont, bfs_int
from repro.core.registry import renumber_chunks
from repro.core.ten import TEN
from repro.topology.topology import Topology


# ---------------------------------------------------------------------------
# Distances for condition ordering (Algorithm 3, lines 1-7)
# ---------------------------------------------------------------------------

class _DistanceCache:
    """Per-source shortest-path times on the static topology, cached.

    Homogeneous graphs use hop counts; heterogeneous use alpha-beta link
    times for the given chunk size (Dijkstra).
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        self.homog = topo.homogeneous()
        self._cache: dict = {}

    def _hops_from(self, src: int) -> "list[float]":
        """Hop distances from one source, served from the topology's shared
        all-pairs matrix (one C-level sweep) when scipy is available."""
        topo = self.topo
        matrix = topo.hop_matrix()
        if matrix is not None:
            return matrix[src].tolist()
        dn = topo.hop_distances_np(src).astype(float)
        dn[dn < 0] = float("inf")
        return dn.tolist()

    def dist(self, src: int, chunk_bytes: float) -> list[float]:
        key = (src, None if self.homog else chunk_bytes)
        got = self._cache.get(key)
        if got is not None:
            return got
        topo = self.topo
        if self.homog:
            d = self._hops_from(src)
        else:
            d = [float("inf")] * topo.num_nodes
            d[src] = 0.0
            heap = [(0.0, src)]
            while heap:
                du, u = heapq.heappop(heap)
                if du > d[u]:
                    continue
                for link in topo.out_links(u):
                    alt = du + link.transfer_time(chunk_bytes)
                    if alt < d[link.dst]:
                        d[link.dst] = alt
                        heapq.heappush(heap, (alt, link.dst))
        self._cache[key] = d
        return d

    def condition_dist(self, c: Condition) -> float:
        d = self.dist(c.src, c.bytes)
        return max((d[dst] for dst in c.remote_dests), default=0.0)


def order_conditions(topo: Topology, conds: list[Condition]) -> list[Condition]:
    """Sort descending by max shortest-path distance (Algorithm 3 line 7);
    deterministic tie-break on (bytes, chunk id)."""
    return SynthesisEngine(topo).order_conditions(conds)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SynthesisEngine:
    """Owns TEN lifecycle, mode selection, condition ordering, and commit.

    One engine per physical topology; cheap to construct, cheaper to reuse
    (the distance cache and the reversed-topology view persist across calls).
    Pass a ``registry`` to share synthesized plans across isomorphic process
    groups and across engines.
    """

    def __init__(self, topology: Topology, *, registry=None):
        self.topology = topology
        self.registry = registry
        self._distances = _DistanceCache(topology)
        self._rev_topo: Topology | None = None
        # reusable per-topology state: {id(topo): (topo, TEN)} — the forward
        # and reversed views in practice. TENs are reset() per synthesis
        # instead of reallocated; distance caches persist across calls.
        self._tens: dict[int, tuple[Topology, TEN]] = {}
        self._dist_caches: dict[int, tuple[Topology, _DistanceCache]] = {
            id(topology): (topology, self._distances)
        }

    # -- lifecycle pieces ---------------------------------------------------

    def _ten_for(self, topo: Topology) -> TEN:
        ent = self._tens.get(id(topo))
        if ent is None or ent[0] is not topo:
            ent = (topo, TEN(topo))
            self._tens[id(topo)] = ent
        ten = ent[1]
        ten.reset()
        return ten

    def _dist_cache_for(self, topo: Topology) -> _DistanceCache:
        ent = self._dist_caches.get(id(topo))
        if ent is None or ent[0] is not topo:
            ent = (topo, _DistanceCache(topo))
            self._dist_caches[id(topo)] = ent
        return ent[1]

    def order_conditions(self, conds: list[Condition]) -> list[Condition]:
        return self._order(self._distances, conds)

    @staticmethod
    def _order(cache: _DistanceCache, conds: list[Condition]) -> list[Condition]:
        """Sort by (-max shortest-path distance, -bytes, chunk), stable.

        Distances come from one (cached, vectorized) pass per source; the
        composite sort key is evaluated in bulk with a numpy lexsort instead
        of a per-condition ``condition_dist`` call inside ``sorted``."""
        nc = len(conds)
        if nc <= 1:
            return list(conds)
        dist_key = np.empty(nc)
        bytes_key = np.empty(nc)
        chunk_key = np.empty(nc, dtype=np.int64)
        for k, c in enumerate(conds):
            d = cache.dist(c.src, c.bytes)
            rd = c.remote_dests
            if len(rd) == 1:
                (x,) = rd
                dist_key[k] = d[x]
            else:
                dist_key[k] = max((d[x] for x in rd), default=0.0)
            bytes_key[k] = c.bytes
            chunk_key[k] = c.chunk
        order = np.lexsort(
            (np.arange(nc), chunk_key, -bytes_key, -dist_key)
        )
        return [conds[k] for k in order]

    def _use_int_mode(self, conds: list[Condition]) -> bool:
        topo = self.topology
        if not topo.homogeneous() or not conds:
            return False
        b0 = conds[0].bytes
        if any(c.bytes != b0 for c in conds):
            return False
        if any(c.release != int(c.release) for c in conds):
            return False
        # unit transfer time required for the integer TEN
        link = topo.links[0] if topo.links else None
        return link is None or link.transfer_time(b0) == 1.0

    @staticmethod
    def _fast_int_commit(topo: Topology, int_mode: bool) -> bool:
        """True when the commit needs no switch bookkeeping (the single
        predicate behind both the per-call hoist in ``synthesize`` and the
        fallback in ``_commit``)."""
        return int_mode and not topo.csr().any_switch

    def _commit(self, ten: TEN, result: PathResult, int_mode: bool) -> None:
        # occupy links of retained paths only (paper Fig. 6e / Fig. 7)
        topo = ten.topology
        if self._fast_int_commit(topo, int_mode):
            ten.commit_int_many(result.transfers)
            return
        last_send_end: dict[int, float] = {}
        for t in result.transfers:
            if int_mode:
                ten.commit_int(t.link, int(t.start))
            else:
                ten.commit(t.link, t.start, t.end)
            if topo.is_switch(t.src):
                last_send_end[t.src] = max(last_send_end.get(t.src, 0.0), t.end)
        # switch residency: arrival .. last retained forward
        for t in result.transfers:
            if topo.is_switch(t.dst):
                ten.commit_residency(
                    t.dst, t.end, max(last_send_end.get(t.dst, t.end), t.end)
                )

    def reversed_topology(self) -> Topology:
        """The link-reversed view used for reduction synthesis, built once."""
        if self._rev_topo is None:
            self._rev_topo = self.topology.reversed()
        return self._rev_topo

    # -- Algorithm 3 --------------------------------------------------------

    def synthesize(
        self,
        conds: list[Condition],
        *,
        preload: CollectiveAlgorithm | None = None,
        mode: str = "auto",
        name: str = "pccl",
        topology: Topology | None = None,
    ) -> CollectiveAlgorithm:
        """Paper Algorithm 3 over a fresh TEN. ``preload``'s transfers are
        committed first (used to compose All-Reduce phases without link
        conflicts). ``topology`` overrides the engine's topology for internal
        reversed-topology passes."""
        topo = topology or self.topology
        ten = self._ten_for(topo)
        int_mode = mode == "int" or (mode == "auto" and self._use_int_mode(conds))
        if preload is not None:
            for t in preload.transfers:
                if int_mode:
                    ten.commit_int(t.link, int(t.start))
                else:
                    ten.commit(t.link, t.start, t.end)

        ordered = self._order(self._dist_cache_for(topo), conds)
        transfers: list[Transfer] = []
        search = bfs_int if int_mode else bfs_cont
        fast_commit = self._fast_int_commit(topo, int_mode)
        for c in ordered:
            result: PathResult = search(ten, c)
            if fast_commit:
                ten.commit_int_many(result.transfers)
            else:
                self._commit(ten, result, int_mode)
            transfers.extend(result.transfers)
        return CollectiveAlgorithm(topo, list(conds), transfers, name=name)

    def synthesize_joint(
        self,
        groups: list[tuple[str, list[Condition]]],
        *,
        name: str = "pccl_joint",
    ) -> CollectiveAlgorithm:
        """Jointly synthesize several process groups' collectives over one
        shared TEN (paper §6.4, Fig. 15). Chunk ids across groups must be
        unique — use a shared ChunkIds allocator."""
        all_conds: list[Condition] = []
        for tag, conds in groups:
            all_conds.extend(replace(c, tag=tag) for c in conds)
        seen: set[int] = set()
        for c in all_conds:
            if c.chunk in seen:
                raise ValueError(
                    f"duplicate chunk id {c.chunk} across process groups"
                )
            seen.add(c.chunk)
        return self.synthesize(all_conds, name=name)

    # -- registry routing ---------------------------------------------------

    def _routed(
        self,
        kind: str,
        group: Sequence[int],
        synth: Callable[[list[int]], CollectiveAlgorithm],
        *,
        params: tuple,
        ids: ChunkIds | None,
    ) -> CollectiveAlgorithm:
        """Fetch a named collective through the registry when one is attached;
        otherwise synthesize directly on the literal group."""
        group = list(group)
        if self.registry is None:
            return renumber_chunks(synth(group), ids)
        return self.registry.get_or_synthesize(
            self.topology, kind, group, synth, params=params, ids=ids
        )

    # -- named collectives --------------------------------------------------

    def all_gather(
        self, group: Sequence[int], *, bytes: float = 1.0,
        chunks_per_npu: int = 1, ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        def synth(g: list[int]) -> CollectiveAlgorithm:
            conds = cnd.all_gather(g, ids=ChunkIds(), bytes=bytes,
                                   chunks_per_npu=chunks_per_npu)
            return self.synthesize(conds, name="pccl_all_gather")

        return self._routed("all_gather", group, synth,
                            params=(bytes, chunks_per_npu), ids=ids)

    def all_to_all(
        self, group: Sequence[int], *, bytes: float = 1.0,
        chunks_per_pair: int = 1, ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        def synth(g: list[int]) -> CollectiveAlgorithm:
            conds = cnd.all_to_all(g, ids=ChunkIds(), bytes=bytes,
                                   chunks_per_pair=chunks_per_pair)
            return self.synthesize(conds, name="pccl_all_to_all")

        return self._routed("all_to_all", group, synth,
                            params=(bytes, chunks_per_pair), ids=ids)

    def reduce(
        self, group: Sequence[int], root: int, *, bytes: float = 1.0,
        ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        group = list(group)
        root_pos = group.index(root)

        def synth(g: list[int]) -> CollectiveAlgorithm:
            return self._reduce_impl(g, g[root_pos], bytes=bytes)

        return self._routed("reduce", group, synth,
                            params=(bytes, root_pos), ids=ids)

    def reduce_scatter(
        self, group: Sequence[int], *, bytes: float = 1.0,
        chunks_per_npu: int = 1, ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        def synth(g: list[int]) -> CollectiveAlgorithm:
            return self._reduce_scatter_impl(g, bytes=bytes,
                                             chunks_per_npu=chunks_per_npu)

        return self._routed("reduce_scatter", group, synth,
                            params=(bytes, chunks_per_npu), ids=ids)

    def all_reduce(
        self, group: Sequence[int], *, bytes: float = 1.0,
        ids: ChunkIds | None = None, pipelined: bool = False,
    ) -> CollectiveAlgorithm:
        def synth(g: list[int]) -> CollectiveAlgorithm:
            return self._all_reduce_impl(g, bytes=bytes, pipelined=pipelined)

        return self._routed("all_reduce", group, synth,
                            params=(bytes, pipelined), ids=ids)

    # -- reduction internals (paper §4.5, Fig. 8) ---------------------------

    def _reverse_algorithm(
        self,
        alg: CollectiveAlgorithm,
        reduce_conds: list[ReduceCondition],
    ) -> CollectiveAlgorithm:
        """Reverse a (broadcast/all-gather style) algorithm synthesized on the
        reversed topology into a reduction algorithm on the forward topology.

        Link k of reversed(topo) is link k of topo with endpoints swapped (by
        construction), so link ids carry over directly. A transfer at [s, e)
        maps to [T - e, T - s): in-trees become out-trees and causality is
        preserved (child partials arrive before the parent forwards its own
        partial)."""
        T = max((t.end for t in alg.transfers), default=0.0)
        base = min((c.release for c in reduce_conds), default=0.0)
        rev = [
            Transfer(t.chunk, t.link, t.dst, t.src, base + T - t.end,
                     base + T - t.start, reduce=True)
            for t in alg.transfers
        ]
        return CollectiveAlgorithm(self.topology, list(reduce_conds), rev,
                                   name=alg.name)

    def _reduce_impl(
        self, group: list[int], root: int, *, bytes: float = 1.0,
    ) -> CollectiveAlgorithm:
        rconds = cnd.reduce(group, root, ids=ChunkIds(0), bytes=bytes)
        bcast = [
            Condition(r.chunk, root, r.srcs, bytes=r.bytes, tag="rev_bcast")
            for r in rconds
        ]
        alg = self.synthesize(bcast, name="pccl_reduce",
                              topology=self.reversed_topology())
        return self._reverse_algorithm(alg, rconds)

    def _reduce_scatter_impl(
        self, group: list[int], *, bytes: float = 1.0, chunks_per_npu: int = 1,
    ) -> CollectiveAlgorithm:
        rconds = cnd.reduce_scatter(group, ids=ChunkIds(0), bytes=bytes,
                                    chunks_per_npu=chunks_per_npu)
        ag = [
            Condition(r.chunk, next(iter(r.dests)), r.srcs, bytes=r.bytes,
                      tag="rev_ag")
            for r in rconds
        ]
        alg = self.synthesize(ag, name="pccl_reduce_scatter",
                              topology=self.reversed_topology())
        return self._reverse_algorithm(alg, rconds)

    def _all_reduce_impl(
        self, group: list[int], *, bytes: float = 1.0, pipelined: bool = False,
    ) -> CollectiveAlgorithm:
        """All-Reduce = Reduce-Scatter then All-Gather (paper §4.5). Each NPU
        in the group owns one shard-chunk. With ``pipelined=True``
        (beyond-paper), each chunk's All-Gather is released at that chunk's
        Reduce-Scatter completion instead of the global makespan."""
        rs = self._reduce_scatter_impl(group, bytes=bytes)
        # per-chunk completion time of the reduce-scatter phase
        owner = {c.chunk: next(iter(c.dests)) for c in rs.conditions}
        done: dict[int, float] = {c.chunk: 0.0 for c in rs.conditions}
        for t in rs.transfers:
            done[t.chunk] = max(done[t.chunk], t.end)
        rs_makespan = max(done.values(), default=0.0)

        ag_conds = [
            Condition(
                c.chunk,
                owner[c.chunk],
                frozenset(group),
                bytes=bytes,
                release=(done[c.chunk] if pipelined else rs_makespan),
                tag="allreduce_ag",
            )
            for c in rs.conditions
        ]
        ag = self.synthesize(ag_conds, preload=rs, name="pccl_all_reduce")
        ar_conds = [
            ReduceCondition(c.chunk, frozenset(group), frozenset(group),
                            bytes=bytes)
            for c in rs.conditions
        ]
        return CollectiveAlgorithm(
            self.topology, ar_conds, rs.transfers + ag.transfers,
            name="pccl_all_reduce",
        )
