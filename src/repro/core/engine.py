"""SynthesisEngine: the single owner of the PCCL synthesis loop.

Historically every ``synthesize*`` front-end in :mod:`repro.core.synthesizer`
re-implemented the same lifecycle: build a TEN, pick int/cont mode, order
conditions, run BFS per condition, commit the pruned paths. The engine owns
that lifecycle in one place (paper §4.4, Algorithm 3) and adds two things the
front-ends could not:

* a per-topology distance cache shared across calls (condition ordering no
  longer recomputes shortest paths for every collective on the same fabric);
* an optional :class:`repro.core.registry.AlgorithmRegistry` hook — named
  collectives (all_gather, all_to_all, reductions) are fetched through the
  registry so isomorphic process groups reuse one synthesized, canonicalized
  plan instead of redoing the TEN/BFS work.

The ``synthesize*`` functions in ``synthesizer.py`` remain as thin wrappers
for backward compatibility; new code should hold a ``SynthesisEngine``.
"""

from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass, replace
from typing import Callable, Sequence

import numpy as np

from repro.core import conditions as cnd
from repro.core.algorithm import (CollectiveAlgorithm, Transfer,
                                  TransferColumns)
from repro.core.conditions import ChunkIds, Condition, ReduceCondition
from repro.core.pathfinding import PathResult, bfs_cont, bfs_int
from repro.core.registry import renumber_chunks
from repro.core.request import (_UNSET, CollectiveRequest,
                                PCCLDeprecationWarning)
from repro.core.ten import TEN
from repro.topology.topology import Topology


# ---------------------------------------------------------------------------
# Phase composition (generalizes the old ad-hoc ``preload`` hack)
# ---------------------------------------------------------------------------

@dataclass
class PhaseSpec:
    """One phase of a composed synthesis, on one global clock.

    A phase either carries ``conds`` to synthesize (releases are absolute
    times — ``after``/``start`` only raise them) or a pre-synthesized
    ``algorithm`` whose transfers are already absolutely timed. Phases may
    run on a sub-topology: ``node_map``/``link_map`` translate local ids
    back into the composing engine's fabric (see
    :meth:`repro.topology.topology.Topology.pod_subtopology`), and
    ``chunk_map`` renumbers phase-local chunk ids into the final
    condition set's ids.

    ``preload_from`` names earlier phases on the *same* topology object
    whose transfers are committed into this phase's TEN before searching, so
    time-overlapping phases stay congestion-free — the mechanism behind
    pipelined All-Reduce and pipelined hierarchical scatter phases.

    Floors come in two granularities. ``after``/``start`` derive one scalar
    floor for the whole phase (the classic barrier). ``floors_from`` /
    ``floors`` instead derive a *per-chunk* floor vector: each condition's
    release is raised to its own chunk's floor — ``floors_from`` names
    earlier phases whose per-chunk completion times (max transfer end per
    global chunk id, the packed ``np.unique`` + ``maximum.at`` reduction)
    become the vector, ``floors`` supplies explicit global-chunk-id ->
    absolute-time entries. This is what lets a composed All-Reduce release
    each chunk's gather at that chunk's own reduce completion instead of
    the phase barrier. Per-chunk floors only ever *raise* releases, and
    they apply to ``conds`` phases only: a pre-synthesized ``algorithm``
    is one congestion-free block — shifting its chunks by different
    amounts could overlap transfers on a shared link, so chunk-granular
    phases must be (re-)synthesized with the floors in their conditions.
    """

    name: str
    conds: list[Condition] | None = None
    algorithm: CollectiveAlgorithm | None = None
    topology: Topology | None = None  # None = the engine's fabric
    node_map: Sequence[int] | None = None  # local node -> global node
    link_map: Sequence[int] | None = None  # local link -> global link
    chunk_map: dict[int, int] | None = None  # local chunk -> global chunk
    after: tuple[str, ...] = ()  # release floor: ends of these phases
    start: float = 0.0  # extra absolute release floor
    preload_from: tuple[str, ...] = ()
    mode: str = "auto"
    replicate: bool = False  # enable the path-replication fast path
    floors_from: tuple[str, ...] = ()  # per-chunk floors: deps' done-times
    floors: dict[int, float] | None = None  # global chunk -> absolute floor


@dataclass
class PhasePlan:
    """Ordered phases + the overall conditions the stitched result fulfils."""

    phases: list[PhaseSpec]
    conditions: list  # list[Condition | ReduceCondition]
    name: str = "pccl_phased"


# ---------------------------------------------------------------------------
# Time reversal (paper §4.5, Fig. 8)
# ---------------------------------------------------------------------------

def time_reversed(
    forward_topo: Topology,
    alg: CollectiveAlgorithm,
    reduce_conds: list,
    *,
    name: str | None = None,
) -> CollectiveAlgorithm:
    """Reverse a (broadcast/all-gather style) algorithm synthesized on the
    reversed topology into a reduction algorithm on the forward topology.

    Link k of ``reversed(topo)`` is link k of ``topo`` with endpoints swapped
    (by construction), so link ids carry over directly. A transfer at [s, e)
    maps to [T - e, T - s): out-trees become in-trees and causality is
    preserved (child partials arrive before the parent forwards its own
    partial). Phase provenance is carried over with spans mirrored into the
    reversed clock and re-sorted into execution order — the scatter phases
    of a hierarchical broadcast become the leaf reduce phases of the
    reduction. Nested spans (``"parent/child"`` entries from multi-level
    composition) mirror the same way; sorting by mirrored start keeps
    parents adjacent to their children even though a parent's window
    contains its children's.
    """
    cols = alg.columns
    T = float(cols.end.max()) if len(cols) else 0.0
    # the reversed schedule starts no earlier than the *latest* release
    # among the reduce conditions: with uniform releases max == min (the
    # historical behaviour, byte-identical), while per-chunk heterogeneous
    # releases (chunk-granular phase floors) need every reversed transfer
    # to clear every condition's release bound
    base = max((c.release for c in reduce_conds), default=0.0)
    rev = cols.time_reversed(base + T)
    spans = sorted(
        ((ph, base + T - hi, base + T - lo)
         for ph, lo, hi in alg.phase_spans),
        key=lambda s: (s[1], s[2], s[0]),
    )
    return CollectiveAlgorithm(forward_topo, list(reduce_conds), rev,
                               name=name or alg.name, phase_spans=spans)


# ---------------------------------------------------------------------------
# Distances for condition ordering (Algorithm 3, lines 1-7)
# ---------------------------------------------------------------------------

class _DistanceCache:
    """Per-source shortest-path times on the static topology, cached.

    Homogeneous graphs use hop counts; heterogeneous use alpha-beta link
    times for the given chunk size (Dijkstra).
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        self.homog = topo.homogeneous()
        self._cache: dict = {}

    def _hops_from(self, src: int) -> "list[float]":
        """Hop distances from one source, served from the topology's shared
        all-pairs matrix (one C-level sweep) when scipy is available."""
        topo = self.topo
        matrix = topo.hop_matrix()
        if matrix is not None:
            return matrix[src].tolist()
        dn = topo.hop_distances_np(src).astype(float)
        dn[dn < 0] = float("inf")
        return dn.tolist()

    def dist(self, src: int, chunk_bytes: float) -> list[float]:
        key = (src, None if self.homog else chunk_bytes)
        got = self._cache.get(key)
        if got is not None:
            return got
        topo = self.topo
        if self.homog:
            d = self._hops_from(src)
        else:
            d = [float("inf")] * topo.num_nodes
            d[src] = 0.0
            heap = [(0.0, src)]
            while heap:
                du, u = heapq.heappop(heap)
                if du > d[u]:
                    continue
                for link in topo.out_links(u):
                    alt = du + link.transfer_time(chunk_bytes)
                    if alt < d[link.dst]:
                        d[link.dst] = alt
                        heapq.heappush(heap, (alt, link.dst))
        self._cache[key] = d
        return d

    def condition_dist(self, c: Condition) -> float:
        d = self.dist(c.src, c.bytes)
        return max((d[dst] for dst in c.remote_dests), default=0.0)


def order_conditions(topo: Topology, conds: list[Condition]) -> list[Condition]:
    """Sort descending by max shortest-path distance (Algorithm 3 line 7);
    deterministic tie-break on (bytes, chunk id)."""
    return SynthesisEngine(topo).order_conditions(conds)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SynthesisEngine:
    """Owns TEN lifecycle, mode selection, condition ordering, and commit.

    One engine per physical topology; cheap to construct, cheaper to reuse
    (the distance cache and the reversed-topology view persist across calls).
    Pass a ``registry`` to share synthesized plans across isomorphic process
    groups and across engines.
    """

    def __init__(self, topology: Topology, *, registry=None,
                 gateway_strategy: str = "auto", sketch=None):
        self.topology = topology
        self.registry = registry
        # inter-pod gateway selection policy and operator constraints for
        # the hierarchical route (see repro.core.hierarchy and
        # repro.core.traffic); picked up by the lazy HierarchicalSynthesizer
        self.gateway_strategy = gateway_strategy
        self.sketch = sketch
        self._distances = _DistanceCache(topology)
        self._rev_topo: Topology | None = None
        self._hier = None  # lazy HierarchicalSynthesizer
        # request-configured engine variants (gateway_strategy/sketch
        # overrides), sharing this engine's topology and registry
        self._variants: dict = {}
        # opt-in plan-capture hook (repro.core.repair): when a list, every
        # synthesize_plan() appends (plan, result) so a repairer can keep
        # the composed PhaseSpec record alongside the stitched algorithm
        self._capture: list | None = None
        # degradation fingerprint (repro.core.repair): set on engines built
        # over degraded fabric views. Folded into whole-collective registry
        # route params — on top of the degraded topology's own structure
        # hash — so a degraded plan never cross-serves a healthy fabric's
        # request or another event's. Appended only when set, keeping
        # healthy-fabric keys bit-identical to the pre-repair format.
        self.degradation: str | None = None
        # reusable per-topology state: {id(topo): (topo, TEN)} — the forward
        # and reversed views in practice. TENs are reset() per synthesis
        # instead of reallocated; distance caches persist across calls.
        self._tens: dict[int, tuple[Topology, TEN]] = {}
        self._dist_caches: dict[int, tuple[Topology, _DistanceCache]] = {
            id(topology): (topology, self._distances)
        }
        # fixed-route scheduling state: canonical (src, dest) routes (found
        # by BFS on an empty TEN, memoized). Keyed by object id but guarded
        # by identity — the entry pins (topo, empty TEN, route table), so a
        # recycled id can never serve a stale topology's routes.
        self._route_tens: dict[int, tuple[Topology, TEN, dict]] = {}

    # -- lifecycle pieces ---------------------------------------------------

    def _ten_for(self, topo: Topology) -> TEN:
        ent = self._tens.get(id(topo))
        if ent is None or ent[0] is not topo:
            ent = (topo, TEN(topo))
            self._tens[id(topo)] = ent
        ten = ent[1]
        ten.reset()
        return ten

    def _dist_cache_for(self, topo: Topology) -> _DistanceCache:
        ent = self._dist_caches.get(id(topo))
        if ent is None or ent[0] is not topo:
            ent = (topo, _DistanceCache(topo))
            self._dist_caches[id(topo)] = ent
        return ent[1]

    def order_conditions(self, conds: list[Condition]) -> list[Condition]:
        return self._order(self._distances, conds)

    @staticmethod
    def _order(cache: _DistanceCache, conds: list[Condition],
               group_runs: bool = False) -> list[Condition]:
        """Sort by (-max shortest-path distance, -bytes, chunk), stable.

        Distances come from one (cached, vectorized) pass per source; the
        composite sort key is evaluated in bulk with a numpy lexsort instead
        of a per-condition ``condition_dist`` call inside ``sorted``.

        ``group_runs`` additionally breaks distance ties by (src, dest,
        release) so identical conditions land adjacent — the precondition
        for the path-replication fast path in :meth:`synthesize`. Algorithm 3
        only prescribes the distance ordering, so tie-break choice does not
        affect correctness.

        Release-bearing condition sets (composed phases, pipelined
        All-Reduce) tie-break by ascending release before chunk id —
        schedule what is ready first; for the uniform-release sets of plain
        collectives every release is equal, so flat synthesis order is
        byte-identical to the historical one."""
        nc = len(conds)
        if nc <= 1:
            return list(conds)
        dist_key = np.empty(nc)
        bytes_key = np.empty(nc)
        chunk_key = np.empty(nc, dtype=np.int64)
        rel_key = np.empty(nc)
        if group_runs:
            src_key = np.empty(nc, dtype=np.int64)
            dest_key = np.empty(nc, dtype=np.int64)
        for k, c in enumerate(conds):
            d = cache.dist(c.src, c.bytes)
            rd = c.remote_dests
            if len(rd) == 1:
                (x,) = rd
                dist_key[k] = d[x]
            else:
                dist_key[k] = max((d[x] for x in rd), default=0.0)
            bytes_key[k] = c.bytes
            chunk_key[k] = c.chunk
            rel_key[k] = c.release
            if group_runs:
                src_key[k] = c.src
                dest_key[k] = min(c.dests)
        if group_runs:
            order = np.lexsort(
                (np.arange(nc), chunk_key, rel_key, dest_key, src_key,
                 -bytes_key, -dist_key)
            )
        else:
            order = np.lexsort(
                (np.arange(nc), chunk_key, rel_key, -bytes_key, -dist_key)
            )
        return [conds[k] for k in order]

    def _use_int_mode(self, conds: list[Condition],
                      topo: Topology | None = None) -> bool:
        topo = topo or self.topology
        if not topo.homogeneous() or not conds:
            return False
        b0 = conds[0].bytes
        if any(c.bytes != b0 for c in conds):
            return False
        if any(c.release != int(c.release) for c in conds):
            return False
        # unit transfer time required for the integer TEN
        link = topo.links[0] if topo.links else None
        return link is None or link.transfer_time(b0) == 1.0

    @staticmethod
    def _fast_int_commit(topo: Topology, int_mode: bool) -> bool:
        """True when the commit needs no switch bookkeeping (the single
        predicate behind both the per-call hoist in ``synthesize`` and the
        fallback in ``_commit``). Switch residency intervals exist solely to
        enforce buffer limits during later searches, so unlimited-buffer
        switches (the common DCI/spine case) take the bulk path too —
        emitted schedules are unchanged, only dead bookkeeping is skipped."""
        if not int_mode:
            return False
        csr = topo.csr()
        return not csr.any_switch or not csr.limited_switches

    def _commit(self, ten: TEN, result: PathResult, int_mode: bool) -> None:
        # occupy links of retained paths only (paper Fig. 6e / Fig. 7)
        topo = ten.topology
        if self._fast_int_commit(topo, int_mode):
            ten.commit_int_many(result.transfers)
            return
        last_send_end: dict[int, float] = {}
        for t in result.transfers:
            if int_mode:
                ten.commit_int(t.link, int(t.start))
            else:
                ten.commit(t.link, t.start, t.end)
            if topo.is_switch(t.src):
                last_send_end[t.src] = max(last_send_end.get(t.src, 0.0), t.end)
        # switch residency: arrival .. last retained forward
        for t in result.transfers:
            if topo.is_switch(t.dst):
                ten.commit_residency(
                    t.dst, t.end, max(last_send_end.get(t.dst, t.end), t.end)
                )

    def reversed_topology(self) -> Topology:
        """The link-reversed view used for reduction synthesis, built once."""
        if self._rev_topo is None:
            self._rev_topo = self.topology.reversed()
        return self._rev_topo

    # -- Algorithm 3 --------------------------------------------------------

    def synthesize(
        self,
        conds: list[Condition],
        *,
        preload: CollectiveAlgorithm | None = None,
        mode: str = "auto",
        name: str = "pccl",
        topology: Topology | None = None,
        replicate: bool = False,
    ) -> CollectiveAlgorithm:
        """Paper Algorithm 3 over a fresh TEN. ``preload``'s transfers are
        committed first (used to compose All-Reduce phases without link
        conflicts). ``topology`` overrides the engine's topology for internal
        reversed-topology passes.

        ``replicate=True`` enables the bulk-traffic fast paths, active only
        in integer mode on fabrics where link occupancy is the sole
        constraint (no buffer-limited and no serial switches):

        * single-destination conditions take *fixed-route scheduling* — the
          (src, dest) route is searched once on an empty TEN and memoized;
          every chunk then rides it with per-hop earliest-free waits. Bulk
          flows wait in queue instead of detouring, which keeps transfer
          counts at the hop-distance minimum (an earliest-arrival search
          under deep congestion detours, and a thousand-chunk run would
          replicate the detour a thousand times).
        * runs of identical multi-destination conditions reuse the first
          instance's searched tree shifted to the next free time slots,
          falling back to a full search when shifting fails.

        Schedules stay valid by construction (the oracle re-checks
        everything) and the default-off flag keeps flat synthesis
        byte-stable."""
        topo = topology or self.topology
        ten = self._ten_for(topo)
        int_mode = mode == "int" or (
            mode == "auto" and self._use_int_mode(conds, topo)
        )
        if preload is not None:
            if int_mode:
                pc = preload.columns
                ten.commit_int_cols(pc.link, pc.start)
            else:
                for t in preload.transfers:
                    ten.commit(t.link, t.start, t.end)

        repl = replicate and int_mode and self._replication_safe(topo)
        ordered = self._order(self._dist_cache_for(topo), conds,
                              group_runs=repl)
        transfers: list[Transfer] = []
        search = bfs_int if int_mode else bfs_cont
        fast_commit = self._fast_int_commit(topo, int_mode)
        prev_key = None
        prev: PathResult | None = None
        prev_rel = 0.0
        for c in ordered:
            result: PathResult | None = None
            if repl:
                rd = c.remote_dests
                if len(rd) == 1:
                    result = self._fixed_route_schedule(ten, topo, c,
                                                        next(iter(rd)))
                else:
                    # release is deliberately NOT part of the run key:
                    # conditions identical up to their release floor (the
                    # pipelined regime's arrival-staggered bulk runs) still
                    # replicate. Identical-release replicas take the
                    # historical uniform shift; staggered replicas re-time
                    # the template tree hop by hop, because a uniform
                    # shift would stall the whole tree on any busy link
                    key = (c.src, c.dests, c.bytes)
                    if key == prev_key and prev is not None and prev.transfers:
                        if c.release == prev_rel:
                            result = self._shift_result(ten, prev, c)
                        else:
                            result = self._retime_tree(ten, prev, c)
                    if result is None:
                        result = search(ten, c)
                    prev_key, prev, prev_rel = key, result, c.release
            else:
                result = search(ten, c)
            if fast_commit:
                ten.commit_int_many(result.transfers)
            else:
                self._commit(ten, result, int_mode)
            transfers.extend(result.transfers)
        return CollectiveAlgorithm(topo, list(conds), transfers, name=name)

    def _route_for(self, topo: Topology, src: int, dest: int) -> tuple:
        """The canonical (src -> dest) hop sequence ((link, u, v), ...):
        what BFS finds on an uncongested TEN, memoized per topology."""
        ent = self._route_tens.get(id(topo))
        if ent is None or ent[0] is not topo:
            ent = (topo, TEN(topo), {})
            self._route_tens[id(topo)] = ent
        routes = ent[2]
        route = routes.get((src, dest))
        if route is None:
            found = bfs_int(ent[1], Condition(0, src, frozenset([dest])))
            route = tuple((t.link, t.src, t.dst) for t in found.transfers)
            routes[(src, dest)] = route
        return route

    def _fixed_route_schedule(self, ten: TEN, topo: Topology, c: Condition,
                              dest: int) -> PathResult:
        """Schedule one chunk along its memoized route with per-hop
        earliest-free waits (store-and-forward causality by construction)."""
        t = int(c.release)
        transfers = []
        arrivals = {c.src: float(t)}
        free = ten.earliest_free_int
        chunk = c.chunk
        for link, u, v in self._route_for(topo, c.src, dest):
            t = free(link, t)
            transfers.append(Transfer(chunk, link, u, v, float(t),
                                      float(t + 1)))
            t += 1
            arrivals[v] = float(t)
        return PathResult(transfers, arrivals, {dest: float(t)})

    @staticmethod
    def _replication_safe(topo: Topology) -> bool:
        """Path replication reasons about link occupancy only; switches with
        buffer limits or serialized egress add constraints a shifted path
        could violate, so those fabrics always take the full search."""
        return not topo.csr().constrained_switch

    @staticmethod
    def _shift_result(ten: TEN, base: PathResult,
                      c: Condition) -> PathResult | None:
        """Re-place ``base``'s path for a condition ``c`` identical up to
        its release by a uniform time shift onto free slots.

        The minimal feasible shift is a fixpoint of per-link next-free-slot
        queries (each O(1) on the occupancy masks), floored so the earliest
        shifted transfer starts no sooner than ``c.release``; a uniform
        shift preserves store-and-forward causality, so the result needs no
        re-validation. Returns None when no fixpoint is found within the
        iteration budget (the caller falls back to BFS)."""
        ts = base.transfers
        s_min = min(int(t.start) for t in ts)
        k = max(1, int(c.release) - s_min)
        for _ in range(64):
            k2 = k
            for t in ts:
                s = int(t.start) + k2
                free = ten.earliest_free_int(t.link, s)
                if free != s:
                    k2 += free - s
            if k2 == k:
                break
            k = k2
        else:
            return None
        kf = float(k)
        chunk = c.chunk
        transfers = [
            Transfer(chunk, t.link, t.src, t.dst, t.start + kf, t.end + kf,
                     t.reduce)
            for t in ts
        ]
        arrivals = {n: a + kf for n, a in base.arrivals.items()}
        reached = {n: a + kf for n, a in base.reached.items()}
        return PathResult(transfers, arrivals, reached)

    @staticmethod
    def _retime_tree(ten: TEN, base: PathResult,
                     c: Condition) -> PathResult:
        """Re-place ``base``'s multicast tree for a condition ``c`` that
        differs only in its release: each hop is re-timed independently to
        the earliest free slot at or after the chunk's arrival at that
        hop's source (store-and-forward causality by construction). Unlike
        a uniform shift, every hop absorbs its own queueing delay, so
        arrival-staggered bulk runs stay as tight on the template tree as
        a fresh search would be."""
        free = ten.earliest_free_int
        chunk = c.chunk
        arrivals: dict[int, float] = {c.src: float(int(c.release))}
        used: dict[int, int] = {}
        transfers = []
        for t in sorted(base.transfers, key=lambda t: t.start):
            s = int(arrivals[t.src])
            lk = t.link
            if lk in used and used[lk] >= s:
                s = used[lk] + 1
            s = free(lk, s)
            used[lk] = s
            transfers.append(Transfer(chunk, lk, t.src, t.dst,
                                      float(s), float(s + 1)))
            e = float(s + 1)
            if t.dst not in arrivals or e < arrivals[t.dst]:
                arrivals[t.dst] = e
        reached = {n: arrivals[n] for n in base.reached if n in arrivals}
        return PathResult(transfers, arrivals, reached)

    def synthesize_joint(
        self,
        groups: list[tuple[str, list[Condition]]],
        *,
        name: str = "pccl_joint",
    ) -> CollectiveAlgorithm:
        """Jointly synthesize several process groups' collectives over one
        shared TEN (paper §6.4, Fig. 15). Chunk ids across groups must be
        unique — use a shared ChunkIds allocator."""
        all_conds: list[Condition] = []
        for tag, conds in groups:
            all_conds.extend(replace(c, tag=tag) for c in conds)
        seen: set[int] = set()
        for c in all_conds:
            if c.chunk in seen:
                raise ValueError(
                    f"duplicate chunk id {c.chunk} across process groups"
                )
            seen.add(c.chunk)
        return self.synthesize(all_conds, name=name)

    # -- phase composition --------------------------------------------------

    def synthesize_plan(self, plan: PhasePlan) -> CollectiveAlgorithm:
        """Synthesize and stitch an ordered :class:`PhasePlan` into one
        algorithm on the engine's fabric.

        Phases share one absolute clock. For each phase, the release floor is
        ``max(start, end of every phase in after)``; phases carrying raw
        conditions are synthesized on their (sub-)topology with that floor
        folded into every condition's release, then lifted into global
        coordinates through ``node_map``/``link_map``/``chunk_map``. The
        result's conditions are ``plan.conditions`` — the caller's statement
        of what the composition achieves end to end — and ``phase_spans``
        records per-phase provenance. Congestion-freedom across phases comes
        from either disjoint link sets, disjoint time windows, or explicit
        ``preload_from``; the stitched algorithm still passes the full
        validation oracle, which checks all of it from scratch.
        """
        ends: dict[str, float] = {}
        local_algs: dict[str, CollectiveAlgorithm] = {}
        shifts: dict[str, float] = {}
        topos: dict[str, Topology] = {}
        lifted_cols: dict[str, TransferColumns] = {}
        merged: list[TransferColumns] = []
        spans: list[tuple[str, float, float]] = []
        for ph in plan.phases:
            if ph.name in ends:
                raise ValueError(f"duplicate phase name {ph.name!r}")
            if (ph.conds is None) == (ph.algorithm is None):
                raise ValueError(
                    f"phase {ph.name!r}: exactly one of conds/algorithm"
                )
            topo = ph.topology or self.topology
            floor = ph.start
            for dep in ph.after:
                if dep not in ends:
                    raise ValueError(
                        f"phase {ph.name!r} depends on unknown/later phase "
                        f"{dep!r}"
                    )
                floor = max(floor, ends[dep])
            chunk_floors = self._chunk_floors(ph, lifted_cols)
            shift = 0.0
            if ph.algorithm is not None:
                if chunk_floors is not None:
                    raise ValueError(
                        f"phase {ph.name!r}: per-chunk floors apply to "
                        f"conds phases only (a pre-timed algorithm cannot "
                        f"be shifted per chunk without re-synthesis)"
                    )
                # Pre-synthesized phases are canonically timed (their clock
                # starts at 0, which is what makes them cacheable across
                # isomorphic pods); the floor shifts them into place.
                alg = ph.algorithm
                shift = floor
            else:
                conds = ph.conds
                if floor > 0.0:
                    conds = [
                        c if c.release >= floor else replace(c, release=floor)
                        for c in conds
                    ]
                if chunk_floors is not None:
                    # raise-only, per chunk: the phase-local chunk id maps
                    # through chunk_map into the global id space the floor
                    # vector is keyed by
                    cm = ph.chunk_map or {}
                    out = []
                    for c in conds:
                        f = chunk_floors.get(cm.get(c.chunk, c.chunk), 0.0)
                        out.append(replace(c, release=f)
                                   if f > c.release else c)
                    conds = out
                preload = None
                if ph.preload_from:
                    pre: list[TransferColumns] = []
                    for dep in ph.preload_from:
                        if dep not in local_algs:
                            raise ValueError(
                                f"phase {ph.name!r} preloads unknown phase "
                                f"{dep!r}"
                            )
                        if topos[dep] is not topo:
                            raise ValueError(
                                f"phase {ph.name!r} preloads {dep!r} which "
                                f"ran on a different topology"
                            )
                        # occupy the dependency's *effective* window: its
                        # local transfers plus whatever floor shifted it
                        pre.append(
                            local_algs[dep].columns.shifted(shifts[dep]))
                    preload = CollectiveAlgorithm(
                        topo, [], TransferColumns.concat(pre),
                        name="preload")
                alg = self.synthesize(
                    conds, preload=preload, mode=ph.mode,
                    name=f"{plan.name}/{ph.name}", topology=topo,
                    replicate=ph.replicate,
                )
            local_algs[ph.name] = alg
            shifts[ph.name] = shift
            topos[ph.name] = topo
            lifted = self._lift(alg.columns, ph, topo, shift)
            lifted_cols[ph.name] = lifted
            merged.append(lifted)
            if len(lifted):
                t_lo = float(lifted.start.min())
                t_hi = float(lifted.end.max())
            else:
                t_lo = t_hi = floor
            ends[ph.name] = max(t_hi, floor)
            spans.append((ph.name, t_lo, t_hi))
            # multi-level composition: a phase that is itself a composed
            # algorithm (a recursive pod plan, a hierarchical RS inside an
            # All-Reduce) carries its own provenance — record it nested,
            # shifted onto this plan's clock, as "parent/child" entries
            for child, lo, hi in alg.phase_spans:
                spans.append((f"{ph.name}/{child}", lo + shift, hi + shift))
        result = CollectiveAlgorithm(
            self.topology, list(plan.conditions),
            TransferColumns.concat(merged), name=plan.name,
            phase_spans=spans,
        )
        if self._capture is not None:
            self._capture.append((plan, result))
        return result

    @staticmethod
    def _chunk_floors(
        ph: PhaseSpec, lifted_cols: dict[str, TransferColumns],
    ) -> dict[int, float] | None:
        """The phase's per-chunk floor vector (global chunk id -> absolute
        release floor), or None when the phase uses scalar floors only.

        ``floors_from`` dependencies contribute their per-chunk completion
        times — the max transfer end per global chunk over the dependency's
        *lifted* columns (so sub-topology phases and chunk renumbering are
        already folded in); explicit ``floors`` entries merge on top.
        Floors only ever raise releases downstream."""
        if not ph.floors_from and not ph.floors:
            return None
        done: dict[int, float] = {}
        for dep in ph.floors_from:
            cols = lifted_cols.get(dep)
            if cols is None:
                raise ValueError(
                    f"phase {ph.name!r} derives floors from unknown/later "
                    f"phase {dep!r}"
                )
            if not len(cols):
                continue
            uc, inv = np.unique(cols.chunk, return_inverse=True)
            dmax = np.full(len(uc), -np.inf)
            np.maximum.at(dmax, inv, cols.end)
            for ck, d in zip(uc.tolist(), dmax.tolist()):
                if d > done.get(ck, 0.0):
                    done[ck] = d
        for ck, f in (ph.floors or {}).items():
            if f > done.get(ck, 0.0):
                done[ck] = f
        return done

    def _lift(self, cols: TransferColumns, ph: PhaseSpec,
              topo: Topology, shift: float = 0.0) -> TransferColumns:
        """Translate one phase's transfer columns into global coordinates,
        shifted ``shift`` later (phases given as canonical pre-timed
        algorithms)."""
        cm = ph.chunk_map or {}
        if topo is self.topology:
            if ph.node_map is not None or ph.link_map is not None:
                raise ValueError(
                    f"phase {ph.name!r}: node/link maps only apply to "
                    f"sub-topology phases"
                )
            if not cm and shift == 0.0:
                return cols
            return cols.relabeled(chunk_map=cm, shift=shift)
        if ph.node_map is None or ph.link_map is None:
            raise ValueError(
                f"phase {ph.name!r}: sub-topology phases need node_map and "
                f"link_map to lift into {self.topology.name}"
            )
        return cols.relabeled(node_map=ph.node_map, link_map=ph.link_map,
                              chunk_map=cm, shift=shift)

    # -- registry routing ---------------------------------------------------

    def _routed(
        self,
        kind: str,
        group: Sequence[int],
        synth: Callable[[list[int]], CollectiveAlgorithm],
        *,
        params: tuple,
        ids: ChunkIds | None,
    ) -> CollectiveAlgorithm:
        """Fetch a named collective through the registry when one is attached;
        otherwise synthesize directly on the literal group."""
        group = list(group)
        if self.registry is None:
            return renumber_chunks(synth(group), ids)
        return self.registry.get_or_synthesize(
            self.topology, kind, group, synth, params=params, ids=ids
        )

    # -- hierarchical routing ----------------------------------------------

    def hierarchical(self):
        """The engine's :class:`repro.core.hierarchy.HierarchicalSynthesizer`
        (built lazily; shares this engine's TENs, distance caches, and
        registry)."""
        if self._hier is None:
            from repro.core.hierarchy import HierarchicalSynthesizer

            self._hier = HierarchicalSynthesizer(self)
        return self._hier

    def _route_hierarchical(self, hierarchy: str, group) -> tuple[bool, tuple]:
        """Resolve a ``hierarchy`` policy ("auto"/"always"/"never") for one
        group: "auto" takes the hierarchical path exactly when the fabric is
        partitioned and the group spans pods. Returns ``(use_hier,
        route_params)`` — the latter goes into the registry key, and keeps
        "always" distinct from "auto": an auto call may legitimately fall
        back to a flat plan on a HierarchyError and cache it, but "always"
        must re-attempt the hierarchical route (and raise) instead of being
        served that cached flat fallback. On an unpartitioned fabric
        "always" is unsatisfiable and raises outright — a caller pinning
        the pod-aware path must not silently receive flat synthesis.

        Hierarchical routes additionally key on the *full partition-tree
        fingerprint*: the topology structure hash is partition-blind, so
        without it a plan cached for a 2-level view of a fabric would be
        served verbatim for a 3-level view of the same fabric (same
        structure, different ``set_partition``) — structurally valid but
        the wrong decomposition. Flat routes stay fingerprint-free: flat
        synthesis never consults the partition.

        Hierarchical routes also key on the *resolved* gateway strategy and
        the sketch fingerprint: a plan whose inter phase was routed
        round-robin must never be served to a TE or sketch-constrained
        request for the same group (and vice versa)."""
        if hierarchy == "always":
            if self.topology.partition is None:
                from repro.core.hierarchy import HierarchyError

                raise HierarchyError(
                    f"hierarchy='always' on {self.topology.name}: the "
                    f"fabric has no partition (set_partition was never "
                    f"called), so the hierarchical path cannot be taken"
                )
            return True, (True, True, self.topology.partition_fingerprint(),
                          *self._te_route_params())
        if hierarchy == "never" or self.topology.partition is None:
            return False, (False, False, None)
        if hierarchy != "auto":
            raise ValueError(f"hierarchy={hierarchy!r} not in auto/always/never")
        use = self.hierarchical().spans_pods(group)
        if not use:
            return False, (False, False, None)
        return True, (True, False, self.topology.partition_fingerprint(),
                      *self._te_route_params())

    def _te_route_params(self) -> tuple:
        """(resolved gateway strategy, sketch fingerprint) for the registry
        route key. The strategy is resolved ("auto" -> "te" on
        heterogeneous boundary fabrics) so the label is stable per fabric
        and a later default change cannot silently re-serve stale plans."""
        h = self.hierarchical()
        sk = h.sketch
        return (h._effective_strategy(),
                sk.fingerprint() if sk is not None else None)

    # -- named collectives --------------------------------------------------

    def collective(
        self, request: CollectiveRequest, *, ids: ChunkIds | None = None,
    ) -> CollectiveAlgorithm:
        """Synthesize the collective described by ``request`` — the primary
        entry point; the named methods below are thin legacy shims over it.

        A request with ``gateway_strategy``/``sketch`` set synthesizes
        through a memoized engine variant configured accordingly (sharing
        this engine's topology and registry); ``None`` inherits this
        engine's configuration. ``ids`` stays a call-site argument: it is
        the caller's mutable chunk-id allocator, not part of the request's
        identity."""
        if request.gateway_strategy is None and request.sketch is None:
            return self._collective(request, ids=ids)
        return self._configured(
            request.gateway_strategy, request.sketch
        )._collective(request, ids=ids)

    def _configured(self, gateway_strategy, sketch) -> "SynthesisEngine":
        """A memoized engine variant with the given overrides (None =
        inherit), sharing topology + registry so cached plans cross over."""
        gs = (gateway_strategy if gateway_strategy is not None
              else self.gateway_strategy)
        sk = sketch if sketch is not None else self.sketch
        key = (gs, sk.fingerprint() if sk is not None else None)
        if gs == self.gateway_strategy and key[1] == (
                self.sketch.fingerprint() if self.sketch is not None
                else None):
            return self
        eng = self._variants.get(key)
        if eng is None:
            eng = SynthesisEngine(self.topology, registry=self.registry,
                                  gateway_strategy=gs, sketch=sk)
            eng.degradation = self.degradation
            self._variants[key] = eng
        return eng

    def _collective(
        self, req: CollectiveRequest, *, ids: ChunkIds | None,
    ) -> CollectiveAlgorithm:
        group = list(req.group)
        if not group:
            raise ValueError(f"{req.kind}: request has an empty group")
        kind = req.kind
        if kind == "reduce":
            root_pos = group.index(req.root)

            def synth(g: list[int]) -> CollectiveAlgorithm:
                return self._reduce_impl(g, g[root_pos], bytes=req.bytes)

            return self._routed("reduce", group, synth,
                                params=self._params(req, None), ids=ids)
        use_hier, route = self._route_hierarchical(req.hierarchy, group)

        def synth(g: list[int]) -> CollectiveAlgorithm:
            if use_hier:
                from repro.core.hierarchy import HierarchyError

                try:
                    return self._hier_impl(kind, g, req)
                except HierarchyError:
                    # HierarchyError is advisory (see repro.core.errors):
                    # the auto route may retry flat — unless the caller
                    # pinned the hierarchical path or a sketch is attached
                    # (a flat plan would ignore its hard constraints)
                    if req.hierarchy == "always" or self.sketch is not None:
                        raise
            return self._flat_impl(kind, g, req)

        return self._routed(kind, group, synth,
                            params=self._params(req, route), ids=ids)

    def _params(self, req: CollectiveRequest, route) -> tuple:
        """The request's registry params, extended with the degradation
        fingerprint on degraded-fabric engines (see ``self.degradation``)."""
        params = req.registry_params(route)
        if self.degradation is not None:
            params = (*params, ("degraded", self.degradation))
        return params

    def _hier_impl(self, kind, g, req: CollectiveRequest):
        h = self.hierarchical()
        if kind == "all_gather":
            return h.all_gather(g, bytes=req.bytes, chunks_per_npu=req.chunks)
        if kind == "all_to_all":
            return h.all_to_all(g, bytes=req.bytes, chunks_per_pair=req.chunks)
        if kind == "reduce_scatter":
            return h.reduce_scatter(g, bytes=req.bytes,
                                    chunks_per_npu=req.chunks)
        return h.all_reduce(g, bytes=req.bytes)

    def _flat_impl(self, kind, g, req: CollectiveRequest):
        if kind == "all_gather":
            conds = cnd.all_gather(g, ids=ChunkIds(), bytes=req.bytes,
                                   chunks_per_npu=req.chunks)
            return self.synthesize(conds, name="pccl_all_gather")
        if kind == "all_to_all":
            conds = cnd.all_to_all(g, ids=ChunkIds(), bytes=req.bytes,
                                   chunks_per_pair=req.chunks)
            return self.synthesize(conds, name="pccl_all_to_all")
        if kind == "reduce_scatter":
            return self._reduce_scatter_impl(g, bytes=req.bytes,
                                             chunks_per_npu=req.chunks)
        return self._all_reduce_impl(g, bytes=req.bytes,
                                     pipelined=req.pipelined)

    # -- legacy kwarg shims -------------------------------------------------

    def _shim(self, kind, group, explicit, ids, **req_kw):
        """Common body of the legacy named-collective shims: accept a
        CollectiveRequest positionally, else build one from the legacy
        kwargs — warning (with the *caller's* frame blamed) only when a
        tuning kwarg was explicitly passed, so bare ``eng.all_gather(g)``
        stays silent sugar."""
        if isinstance(group, CollectiveRequest):
            if group.kind != kind:
                raise ValueError(
                    f"SynthesisEngine.{kind}() got a {group.kind!r} request")
            if explicit:
                raise TypeError(
                    f"SynthesisEngine.{kind}(): pass tuning in the "
                    f"CollectiveRequest, not alongside it")
            return self.collective(group, ids=ids)
        if explicit:
            warnings.warn(
                f"SynthesisEngine.{kind}({', '.join(sorted(explicit))}) "
                f"kwargs are deprecated; pass a CollectiveRequest to "
                f"SynthesisEngine.collective()",
                PCCLDeprecationWarning, stacklevel=3)
        req = CollectiveRequest(kind, group=tuple(group), **req_kw)
        return self._collective(req, ids=ids)

    def all_gather(
        self, group, *, bytes=_UNSET, chunks_per_npu=_UNSET, ids=None,
        hierarchy=_UNSET,
    ) -> CollectiveAlgorithm:
        explicit = {k for k, v in (("bytes", bytes),
                                   ("chunks_per_npu", chunks_per_npu),
                                   ("hierarchy", hierarchy))
                    if v is not _UNSET}
        return self._shim(
            "all_gather", group, explicit, ids,
            bytes=1.0 if bytes is _UNSET else bytes,
            chunks=1 if chunks_per_npu is _UNSET else chunks_per_npu,
            hierarchy="auto" if hierarchy is _UNSET else hierarchy)

    def all_to_all(
        self, group, *, bytes=_UNSET, chunks_per_pair=_UNSET, ids=None,
        hierarchy=_UNSET,
    ) -> CollectiveAlgorithm:
        explicit = {k for k, v in (("bytes", bytes),
                                   ("chunks_per_pair", chunks_per_pair),
                                   ("hierarchy", hierarchy))
                    if v is not _UNSET}
        return self._shim(
            "all_to_all", group, explicit, ids,
            bytes=1.0 if bytes is _UNSET else bytes,
            chunks=1 if chunks_per_pair is _UNSET else chunks_per_pair,
            hierarchy="auto" if hierarchy is _UNSET else hierarchy)

    def reduce(
        self, group, root=None, *, bytes=_UNSET, ids=None,
    ) -> CollectiveAlgorithm:
        if isinstance(group, CollectiveRequest):
            if root is not None:
                raise TypeError(
                    "SynthesisEngine.reduce(): root lives in the request")
            return self._shim("reduce", group, set(), ids)
        if root is None:
            raise TypeError("SynthesisEngine.reduce() needs root")
        explicit = {"bytes"} if bytes is not _UNSET else set()
        return self._shim(
            "reduce", group, explicit, ids,
            bytes=1.0 if bytes is _UNSET else bytes, root=root)

    def reduce_scatter(
        self, group, *, bytes=_UNSET, chunks_per_npu=_UNSET, ids=None,
        hierarchy=_UNSET,
    ) -> CollectiveAlgorithm:
        explicit = {k for k, v in (("bytes", bytes),
                                   ("chunks_per_npu", chunks_per_npu),
                                   ("hierarchy", hierarchy))
                    if v is not _UNSET}
        return self._shim(
            "reduce_scatter", group, explicit, ids,
            bytes=1.0 if bytes is _UNSET else bytes,
            chunks=1 if chunks_per_npu is _UNSET else chunks_per_npu,
            hierarchy="auto" if hierarchy is _UNSET else hierarchy)

    def all_reduce(
        self, group, *, bytes=_UNSET, ids=None, pipelined=_UNSET,
        hierarchy=_UNSET,
    ) -> CollectiveAlgorithm:
        """All-Reduce = Reduce-Scatter then All-Gather. Pod-spanning groups
        on partitioned fabrics route hierarchically (both halves composed
        through the pod-aware pipeline); ``pipelined`` applies to the flat
        route only — the hierarchical composition runs its phases on the
        dependency floors derived by ``synthesize_plan``."""
        explicit = {k for k, v in (("bytes", bytes),
                                   ("pipelined", pipelined),
                                   ("hierarchy", hierarchy))
                    if v is not _UNSET}
        return self._shim(
            "all_reduce", group, explicit, ids,
            bytes=1.0 if bytes is _UNSET else bytes,
            pipelined=False if pipelined is _UNSET else pipelined,
            hierarchy="auto" if hierarchy is _UNSET else hierarchy)

    # -- reduction internals (paper §4.5, Fig. 8) ---------------------------

    def _reverse_algorithm(
        self,
        alg: CollectiveAlgorithm,
        reduce_conds: list[ReduceCondition],
    ) -> CollectiveAlgorithm:
        """See :func:`time_reversed` — engine-local wrapper binding the
        forward fabric."""
        return time_reversed(self.topology, alg, reduce_conds)

    def _reduce_impl(
        self, group: list[int], root: int, *, bytes: float = 1.0,
    ) -> CollectiveAlgorithm:
        rconds = cnd.reduce(group, root, ids=ChunkIds(0), bytes=bytes)
        bcast = cnd.gather_view(rconds, tag="rev_bcast")
        alg = self.synthesize(bcast, name="pccl_reduce",
                              topology=self.reversed_topology())
        return self._reverse_algorithm(alg, rconds)

    def _reduce_scatter_impl(
        self, group: list[int], *, bytes: float = 1.0, chunks_per_npu: int = 1,
    ) -> CollectiveAlgorithm:
        rconds = cnd.reduce_scatter(group, ids=ChunkIds(0), bytes=bytes,
                                    chunks_per_npu=chunks_per_npu)
        ag = cnd.gather_view(rconds, tag="rev_ag")
        alg = self.synthesize(ag, name="pccl_reduce_scatter",
                              topology=self.reversed_topology())
        return self._reverse_algorithm(alg, rconds)

    def _all_reduce_impl(
        self, group: list[int], *, bytes: float = 1.0, pipelined: bool = False,
    ) -> CollectiveAlgorithm:
        """All-Reduce = Reduce-Scatter then All-Gather (paper §4.5), composed
        as a two-phase :class:`PhasePlan`. Each NPU in the group owns one
        shard-chunk. With ``pipelined=True`` (beyond-paper), each chunk's
        All-Gather is released at that chunk's Reduce-Scatter completion
        instead of the global makespan; ``preload_from`` keeps the
        overlapping phases congestion-free on the shared links."""
        rs = self._reduce_scatter_impl(group, bytes=bytes)
        owner = {c.chunk: next(iter(c.dests)) for c in rs.conditions}
        ag_conds = [
            Condition(c.chunk, owner[c.chunk], frozenset(group), bytes=bytes,
                      tag="allreduce_ag")
            for c in rs.conditions
        ]
        ar_conds = [
            ReduceCondition(c.chunk, frozenset(group), frozenset(group),
                            bytes=bytes)
            for c in rs.conditions
        ]
        # pipelined: each chunk's gather releases at its own reduce
        # completion — the per-chunk floor vector derived from the RS
        # phase's columns; barrier mode floors the whole phase at RS end
        plan = PhasePlan(
            phases=[
                PhaseSpec("reduce_scatter", algorithm=rs),
                PhaseSpec("all_gather", conds=ag_conds,
                          preload_from=("reduce_scatter",),
                          floors_from=(("reduce_scatter",) if pipelined
                                       else ()),
                          after=(() if pipelined else ("reduce_scatter",))),
            ],
            conditions=ar_conds,
            name="pccl_all_reduce",
        )
        return self.synthesize_plan(plan)
