"""alpha-beta store-and-forward network simulator (ASTRA-sim-lite).

The paper evaluates synthesized algorithms in ASTRA-sim (§5.1). We reproduce
the relevant behavior with a per-link FIFO queuing simulator: chunks follow
fixed hop-by-hop routes; each directed link serves one chunk at a time with
service time alpha + bytes*beta; a chunk becomes ready at hop k+1 when its
hop-k transfer completes (store-and-forward).

PCCL-synthesized algorithms are already fully timed and congestion-free, so
"simulating" them is a replay; the simulator's queuing model is what gives
the *baseline* (Direct / logical-ring) algorithms their contention behavior
— the effect the paper's Figures 13/14/16-19 measure.
"""

from __future__ import annotations

import heapq
from collections import defaultdict
from dataclasses import dataclass, field

from repro.core.algorithm import CollectiveAlgorithm, Transfer
from repro.topology.topology import Topology


@dataclass
class Flow:
    """One chunk's demand: bytes moved along `route` (list of link ids)."""

    chunk: int
    bytes: float
    route: list[int]
    release: float = 0.0


@dataclass
class SimResult:
    makespan: float
    completion: dict[int, float]  # chunk -> arrival at final dest
    link_busy: dict[int, float]  # link -> total busy time
    transfers: list[Transfer] = field(default_factory=list)

    def link_utilization(self) -> dict[int, float]:
        span = self.makespan or 1.0
        return {l: b / span for l, b in self.link_busy.items()}

    def busy_timeline(self, num_links: int, bins: int = 50) -> list[float]:
        """Fraction of links busy per time bin (paper Fig. 18)."""
        if not self.transfers or self.makespan <= 0:
            return [0.0] * bins
        width = self.makespan / bins
        busy = [0.0] * bins
        for t in self.transfers:
            # clamp both ends: a transfer starting exactly at the makespan
            # (e.g. a replayed schedule whose last transfer has zero slack)
            # would otherwise index bin `bins`
            b0 = min(int(t.start / width), bins - 1)
            b1 = min(int((t.end - 1e-12) / width), bins - 1)
            for b in range(b0, b1 + 1):
                lo = max(t.start, b * width)
                hi = min(t.end, (b + 1) * width)
                busy[b] += max(0.0, hi - lo)
        return [x / (width * num_links) for x in busy]


def simulate_flows(topo: Topology, flows: list[Flow]) -> SimResult:
    """Event-driven FIFO queuing over directed links."""
    link_free = [0.0] * topo.num_links
    link_busy: dict[int, float] = defaultdict(float)
    completion: dict[int, float] = {}
    transfers: list[Transfer] = []
    # (ready_time, seq, flow_index, hop_index)
    heap: list[tuple[float, int, int, int]] = []
    seq = 0
    for fi, f in enumerate(flows):
        heapq.heappush(heap, (f.release, seq, fi, 0))
        seq += 1
    while heap:
        ready, _, fi, hop = heapq.heappop(heap)
        f = flows[fi]
        if hop >= len(f.route):
            completion[f.chunk] = ready
            continue
        link = topo.links[f.route[hop]]
        start = max(ready, link_free[link.id])
        if start > ready:
            # another chunk may become ready before this one can start;
            # requeue at the link's free time to preserve FIFO-by-ready-time.
            heapq.heappush(heap, (start, seq, fi, hop))
            seq += 1
            continue
        dur = link.transfer_time(f.bytes)
        end = start + dur
        link_free[link.id] = end
        link_busy[link.id] += dur
        transfers.append(Transfer(f.chunk, link.id, link.src, link.dst, start, end))
        heapq.heappush(heap, (end, seq, fi, hop + 1))
        seq += 1
    makespan = max(completion.values(), default=0.0)
    return SimResult(makespan, completion, dict(link_busy), transfers)


def replay_algorithm(alg: CollectiveAlgorithm) -> SimResult:
    """A synthesized schedule is already timed; replay it into a SimResult."""
    completion: dict[int, float] = {}
    for t in alg.transfers:
        completion[t.chunk] = max(completion.get(t.chunk, 0.0), t.end)
    return SimResult(
        alg.makespan, completion, alg.link_busy_time(), list(alg.transfers)
    )


def phase_breakdown(alg: CollectiveAlgorithm) -> dict[str, dict[str, float]]:
    """Per-phase timing of a composed (hierarchical / PhasePlan) algorithm:
    ``{phase: {"start", "end", "span"}}`` from the algorithm's recorded
    ``phase_spans`` — e.g. how much of a hierarchical All-to-All's makespan
    the inter-pod phase accounts for. Multi-level compositions contribute
    nested ``"parent/child"`` keys whose windows lie inside the parent's
    (filter with ``alg.top_phase_spans()`` for the top level only). Empty
    for single-phase algorithms."""
    return {
        name: {"start": lo, "end": hi, "span": hi - lo}
        for name, lo, hi in getattr(alg, "phase_spans", [])
    }


def collective_bandwidth(
    result: SimResult, payload_bytes: float
) -> float:
    """Algorithmic bandwidth: useful collective payload / completion time."""
    return payload_bytes / result.makespan if result.makespan > 0 else float("inf")
