"""The unified PCCL exception surface.

Every domain error the synthesis stack raises derives from
:class:`PCCLError`, so callers can catch one base type at the serving
boundary. The subclasses differ in one load-bearing way: whether the
engine's *silent flat fallback* (retry the collective as a flat
whole-fabric synthesis when the hierarchical route fails) is allowed to
swallow them. The contract, asserted by ``tests/test_request.py``:

``HierarchyError``
    Advisory. "This group/fabric cannot take the hierarchical path"
    (no partition, single pod, missing gateways, unreachable pods). The
    engine's ``hierarchy="auto"`` route MAY catch it and fall back to flat
    synthesis — the flat plan fulfils the same conditions, just without the
    pod decomposition. The fallback is forbidden only when the caller
    pinned the route (``hierarchy="always"``) or a :class:`CommSketch` is
    attached (flat synthesis would ignore its hard constraints).

``SketchInfeasibleError``
    Hard. A sketch constraint cannot be satisfied. Deliberately NOT a
    ``HierarchyError`` subclass: it must never ride the flat fallback,
    because a flat plan would silently ignore the operator's constraints.

``FabricDegradedError``
    Hard, and louder still: the *surviving* fabric cannot fulfil the
    requested collective at all (a group member unreachable, a pod's sole
    gateway dead with no boundary alternative). No fallback of any kind
    may produce a schedule — a degraded fabric must either yield a plan
    that validates end to end or fail with this error. Raised by
    :mod:`repro.core.repair`.
"""

from __future__ import annotations

__all__ = ["PCCLError", "FabricDegradedError"]


class PCCLError(Exception):
    """Base of every PCCL domain error (see the module docstring for the
    per-subclass silent-fallback rules)."""


class FabricDegradedError(PCCLError, RuntimeError):
    """The surviving (degraded) fabric cannot fulfil the requested
    collective: repair and cold resynthesis are both impossible. Never
    swallowed — no fallback path may turn this into a schedule."""
