"""Translate synthesis results into executable representations (paper §4.8).

The paper exports to MSCCL / MSCCL++ for GPU execution. Our deployment
substrate is JAX on TPU, so the primary translation is a *ppermute program*:
the timed transfer schedule is bucketed into rounds; each round becomes one
(or more) ``jax.lax.ppermute`` calls inside ``shard_map`` (see
``repro.comms.executor``). A congestion-free PCCL schedule whose transfers
ride physical-neighbor links translates to neighbor-only permutes on the TPU
torus, preserving the synthesizer's no-contention invariant.

An MSCCL-IR-style JSON export is retained for interoperability/debugging.
"""

from __future__ import annotations

import hashlib
import json
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core.algorithm import CollectiveAlgorithm, TransferColumns
from repro.core.conditions import Condition, ReduceCondition


@dataclass(frozen=True)
class Send:
    src: int
    dst: int
    chunk: int
    reduce: bool = False


@dataclass
class PpermuteProgram:
    """A list of rounds; each round is a set of sends where every device
    appears at most once as a source and at most once as a destination —
    i.e. each round is directly one ``lax.ppermute`` permutation."""

    num_devices: int
    rounds: list[list[Send]] = field(default_factory=list)
    # chunk -> condition metadata for buffer planning. Plain chunks have one
    # initial holder; reduced chunks start at every contributing device.
    chunk_holders: dict[int, tuple[int, ...]] = field(default_factory=dict)
    chunk_dests: dict[int, tuple[int, ...]] = field(default_factory=dict)
    _digest: str | None = field(default=None, repr=False, compare=False)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def num_sends(self) -> int:
        return sum(len(r) for r in self.rounds)

    @property
    def chunk_srcs(self) -> dict[int, int]:
        """Primary holder per chunk (the source for non-reduction chunks)."""
        return {c: h[0] for c, h in self.chunk_holders.items()}

    def digest(self) -> str:
        """Structural fingerprint of the *program itself* (rounds, sends,
        chunk metadata), memoized. Buffer-plan caching keys on this in
        addition to the caller's fingerprint, so two distinct programs can
        never cross-serve one plan even if their callers' fingerprints
        collide (see ``repro.comms.executor.plan_buffers_cached``)."""
        if self._digest is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(str(self.num_devices).encode())
            for rnd in self.rounds:
                h.update(b"|")
                for s in rnd:
                    h.update(
                        f"{s.src},{s.dst},{s.chunk},{int(s.reduce)};".encode())
            h.update(repr(sorted(self.chunk_holders.items())).encode())
            h.update(repr(sorted(self.chunk_dests.items())).encode())
            self._digest = h.hexdigest()
        return self._digest


def _unroll_switch_hops(alg: CollectiveAlgorithm) -> list[tuple]:
    """Collapse switch hops into direct NPU-to-NPU sends.

    Switch nodes exist on the fabric (DCI/spine/aggregation) but not on the
    execution mesh, so a chunk's path ``npu -> switch -> ... -> npu`` must
    lower to sends between NPUs only. Walking transfers in time order, each
    switch keeps a per-chunk set of *contributions* — the effective NPU
    origins whose values have arrived so far:

    * a **copy** out of a switch (multicast fan-out, store-and-forward
      relay) re-emits from any arrived origin — every copy of a chunk
      carries the same value (the validator's normal form permits copies of
      reduce chunks only after assembly), so the origin choice is free and
      we take the earliest arrival for determinism; contributions stay for
      later fan-out hops;
    * a **reduce** out of a switch merges every arrived contribution — the
      lowered program sends each contributing origin's partial directly to
      the hop's destination, which accumulates them (receive-reduce), so
      the switch-side accumulation of the timed schedule is reproduced at
      the destination NPU. Contributions are consumed: the normal form
      allows at most one partial send per (chunk, node).

    Each lowered send is stamped with the *final hop's* start time, so wave
    order (and therefore store-and-forward causality) is inherited from the
    timed schedule: the origin held its value no later than its own send
    into the switch chain, which started strictly earlier.
    """
    topo = alg.topology
    is_sw = topo.is_switch
    # (switch, chunk) -> list of (arrival_time, origin_npu)
    pending: dict[tuple[int, int], list[tuple[float, int]]] = defaultdict(list)
    out: list[tuple] = []
    order = sorted(alg.transfers,
                   key=lambda t: (t.start, t.end, t.src, t.dst, t.chunk))
    eps = 1e-9
    for t in order:
        if is_sw(t.src):
            key = (t.src, t.chunk)
            arrived = [e for e in pending[key] if e[0] <= t.start + eps]
            if not arrived:
                raise ValueError(
                    f"switch {t.src} forwards chunk {t.chunk} at t={t.start} "
                    f"before any arrival: schedule is not store-and-forward"
                )
            if t.reduce:
                origins = [o for _, o in arrived]
                pending[key] = [e for e in pending[key]
                                if e[0] > t.start + eps]
            else:
                origins = [min(arrived)[1]]
        else:
            origins = [t.src]
        if is_sw(t.dst):
            pending[(t.dst, t.chunk)].extend((t.end, o) for o in origins)
        else:
            for o in origins:
                if o == t.dst:
                    if t.reduce:
                        raise ValueError(
                            f"chunk {t.chunk}: reduce contribution of NPU "
                            f"{o} routed back into itself (would double-"
                            f"count); schedule violates the in-forest form"
                        )
                    continue  # copy round-trip: value already resident
                out.append((t.start, o, t.dst, t.chunk, t.reduce))
    return out


def to_ppermute_program(
    alg: CollectiveAlgorithm,
    device_of_npu: dict[int, int] | None = None,
    *,
    unroll_switches: bool = True,
) -> PpermuteProgram:
    """Bucket timed transfers into dependency-honoring ppermute rounds.

    Transfers are grouped by start time (identical start = same wave of the
    synchronous schedule); each wave is split greedily so that within one
    round every device sends at most one chunk and receives at most one chunk
    (ppermute semantics). Store-and-forward causality is kept because waves
    execute in start-time order and a chunk's forward always starts at or
    after its arrival wave.

    Composed :class:`~repro.core.engine.PhasePlan` schedules (hierarchical
    sequential, chunk-pipelined, TE-routed, time-reversed, repaired) lower
    through the same path: their phases share one absolute clock, so
    per-chunk release floors and phase barriers collapse to wave order here,
    and their receive-reduce transfers carry the ``reduce`` flag per send.
    Schedules riding switch nodes (multi_pod DCI, three_level aggregation,
    two_level_switch spines) are unrolled into direct NPU-to-NPU sends
    first (see :func:`_unroll_switch_hops`); pass ``unroll_switches=False``
    to get the historical strict behavior instead.
    """
    if device_of_npu is None:
        device_of_npu = {n: n for n in alg.topology.npus}
    topo = alg.topology
    has_switch = any(
        topo.is_switch(int(n))
        for n in np.unique(np.concatenate(
            [alg.columns.src, alg.columns.dst]))
    ) if len(alg.columns) else False
    if has_switch:
        if not unroll_switches:
            raise ValueError(
                "ppermute translation requires NPU-to-NPU schedules; "
                "unroll switches or use the JSON export"
            )
        sends = _unroll_switch_hops(alg)
    else:
        cols = alg.columns
        sends = list(zip(cols.start.tolist(), cols.src.tolist(),
                         cols.dst.tolist(), cols.chunk.tolist(),
                         cols.reduce.tolist()))
    waves: dict[float, list[tuple]] = defaultdict(list)
    for s in sends:
        waves[round(s[0], 9)].append(s)

    prog = PpermuteProgram(num_devices=len(device_of_npu))
    for c in alg.conditions:
        holders = c.srcs if hasattr(c, "srcs") else (c.src,)
        prog.chunk_holders[c.chunk] = tuple(
            sorted(device_of_npu[s] for s in holders)
        )
        prog.chunk_dests[c.chunk] = tuple(
            sorted(device_of_npu[d] for d in c.dests)
        )
    for start in sorted(waves):
        pending = sorted(waves[start], key=lambda s: (s[1], s[2], s[3]))
        while pending:
            used_src: set[int] = set()
            used_dst: set[int] = set()
            round_sends: list[Send] = []
            rest: list[tuple] = []
            for t in pending:
                s, d = device_of_npu[t[1]], device_of_npu[t[2]]
                if s in used_src or d in used_dst:
                    rest.append(t)
                    continue
                used_src.add(s)
                used_dst.add(d)
                round_sends.append(Send(s, d, t[3], bool(t[4])))
            prog.rounds.append(round_sends)
            pending = rest
    return prog


def to_msccl_json(alg: CollectiveAlgorithm) -> str:
    """MSCCL-IR-flavored JSON: per-NPU ordered op lists with explicit
    dependencies implied by transfer times. The ``conditions`` section (an
    additive extension to the IR) records the pre/postconditions so the
    document round-trips through :func:`from_msccl_json` — this is the
    on-disk format of the algorithm registry."""
    ops_by_npu: dict[int, list[dict]] = defaultdict(list)
    # one tolist() per column: native scalars without per-row Transfer views
    cols = alg.columns
    rows = zip(cols.chunk.tolist(), cols.link.tolist(), cols.src.tolist(),
               cols.dst.tolist(), cols.start.tolist(), cols.end.tolist(),
               cols.reduce.tolist())
    for i, (chunk, link, src, dst, start, end, red) in enumerate(rows):
        ops_by_npu[src].append(
            {"op": "send", "chunk": chunk, "peer": dst, "t_start": start,
             "t_end": end, "link": link, "idx": i, "reduce": red}
        )
        kind = "recv_reduce" if red else "recv"
        ops_by_npu[dst].append(
            {"op": kind, "chunk": chunk, "peer": src, "t_start": start,
             "t_end": end, "link": link, "idx": i, "reduce": red}
        )
    conditions = []
    for c in alg.conditions:
        entry = {"chunk": c.chunk, "dests": sorted(c.dests), "bytes": c.bytes,
                 "release": c.release, "tag": c.tag}
        if isinstance(c, ReduceCondition):
            entry["srcs"] = sorted(c.srcs)
        else:
            entry["src"] = c.src
        conditions.append(entry)
    doc = {
        "name": alg.name,
        "topology": alg.topology.name,
        "num_npus": len(alg.topology.npus),
        "makespan": alg.makespan,
        "conditions": conditions,
        "gpus": [
            {"id": npu, "ops": sorted(ops, key=lambda o: (o["t_start"], o["idx"]))}
            for npu, ops in sorted(ops_by_npu.items())
        ],
    }
    return json.dumps(doc, indent=1)


def from_msccl_json(doc: str | dict, topology) -> CollectiveAlgorithm:
    """Inverse of :func:`to_msccl_json`: rebuild a ``CollectiveAlgorithm``
    against ``topology`` (which must be the fabric the document was exported
    from — link ids are positional). Raises ``ValueError`` on documents
    missing the ``conditions`` extension or referencing unknown links."""
    if isinstance(doc, str):
        doc = json.loads(doc)
    if "conditions" not in doc:
        raise ValueError("document lacks the 'conditions' section; "
                         "re-export with to_msccl_json")
    conds: list = []
    for e in doc["conditions"]:
        if "srcs" in e:
            conds.append(ReduceCondition(
                e["chunk"], frozenset(e["srcs"]), frozenset(e["dests"]),
                e.get("bytes", 1.0), e.get("release", 0.0), e.get("tag", "")))
        else:
            conds.append(Condition(
                e["chunk"], e["src"], frozenset(e["dests"]),
                e.get("bytes", 1.0), e.get("release", 0.0), e.get("tag", "")))
    reduce_idx = {
        op["idx"] for gpu in doc["gpus"] for op in gpu["ops"]
        if op["op"] == "recv_reduce"
    }
    # gather send ops into parallel lists, then validate link ids and
    # endpoints in two vectorized sweeps instead of per-op topology lookups
    chunk: list[int] = []
    link: list[int] = []
    src: list[int] = []
    dst: list[int] = []
    start: list[float] = []
    end: list[float] = []
    red: list[bool] = []
    for gpu in doc["gpus"]:
        gid = gpu["id"]
        for op in gpu["ops"]:
            if op["op"] != "send":
                continue
            chunk.append(op["chunk"])
            link.append(op["link"])
            src.append(gid)
            dst.append(op["peer"])
            start.append(op["t_start"])
            end.append(op["t_end"])
            red.append(op.get("reduce", op["idx"] in reduce_idx))
    la = np.asarray(link, np.int64)
    sa = np.asarray(src, np.int64)
    da = np.asarray(dst, np.int64)
    nl = topology.num_links
    out_of_range = (la < 0) | (la >= nl)
    safe = np.where(out_of_range, 0, la)
    lsrc = np.fromiter((l.src for l in topology.links), np.int64, nl)
    ldst = np.fromiter((l.dst for l in topology.links), np.int64, nl)
    mismatch = ~out_of_range & ((lsrc[safe] != sa) | (ldst[safe] != da)) \
        if nl else out_of_range & False
    bad = out_of_range | mismatch
    if bad.any():
        # report the first offending op, matching the serial scan's order
        k = int(np.argmax(bad))
        if out_of_range[k]:
            raise ValueError(f"op references unknown link {link[k]}")
        raise ValueError(
            f"link {link[k]} endpoints do not match op "
            f"{src[k]}->{dst[k]}: topology mismatch")
    cols = TransferColumns(
        np.asarray(chunk, np.int64), la.astype(np.int32),
        sa.astype(np.int32), da.astype(np.int32),
        np.asarray(start, np.float64), np.asarray(end, np.float64),
        np.asarray(red, np.bool_))
    return CollectiveAlgorithm(topology, conds, cols,
                               name=doc.get("name", "pccl"))
