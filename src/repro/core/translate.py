"""Translate synthesis results into executable representations (paper §4.8).

The paper exports to MSCCL / MSCCL++ for GPU execution. Our deployment
substrate is JAX on TPU, so the primary translation is a *ppermute program*:
the timed transfer schedule is bucketed into rounds; each round becomes one
(or more) ``jax.lax.ppermute`` calls inside ``shard_map`` (see
``repro.comms.executor``). A congestion-free PCCL schedule whose transfers
ride physical-neighbor links translates to neighbor-only permutes on the TPU
torus, preserving the synthesizer's no-contention invariant.

An MSCCL-IR-style JSON export is retained for interoperability/debugging.
"""

from __future__ import annotations

import json
from collections import defaultdict
from dataclasses import dataclass, field

from repro.core.algorithm import CollectiveAlgorithm, Transfer


@dataclass(frozen=True)
class Send:
    src: int
    dst: int
    chunk: int
    reduce: bool = False


@dataclass
class PpermuteProgram:
    """A list of rounds; each round is a set of sends where every device
    appears at most once as a source and at most once as a destination —
    i.e. each round is directly one ``lax.ppermute`` permutation."""

    num_devices: int
    rounds: list[list[Send]] = field(default_factory=list)
    # chunk -> condition metadata for buffer planning. Plain chunks have one
    # initial holder; reduced chunks start at every contributing device.
    chunk_holders: dict[int, tuple[int, ...]] = field(default_factory=dict)
    chunk_dests: dict[int, tuple[int, ...]] = field(default_factory=dict)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def chunk_srcs(self) -> dict[int, int]:
        """Primary holder per chunk (the source for non-reduction chunks)."""
        return {c: h[0] for c, h in self.chunk_holders.items()}


def to_ppermute_program(
    alg: CollectiveAlgorithm, device_of_npu: dict[int, int] | None = None
) -> PpermuteProgram:
    """Bucket timed transfers into dependency-honoring ppermute rounds.

    Transfers are grouped by start time (identical start = same wave of the
    synchronous schedule); each wave is split greedily so that within one
    round every device sends at most one chunk and receives at most one chunk
    (ppermute semantics). Store-and-forward causality is kept because waves
    execute in start-time order and a chunk's forward always starts at or
    after its arrival wave.
    """
    if device_of_npu is None:
        device_of_npu = {n: n for n in alg.topology.npus}
    for t in alg.transfers:
        if alg.topology.is_switch(t.src) or alg.topology.is_switch(t.dst):
            raise ValueError(
                "ppermute translation requires NPU-to-NPU schedules; "
                "unroll switches or use the JSON export"
            )
    waves: dict[float, list[Transfer]] = defaultdict(list)
    for t in alg.transfers:
        waves[round(t.start, 9)].append(t)

    prog = PpermuteProgram(num_devices=len(device_of_npu))
    for c in alg.conditions:
        holders = c.srcs if hasattr(c, "srcs") else (c.src,)
        prog.chunk_holders[c.chunk] = tuple(
            sorted(device_of_npu[s] for s in holders)
        )
        prog.chunk_dests[c.chunk] = tuple(
            sorted(device_of_npu[d] for d in c.dests)
        )
    for start in sorted(waves):
        pending = sorted(waves[start], key=lambda t: (t.src, t.dst, t.chunk))
        while pending:
            used_src: set[int] = set()
            used_dst: set[int] = set()
            round_sends: list[Send] = []
            rest: list[Transfer] = []
            for t in pending:
                s, d = device_of_npu[t.src], device_of_npu[t.dst]
                if s in used_src or d in used_dst:
                    rest.append(t)
                    continue
                used_src.add(s)
                used_dst.add(d)
                round_sends.append(Send(s, d, t.chunk, t.reduce))
            prog.rounds.append(round_sends)
            pending = rest
    return prog


def to_msccl_json(alg: CollectiveAlgorithm) -> str:
    """MSCCL-IR-flavored JSON: per-NPU ordered op lists with explicit
    dependencies implied by transfer times."""
    ops_by_npu: dict[int, list[dict]] = defaultdict(list)
    for i, t in enumerate(alg.transfers):
        ops_by_npu[t.src].append(
            {"op": "send", "chunk": t.chunk, "peer": t.dst, "t_start": t.start,
             "t_end": t.end, "link": t.link, "idx": i}
        )
        kind = "recv_reduce" if t.reduce else "recv"
        ops_by_npu[t.dst].append(
            {"op": kind, "chunk": t.chunk, "peer": t.src, "t_start": t.start,
             "t_end": t.end, "link": t.link, "idx": i}
        )
    doc = {
        "name": alg.name,
        "topology": alg.topology.name,
        "num_npus": len(alg.topology.npus),
        "makespan": alg.makespan,
        "gpus": [
            {"id": npu, "ops": sorted(ops, key=lambda o: (o["t_start"], o["idx"]))}
            for npu, ops in sorted(ops_by_npu.items())
        ],
    }
    return json.dumps(doc, indent=1)
